"""Quickstart: build an architecture, run a forward pass, generate tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch tinyllama-1.1b]

Uses the reduced (smoke) config so it runs on CPU in seconds; drop
``.reduced()`` on a TPU pod to get the full model under the production mesh.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, list_archs
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.sampler import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={M.param_count(cfg):,}")

    # 1. Initialize parameters and run one forward pass.
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32)[None] % cfg.vocab_size}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((1, cfg.num_patches, cfg.d_model),
                                          jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((1, cfg.encdec.encoder_seq_len,
                                     cfg.d_model), jnp.bfloat16)
    logits, _ = M.forward(cfg, params, batch)
    print(f"forward: logits {logits.shape}, "
          f"finite={bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))}")

    # 2. Generate through the continuous-batching serving engine: submit
    # requests with different prompt AND completion lengths, then step the
    # scheduler — each step() admits queued work, prefills (at most) one
    # prompt chunk, and runs decode_steps jitted masked decode iterations
    # across all lanes.
    #
    # KV memory is a PAGED, REF-COUNTED block store: requests address one
    # pool of fixed-size token blocks through per-lane block tables, and
    # requests sharing a prompt prefix (system prompts, few-shot headers)
    # SHARE its blocks — admission matches the longest cached prefix, so
    # prefill only runs the uncached tail, and retired requests' blocks
    # linger in an LRU pool for future hits.  Admission is optimistic (no
    # worst-case reservation): if decode growth runs the pool dry, the
    # youngest request is preempted and recomputed later, bit-identically.
    # Knobs:
    #   block_size    — tokens per KV block; small (8-16) minimizes
    #                   fragmentation AND sharing granularity (only full
    #                   blocks are shared); >= max_len degenerates to one
    #                   stripe per request (the old slot engine);
    #   num_blocks    — pool size (default: max_batch stripes' worth);
    #   prefill_chunk — max prompt tokens prefilled per step, so a long
    #                   prompt's admission interleaves with in-flight
    #                   decodes instead of stalling them (None = whole
    #                   prompt at once);
    #   prefix_cache  — block sharing on/off (greedy outputs are
    #                   bit-identical either way);
    #   decode_steps  — decode iterations per host sync (masked early
    #                   exit on retirement; amortizes dispatch latency);
    #   attn_kernel   — attention-kernel implementation for BOTH paged hot
    #                   paths: "auto" runs the Pallas kernels on TPU and
    #                   the jnp references elsewhere; "on" forces the
    #                   kernels (interpret mode off-TPU), "off" the
    #                   references.  Decode walks each lane's blocks
    #                   through its table straight out of the shared pool
    #                   (KV bytes stream once per token, no dense per-lane
    #                   gather); chunked prefill streams the cached
    #                   context the same way, derives its causal/left-pad
    #                   mask from scalars in-kernel (no dense (B, S, S)
    #                   mask) and scatters the chunk's new K/V into the
    #                   pool inside the same kernel call.  All scheduling
    #                   invariants (prefix sharing, preemption,
    #                   decode_steps) hold bit-identically WITHIN either
    #                   implementation; across them, logits agree to dtype
    #                   tolerance (fp32 online softmax vs bf16 two-pass
    #                   reference).  decode_kernel= is the deprecated
    #                   PR-4 spelling (DeprecationWarning);
    #   preempt_policy— pool-pressure victim selection: "youngest"
    #                   (default), "largest" (most KV blocks held) or
    #                   "deadline" (latest submit(deadline=...) first);
    #   kv_dtype      — paged-pool encoding (SCLAD: store-as-compressed,
    #                   load-as-dense).  "fp" (default) keeps the fp-exact
    #                   bf16 pool; "int8" / "fp8" store a compressed
    #                   payload + per-token-per-head fp32 scales and every
    #                   reader (jnp references AND Pallas kernels, so it
    #                   composes with attn_kernel) dequantizes on load.
    #                   ~1.88x blocks per pool byte at head_dim=64 ->
    #                   more concurrent requests before preemption.  The
    #                   whole scheduling matrix (prefix cache, chunk
    #                   sizes, preemption recompute) stays bit-identical
    #                   WITHIN an encoding — quantization is path-
    #                   independent, and prefix-cache chain roots are
    #                   namespaced per encoding so pools never share
    #                   blocks across kv_dtypes.  Vs the fp-exact pool,
    #                   last-token logits stay within the documented
    #                   gates (tests/test_kv_quant.py: int8 <= 0.15,
    #                   fp8 <= 0.35 max abs error on the smoke configs);
    #   spec_decode   — speculative multi-token decoding: "off" (default)
    #                   or "ngram" (suffix-match draft proposer).  Each
    #                   step drafts up to spec_k tokens per lane from the
    #                   lane's own history, scores anchor+drafts in ONE
    #                   flash-prefill pass, keeps the longest prefix that
    #                   matches what plain decode would sample, and rolls
    #                   rejected K/V back (BlockStore.truncate).  Outputs
    #                   are bit-identical to spec_decode="off" on the
    #                   reference attention path — speculation only
    #                   changes tokens-per-host-sync, never a token;
    #   spec_k        — max drafted tokens per lane per verify pass.
    #                   The win scales with draft ACCEPTANCE RATE: text
    #                   that revisits its own n-grams (code, JSON, chat
    #                   templates, repetitive suffixes) accepts most
    #                   drafts and can approach (1 + spec_k) tokens per
    #                   sync; adversarially random output accepts ~none
    #                   and pays only the slightly wider verify pass.
    #                   stats.spec_acceptance_rate tells you which regime
    #                   a workload is in — below ~0.2, leave spec off.
    eng = ServingEngine(cfg, params, max_batch=2, max_len=48, eos_id=-1,
                        block_size=8, prefill_chunk=16, prefix_cache=True,
                        decode_steps=1,
                        sampler=SamplerConfig(temperature=0.7, top_k=20))
    eng.submit(np.arange(1, 9), max_new_tokens=8)
    eng.submit(np.arange(5, 18), max_new_tokens=5)
    eng.submit(np.arange(2, 8), max_new_tokens=6)  # waits for a freed slot
    done = {}
    if eng.mode == "continuous":
        while len(done) < 3:
            for uid, toks in eng.step():
                done[uid] = toks
    else:  # ssm/hybrid/audio fall back to lockstep wave batching
        done = eng.run()
    for uid, toks in sorted(done.items()):
        print(f"generated[{uid}]: {toks}")
    blocks = (f", KV utilization {eng.stats.block_utilization:.0%}, "
              f"prefix hit-rate {eng.stats.prefix_hit_rate:.0%}") \
        if eng.mode == "continuous" else ""
    print(f"decode throughput: {eng.stats.tokens_per_s:.1f} tok/s, "
          f"prefill {eng.stats.prefill_tokens_per_s:.1f} tok/s, "
          f"mean TTFT {eng.stats.mean_ttft_s * 1e3:.1f}ms, "
          f"lane occupancy {eng.stats.slot_occupancy:.0%}{blocks} (CPU)")

    # 3. The network-facing layer: AsyncFrontend wraps the same engine in
    # an asyncio streaming API with admission control.  submit() returns
    # a TokenStream (async-iterate tokens as the scheduler emits them;
    # aclose() cancels and frees the request's KV blocks); a background
    # pump drives engine.step() off the event loop through a one-worker
    # executor.  Admission: at most max_queue_depth requests in flight
    # (beyond it submit raises RejectedError(kind="backpressure")), and a
    # closed/open/half-open CircuitBreaker sheds arrivals
    # (kind="breaker") while preemption churn or pool saturation
    # persists — deadline=/priority= map onto preempt_policy="deadline"
    # so prioritized traffic is preempted last.  Streamed tokens are
    # bit-identical to the closed-loop run() path (tests/test_frontend.py);
    # `python -m benchmarks.serving_bench` drives Poisson open-loop
    # traces through this layer and reports p50/p99 TTFT/ITL and
    # goodput-under-SLO.
    if eng.mode == "continuous":
        import asyncio
        from repro.serving.frontend import AsyncFrontend

        async def stream_demo():
            async with AsyncFrontend(eng, max_queue_depth=8) as fe:
                stream = await fe.submit(np.arange(3, 12),
                                         max_new_tokens=6, priority=1)
                async for tok in stream:
                    print(f"streamed[{stream.uid}]: {tok}")
                return fe.stats

        fstats = asyncio.run(stream_demo())
        print(f"frontend: accepted={fstats.accepted} "
              f"completed={fstats.completed} "
              f"p99 TTFT {eng.stats.p99_ttft_s * 1e3:.1f}ms, "
              f"p99 ITL {eng.stats.p99_itl_s * 1e3:.1f}ms")

    # 4. Scale out — two rungs on top of one engine:
    #
    #   TENSOR scale-up: pass a ("data", "model") mesh to
    #   ServingEngine(mesh=...).  Weights shard per the serve specs, and
    #   the paged KV pool's kv-head axis (payload AND SCLAD scale
    #   leaves) shards over "model" — both paged attention paths then
    #   run under shard_map with block tables / lengths / starts
    #   broadcast and the per-shard kernel body unchanged, so there is
    #   NO pool-sized collective on the hot path.  Greedy outputs are
    #   bit-identical to the meshless engine (on CPU parity runs use
    #   float32 params: bf16 tensor-parallel psum reduction order can
    #   flip a greedy near-tie).  Try it without a TPU via forced host
    #   devices:
    #     PYTHONPATH=src python -m benchmarks.sharded_probe --model-parallel 2
    #     PYTHONPATH=src python -m repro.launch.dryrun --serving-smoke
    #
    #   DATA-PARALLEL scale-out: N independent replicas (each its own
    #   scheduler, pool, and breaker — nothing shared) behind
    #   serving.router.ReplicaRouter, one submit() surface.  Placement
    #   is prefix-AFFINITY by default: every replica's prefix cache is
    #   probed with the SAME hash chain admission uses, the request goes
    #   to the deepest match (block pools don't gossip — only the
    #   replica holding your system prompt's blocks can skip its
    #   prefill), and no-match traffic falls back to least-loaded.
    #   RejectedError surfaces only when EVERY replica rejected.
    #   The launcher exposes the same path:
    #     python -m repro.launch.serve --frontend async --replicas 2 \
    #         [--router-policy affinity|round_robin]
    if eng.mode == "continuous":
        import asyncio

        from repro.serving.router import ReplicaRouter

        def make_replica():
            return ServingEngine(cfg, params, max_batch=2, max_len=32,
                                 eos_id=-1, block_size=8,
                                 prefill_chunk=None)

        async def fleet_demo():
            async with ReplicaRouter([make_replica(),
                                      make_replica()]) as router:
                system = np.arange(5, 13)  # shared "system prompt"
                # Drain the first request so its prefix blocks commit —
                # affinity can only follow blocks that exist.
                first = await router.submit(
                    np.concatenate([system, [3, 4]]), max_new_tokens=3)
                async for _ in first:
                    pass
                streams = [await router.submit(
                    np.concatenate([system, tail]), max_new_tokens=3)
                    for tail in ([6, 7], [8, 9])]
                for st in streams:
                    async for _ in st:
                        pass
                return router.routing_report()

        rep = asyncio.run(fleet_demo())
        print(f"router: replicas={rep['replicas']} "
              f"per_replica={rep['per_replica_requests']} "
              f"affinity_hit_rate={rep['affinity_hit_rate']:.2f} "
              f"prefix_hit_rate={rep['prefix_hit_rate']:.2f}")

    # 5. Surviving failures.  Replicas die; the fleet should not drop
    # requests when they do.  Three pieces compose:
    #
    #   FAULT INJECTION (serving.faults): FaultyEngine wraps any engine
    #   and injects a seeded FaultPlan — crash (permanent death), hang
    #   (a step that "takes" N ticks), raise (transient exception), slow
    #   (skipped beats) — at the step() BOUNDARY only, counted in step
    #   ticks, never wall clock.  The same plan replays the same chaos
    #   bit-for-bit, so every failure scenario is a deterministic test
    #   (FaultPlan.seeded(seed) draws a reproducible schedule).
    #
    #   HEALTH TRACKING (serving.router.ReplicaHealth): each replica
    #   walks healthy -> suspect -> dead from tick-counted signals — a
    #   step whose cost exceeds deadline_ticks trips the watchdog, and
    #   crash_threshold consecutive step errors declare death.  Suspect
    #   replicas take only a probe request (success revives them);
    #   dead and router.drain(i)'d replicas are excluded from placement
    #   (drain also lets you take a replica down for maintenance and
    #   undrain(i) it back).
    #
    #   BIT-IDENTICAL FAILOVER: when a replica dies, its in-flight
    #   requests are resubmitted to a healthy replica as
    #   prompt + tokens-already-emitted — the same recompute path
    #   preemption uses — and the client's TokenStream continues
    #   SEAMLESSLY from the next token: no duplicates, no gaps, and the
    #   completed greedy output is bit-identical to a failure-free run.
    #   Each request retries at most retry_budget times before its
    #   stream surfaces RejectedError(kind="timeout").
    if eng.mode == "continuous":
        import asyncio

        from repro.serving.faults import FaultPlan, FaultyEngine
        from repro.serving.router import ReplicaRouter

        async def chaos_demo():
            # Replica 0 will crash at step tick 2 — mid-decode for the
            # request below; replica 1 stays healthy as failover target.
            doomed = FaultyEngine(make_replica(), FaultPlan.crash_at(2))
            async with ReplicaRouter([doomed, make_replica()],
                                     policy="round_robin") as router:
                stream = await router.submit(np.arange(4, 12),
                                             max_new_tokens=6)
                toks = [t async for t in stream]
                return toks, router.fault_report()

        toks, ft = asyncio.run(chaos_demo())
        print(f"failover: tokens={toks} "
              f"deaths={ft['replica_deaths']} "
              f"failovers={ft['failovers']} "
              f"health={ft['health']}")
        # The launcher exposes the same chaos knobs end to end:
        #   python -m repro.launch.serve --frontend async --replicas 3 \
        #       --fault-crash-replica 0 --fault-crash-tick 24 \
        #       [--fault-seed 7] [--drain-replica 2] [--retry-budget 3]
        # and its report gains availability + fault_tolerance blocks.


if __name__ == "__main__":
    main()
