"""End-to-end training driver.

    # CPU demo (~1 minute):
    PYTHONPATH=src python examples/train_lm.py --preset smoke

    # ~100M-parameter run, a few hundred steps (sized for a TPU slice; on
    # CPU expect hours):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Shows the full substrate path: synthetic restartable data pipeline, jit'd
train step with FSDP+TP sharding rules, AdamW, async atomic checkpoints.
"""
import argparse
import dataclasses

from repro.configs.base import get_config
from repro.training import optimizer as opt_lib
from repro.training.train_loop import TrainConfig, train


def preset_config(name: str):
    base = get_config("tinyllama-1.1b")
    if name == "smoke":
        return base.reduced(), dict(steps=30, seq_len=64, global_batch=4)
    if name == "100m":
        cfg = dataclasses.replace(
            base, name="tinyllama-100m", num_layers=8, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32_000)
        return cfg, dict(steps=300, seq_len=512, global_batch=32)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg, defaults = preset_config(args.preset)
    if args.steps:
        defaults["steps"] = args.steps
    print(f"training {cfg.name}: {cfg.param_count():,} params, "
          f"{defaults['steps']} steps")
    tcfg = TrainConfig(ckpt_dir=args.ckpt, ckpt_every=50, log_every=10,
                       opt=opt_lib.AdamWConfig(total_steps=defaults["steps"]),
                       **defaults)
    state = train(cfg, tcfg)
    print(f"done at step {state.step}; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
