"""Run the paper's two-phase co-design search end to end (Table 2 style).

    PYTHONPATH=src python examples/codesign_search.py --model gpt3-175b
    PYTHONPATH=src python examples/codesign_search.py --arch phi3-medium-14b

Phase 1 enumerates ~1.3k feasible chip/server designs under the Table 1
constraints; phase 2 searches TP/PP/batch/micro-batch mappings per design
with the analytic inference simulator and ranks by TCO per token.  The same
engine accepts our assigned architectures through the workload adapter.
"""
import argparse

from repro.core import explore
from repro.core.workloads import PAPER_MODELS, from_model_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default=None,
                    choices=sorted(PAPER_MODELS))
    ap.add_argument("--arch", default=None,
                    help="one of the assigned architectures instead")
    ap.add_argument("--ctx", type=int, default=2048)
    args = ap.parse_args()

    if args.arch:
        from repro.configs.base import get_config
        wl = from_model_config(get_config(args.arch))
    else:
        wl = PAPER_MODELS[args.model or "gpt3-175b"]

    print(f"workload: {wl.name}  params={wl.params:.3g} "
          f"(active {wl.active:.3g})  kv/tok={wl.kv_bytes_per_token()/1e3:.0f}KB")
    servers = explore.phase1_servers()
    print(f"phase 1: {len(servers)} feasible server designs")
    res = explore.explore(wl, ctx=args.ctx, servers=servers, keep_all=False)
    row = res.best.table_row()
    print("phase 2 TCO/token-optimal design:")
    for k, v in row.items():
        print(f"  {k:18s} {v}")


if __name__ == "__main__":
    main()
