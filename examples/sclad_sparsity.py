"""SCLD (store-as-compressed, load-as-dense) end to end.

    PYTHONPATH=src python examples/sclad_sparsity.py

1. Block-compresses a weight matrix at several sparsities.
2. Applies it with the Pallas SCLD kernel (interpret mode on CPU).
3. Reports the storage/bandwidth savings and the analytic TCO/token effect
   on an OPT-175B-class model (paper Fig 13).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import hardware, perf, sparsity
from repro.core.workloads import PAPER_MODELS
from repro.kernels.sclad_matmul.ops import SCLDLinear
from repro.kernels.sclad_matmul.ref import sclad_matmul_ref


def main():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((512, 512)).astype(np.float32)
    x = jnp.asarray(rng.standard_normal((128, 512)), jnp.float32)

    print("== kernel: block-SCLD matmul ==")
    for units in (16, 8, 6):
        lin = SCLDLinear.from_dense(w, units_kept=units)
        y = lin(x, interpret=True)
        ref = sclad_matmul_ref(x, np.asarray(lin.vals), np.asarray(lin.rows))
        err = float(jnp.max(jnp.abs(y - ref)))
        dense_b = w.size * 2
        stored_b = lin.vals.size * 2 + lin.rows.size * 4
        print(f"  units={units:2d} sparsity={lin.sparsity:.2f} "
              f"traffic={stored_b / dense_b:.2f}x dense  max_err={err:.2e}")

    print("== system: TCO/token vs sparsity (OPT-175B-class, Fig 13) ==")
    wl = PAPER_MODELS["gpt3-175b"]
    chip = hardware.ChipConfig(die_mm2=140, sram_mb=226, tflops=5.5)
    server = hardware.ServerConfig(chip=chip, chips_per_lane=17)
    base = perf.best_mapping(server, wl, ctx=2048).tco_per_mtoken
    for s in (0.0, 0.3, 0.5, 0.6, 0.7):
        wls = dataclasses.replace(
            wl, weight_storage_factor=sparsity.storage_factor(s))
        dp = perf.best_mapping(server, wls, ctx=2048)
        ppl = sparsity.OPT175B_PERPLEXITY.get(s)
        print(f"  sparsity={s:.1f} tco_delta={100 * (dp.tco_per_mtoken - base) / base:+5.1f}% "
              f"perplexity={ppl}")
    print(f"  max model scale at 60%: {sparsity.max_model_scale(0.6):.2f}x")


if __name__ == "__main__":
    main()
