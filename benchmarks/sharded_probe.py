"""Sharded-vs-single paged-serving parity probe (subprocess half of
``benchmarks.serving_bench`` section 8).

Runs in its OWN process because the device topology is decided at jax
import time: this module forces ``--xla_force_host_platform_device_count``
BEFORE importing jax, builds a (1, model_parallel) ("data", "model") mesh,
and times the SAME request trace through a meshless reduced engine and one
whose paged KV pool (payload + SCLAD scale leaves) is shard_map-sharded
over ``model`` — the PR-9 tensor scale-up rung.  float32 params so TP
psum reduction-order noise cannot flip a greedy argmax (the parity
contract; see tests/test_sharded_dispatch.py for the full matrix).

Prints ONE machine-readable JSON line on stdout:

  {"devices": 2, "model_parallel": 2, "requests": 6, "kv_dtype": "fp",
   "single": {"decode_tokens_per_s": ..., "prefill_tokens_per_s": ...},
   "sharded": {...}, "greedy_identical": true, "stats_identical": true}

Run directly (the bench invokes it with the same flags):
  PYTHONPATH=src python -m benchmarks.sharded_probe \
      [--model-parallel 2] [--requests 6] [--kv-dtype fp]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-parallel", type=int, default=2,
                    help="model-axis mesh size (forced host device count)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--kv-dtype", default="fp", choices=["fp", "int8"],
                    help="pool encoding: int8 shards scale leaves too")
    return ap.parse_args(argv)


def _force_devices(n: int) -> None:
    """Must run before the first jax import in this process."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def run(model_parallel: int = 2, requests: int = 6, max_new: int = 6,
        kv_dtype: str = "fp") -> dict:
    import dataclasses
    import time

    import jax
    import numpy as np

    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serving.engine import EngineStats, ServingEngine

    if len(jax.devices()) < model_parallel:
        raise RuntimeError(
            f"need {model_parallel} devices, have {len(jax.devices())} — "
            f"run this module as its own process (jax was imported before "
            f"the device count was forced)")

    # num_kv_heads must divide by the mesh or the dispatch gate
    # (sharding.attn_shard_size) falls back to the single-device path
    # and the probe would measure nothing.
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              num_heads=max(4, model_parallel),
                              num_kv_heads=model_parallel)
    if kv_dtype != "fp":
        cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    params = jax.tree.map(lambda x: x.astype(jax.numpy.float32),
                          M.init_params(cfg, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(3)
    # Shared 16-token system prompt on half the trace: exercises the
    # prefix-cache + chunked-prefill path under sharding, not just decode.
    system = rng.integers(1, cfg.vocab_size, size=16)
    reqs = []
    for i in range(requests):
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(4, 12)))
        p = np.concatenate([system, tail]) if i % 2 == 0 else tail
        reqs.append((p, max_new))

    def measure(mesh):
        eng = ServingEngine(cfg, params, max_batch=4, max_len=64,
                            mode="continuous", mesh=mesh, block_size=8,
                            prefill_chunk=16, seed=11)
        # Warm pass compiles every prefill bucket + the decode window so
        # the measured pass times steady-state scheduling, not XLA.
        for p, m in reqs:
            eng.submit(p, max_new_tokens=m)
        eng.run()
        eng.stats = EngineStats()
        for p, m in reqs:
            eng.submit(p, max_new_tokens=m)
        t0 = time.perf_counter()
        out = eng.run()
        wall = time.perf_counter() - t0
        return out, wall, eng.stats

    solo_out, solo_wall, s0 = measure(None)
    devs = np.array(jax.devices()[:model_parallel]).reshape(
        1, model_parallel)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    shard_out, shard_wall, s1 = measure(mesh)

    sched = lambda s: (s.preemptions, s.admissions, s.cached_prompt_tokens,
                       s.prefill_tokens, s.generated_tokens)
    per = lambda s, wall: {
        "decode_tokens_per_s": s.generated_tokens / max(wall, 1e-9),
        "prefill_tokens_per_s": s.prefill_tokens / max(wall, 1e-9),
        "wall_s": wall,
    }
    return {
        "devices": len(jax.devices()),
        "model_parallel": model_parallel,
        "requests": requests,
        "kv_dtype": kv_dtype,
        "single": per(s0, solo_wall),
        "sharded": per(s1, shard_wall),
        "greedy_identical": solo_out == shard_out,
        "stats_identical": sched(s0) == sched(s1),
        "note": "CPU interpret-path timing — parity evidence, not a "
                "speedup claim (model-axis speedup needs real devices)",
    }


def main(argv=None):
    args = _parse_args(argv)
    _force_devices(args.model_parallel)
    rec = run(model_parallel=args.model_parallel, requests=args.requests,
              max_new=args.max_new, kv_dtype=args.kv_dtype)
    print(json.dumps(rec))
    return 0 if rec["greedy_identical"] and rec["stats_identical"] else 1


if __name__ == "__main__":
    sys.exit(main())
