"""Paper Fig 7: chip size vs TCO (GPT-3) — small dies win on cost."""
from __future__ import annotations

from collections import defaultdict

from benchmarks.common import Row, servers, timed
from repro.core import perf
from repro.core.workloads import PAPER_MODELS


def run() -> list[Row]:
    wl = PAPER_MODELS["gpt3-175b"]

    def work():
        best_by_die = {}
        for s in servers():
            dp = perf.best_mapping(s, wl, ctx=2048, batches=(32, 64, 128, 256))
            if dp is None:
                continue
            die = s.chip.die_mm2
            if die not in best_by_die or \
                    dp.tco_per_mtoken < best_by_die[die].tco_per_mtoken:
                best_by_die[die] = dp
        return best_by_die

    best, us = timed(work)
    rows: list[Row] = []
    base = min(d.tco_per_mtoken for d in best.values())
    for die in sorted(best):
        dp = best[die]
        rows.append((f"fig7/die_{die}mm2", us / max(len(best), 1),
                     f"tco_per_mtoken={dp.tco_per_mtoken:.4f};"
                     f"rel={dp.tco_per_mtoken / base:.2f}"))
    # Paper: ~200mm2 beats >700mm2 by ~2.2x.
    big = [d for d in best if d >= 700]
    small = [d for d in best if 100 <= d <= 240]
    if big and small:
        ratio = min(best[d].tco_per_mtoken for d in big) / \
            min(best[d].tco_per_mtoken for d in small)
        rows.append(("fig7/big_vs_small_ratio", 0.0,
                     f"ratio={ratio:.2f};paper=2.2"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
