"""Shared helpers for the benchmark harness.

Each benchmark module exposes ``run() -> list[tuple[name, value, derived]]``
mirroring one table/figure of the paper; ``benchmarks.run`` executes all of
them and prints ``name,us_per_call,derived`` CSV (us_per_call is the
wall-time of producing the row; derived carries the figure's metric).
"""
from __future__ import annotations

import functools
import time
from typing import List, Tuple

Row = Tuple[str, float, str]


@functools.lru_cache(maxsize=1)
def servers():
    from repro.core import explore
    return tuple(explore.phase1_servers())


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# GPU/TPU baselines for Figs 10-12 (documented public constants, 2023).
A100_TOKENS_PER_S_GPT3 = 18.0        # DeepSpeed-Inference [3]
A100_RENT_PER_HR = 1.10              # Lambda cloud [26]
TPUV4_RENT_PER_HR = 3.22             # GCP on-demand [10]
PALM_TOKENS_PER_S_PER_TPU = 60.0     # Pope et al [37], throughput-optimal
# "Fabricated" (owned) baselines: the paper's Fig 11 reports that owning
# the chip saves 12.7x (GPU) / 12.4x (TPU) vs renting under its TCO model
# (which, as the paper notes, still under-counts liquid cooling + advanced
# packaging).  We apply those factors to the rented baselines rather than
# invent a BoM for hardware we can't cost.
GPU_OWNED_SAVINGS = 12.7
TPU_OWNED_SAVINGS = 12.4
