"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--only <prefix>`` filters
modules.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "benchmarks.table2_designs",
    "benchmarks.fig7_chipsize",
    "benchmarks.fig8_batch",
    "benchmarks.fig9_pipeline",
    "benchmarks.fig10_12_compare",
    "benchmarks.fig12_tpu_batch",
    "benchmarks.fig13_sparsity",
    "benchmarks.fig14_flexibility",
    "benchmarks.fig15_nre",
    "benchmarks.roofline",
    "benchmarks.kernels_bench",
    "benchmarks.serving_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failures = 0
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{modname},0,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
