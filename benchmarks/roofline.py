"""Roofline report: reads the dry-run JSONs and emits the §Roofline table.

One row per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS / HLO_FLOPS, and memory-fit evidence.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks.common import Row

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

HBM_PER_CHIP = 16e9  # TPU v5e


def load_cells(mesh: str = "single"):
    cells = []
    for f in sorted(glob.glob(str(DRYRUN_DIR / f"*__{mesh}.json"))):
        cells.append(json.load(open(f)))
    return cells


def run() -> list[Row]:
    rows: list[Row] = []
    for mesh in ("single", "multi"):
        n_ok = n_skip = 0
        for d in load_cells(mesh):
            name = f"roofline/{d['arch']}/{d['shape']}/{mesh}"
            if d["status"] == "skipped":
                n_skip += 1
                rows.append((name, 0.0, "skipped=" + d["reason"][:40]))
                continue
            if d["status"] != "ok":
                rows.append((name, 0.0, "ERROR"))
                continue
            n_ok += 1
            r = d["roofline"]
            mem = d.get("memory_analysis") or {}
            tmp = (mem.get("temp_size_in_bytes") or 0) / 1e9
            args = (mem.get("argument_size_in_bytes") or 0) / 1e9
            fits = (tmp + args) <= HBM_PER_CHIP / 1e9
            useful = d.get("useful_flops_ratio") or 0.0
            rows.append((
                name, d.get("total_s", 0) * 1e6,
                f"t_comp={r['t_compute_s']:.3e};t_mem={r['t_memory_s']:.3e};"
                f"t_coll={r['t_collective_s']:.3e};"
                f"bound={r['bottleneck']};useful={useful:.2f};"
                f"mem_gb={tmp + args:.1f};fits={fits}"))
        rows.append((f"roofline/summary/{mesh}", 0.0,
                     f"ok={n_ok};skipped={n_skip}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
