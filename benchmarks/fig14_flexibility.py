"""Paper Fig 14: chip flexibility — a chip optimized for model A serving
model B costs 1.1-1.5x the model-optimized TCO; a multi-model chip averages
~1.16x."""
from __future__ import annotations

import math

from benchmarks.common import Row, servers, timed
from repro.core import explore, perf
from repro.core.workloads import PAPER_MODELS

MODELS = ["llama2-70b", "gopher-280b", "gpt3-175b"]


def run() -> list[Row]:
    srv = servers()
    rows: list[Row] = []

    def work():
        opt = {m: explore.phase2(srv, PAPER_MODELS[m], ctx=2048,
                                 keep_all=False).best for m in MODELS}
        cross = {}
        for a in MODELS:  # chip optimized for a ...
            for b in MODELS:  # ... serving b (scale-out allowed)
                dp = perf.best_mapping(opt[a].server, PAPER_MODELS[b],
                                       ctx=2048)
                cross[(a, b)] = dp.tco_per_mtoken if dp else None
        return opt, cross

    (opt, cross), us = timed(work)
    n = 0
    for a in MODELS:
        for b in MODELS:
            v = cross[(a, b)]
            rel = v / opt[b].tco_per_mtoken if v else float("nan")
            rows.append((f"fig14/chip_{a}/model_{b}", us / 9,
                         f"rel_tco={rel:.2f};paper_range=1.0-1.5"))
            n += 1

    def work2():
        wls = [PAPER_MODELS[m] for m in MODELS]
        # Multi-model chip: geomean objective over a subsampled server list
        # (full sweep x all models is minutes; stride keeps it representative)
        _, geo, pts = explore.multi_model_optimum(wls, ctx=2048,
                                                  servers=srv[::7])
        rel = [p.tco_per_mtoken / opt[m].tco_per_mtoken
               for m, p in zip(MODELS, pts)]
        return math.exp(sum(map(math.log, rel)) / len(rel))

    avg_rel, us2 = timed(work2)
    rows.append(("fig14/multi_model_geomean_overhead", us2,
                 f"rel_tco={avg_rel:.2f};paper=1.16"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
