"""Paper Fig 8: optimal TCO/token vs batch size — MHA models peak at 32-256;
MQA/GQA models stay near-optimal through batch 1024."""
from __future__ import annotations

from benchmarks.common import Row, servers, timed
from repro.core import explore
from repro.core.workloads import PAPER_MODELS

MODELS = ["gpt3-175b", "mt-nlg-530b", "palm-540b", "llama2-70b"]
BATCHES = (1, 4, 16, 64, 128, 256, 1024)


def run() -> list[Row]:
    rows: list[Row] = []
    srv = servers()
    for name in MODELS:
        wl = PAPER_MODELS[name]

        def work():
            out = {}
            for b in BATCHES:
                try:
                    res = explore.phase2(srv, wl, ctx=2048, batches=(b,),
                                         keep_all=False)
                    out[b] = res.best.tco_per_mtoken
                except RuntimeError:
                    out[b] = None
            return out

        curve, us = timed(work)
        feas = {b: v for b, v in curve.items() if v}
        best_b = min(feas, key=feas.get)
        for b, v in curve.items():
            rows.append((f"fig8/{name}/batch_{b}", us / len(BATCHES),
                         f"tco_per_mtoken={v if v else 'infeasible'}"))
        kv = "mqa_gqa" if wl.kv_heads < wl.num_heads else "mha"
        rows.append((f"fig8/{name}/optimal_batch", 0.0,
                     f"batch={best_b};kv={kv}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
