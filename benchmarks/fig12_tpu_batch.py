"""Paper Fig 12: Chiplet Cloud vs TPU v4 across batch sizes.

Chiplet Cloud's high-bandwidth CC-MEM wins most at SMALL batch (low
operational intensity); the paper reports up to 3.7x TCO/token at batch 4.
The TPU side uses the same TCO machinery fed with TPUv4-like constants
(HBM-bound decode throughput model), as the paper does with its own model.
"""
from __future__ import annotations

from benchmarks.common import Row, servers, timed
from repro.core import explore, perf, tco
from repro.core.hardware import ChipConfig, ServerConfig
from repro.core.workloads import PAPER_MODELS

# TPUv4-like chip through our cost model: 275 TF bf16, 1.2 TB/s HBM, 780mm2.
TPU_LIKE = ChipConfig(die_mm2=780.0, sram_mb=144.0, tflops=275.0,
                      bw_ratio=1.0)
# Costs the CC servers don't have (documented assumptions): HBM2e stacks
# (~$15/GB x 32 GB), silicon-interposer packaging, host/OCS share.  The
# paper makes the same point qualitatively (its model "does not include
# liquid cooling and advanced packaging, which are critical for TPUs").
TPU_EXTRA_CAPEX_PER_CHIP = 480.0 + 150.0 + 250.0


def _tpu_tco_per_mtoken(wl, batch: int, ctx: int) -> float:
    """Decode on an HBM machine: weights re-streamed per token from HBM at
    1.2 TB/s (not SRAM), batch amortizes weight reads."""
    hbm_bw = 1.2e12
    chips = 64
    w_bytes = wl.params * 2.0
    t_token = max(
        w_bytes / (chips * hbm_bw),  # stream weights once per microbatch
        2.0 * wl.active * batch / (chips * 275e12 * 0.4),
    ) / max(batch, 1)
    server = ServerConfig(chip=TPU_LIKE, chips_per_lane=1, lanes=8)
    extra_rate = TPU_EXTRA_CAPEX_PER_CHIP * chips / (
        tco.SERVER_LIFE_YEARS * tco.SECONDS_PER_YEAR)
    rate = tco.server_tco(server).rate * (chips / 8) + extra_rate
    tokens_per_s = 1.0 / t_token
    return rate / tokens_per_s * 1e6


def run() -> list[Row]:
    wl = PAPER_MODELS["palm-540b"]
    srv = servers()
    rows: list[Row] = []
    for batch in (1, 4, 16, 64, 256):
        def work():
            try:
                res = explore.phase2(srv, wl, ctx=2048, batches=(batch,),
                                     keep_all=False)
                cc = res.best.tco_per_mtoken
            except RuntimeError:
                return None
            return cc

        cc, us = timed(work)
        if cc is None:
            rows.append((f"fig12/batch_{batch}", us, "infeasible"))
            continue
        tpu = _tpu_tco_per_mtoken(wl, batch, 2048)
        rows.append((f"fig12/batch_{batch}", us,
                     f"improvement={tpu / cc:.1f}x;cc={cc:.3f};tpu={tpu:.3f}"))
    rows.append(("fig12/note", 0.0,
                 "paper: up to 3.7x at batch 4, advantage shrinks at large "
                 "batch"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
