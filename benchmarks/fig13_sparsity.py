"""Paper Fig 13: SCLD sparsity — TCO/token + perplexity vs sparsity, and
max supported model scale (1.7x at 60%)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, timed
from repro.core import hardware, perf, sparsity
from repro.core.workloads import PAPER_MODELS


def run() -> list[Row]:
    wl = PAPER_MODELS["gpt3-175b"]  # OPT-175B-shaped
    chip = hardware.ChipConfig(die_mm2=140, sram_mb=226, tflops=5.5)
    server = hardware.ServerConfig(chip=chip, chips_per_lane=17)

    def work():
        out = {}
        for s in (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8):
            w = dataclasses.replace(
                wl, weight_storage_factor=sparsity.storage_factor(s))
            dp = perf.best_mapping(server, w, ctx=2048,
                                   batches=(32, 64, 128, 256))
            out[s] = dp.tco_per_mtoken if dp else None
        return out

    curve, us = timed(work)
    rows: list[Row] = []
    base = curve[0.0]
    for s, v in curve.items():
        ppl = sparsity.OPT175B_PERPLEXITY.get(round(s, 1))
        delta = (v - base) / base * 100 if v else float("nan")
        rows.append((f"fig13/sparsity_{int(s*100)}", us / len(curve),
                     f"tco_delta_pct={delta:+.1f};perplexity={ppl}"))
    rows.append(("fig13/model_scale_at_60pct", 0.0,
                 f"scale={sparsity.max_model_scale(0.6):.2f}x;paper=1.7x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
