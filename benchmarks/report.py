"""Generate the EXPERIMENTS.md roofline tables from dry-run JSON dirs.

    PYTHONPATH=src python -m benchmarks.report [--update]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def table(dirname: str, mesh: str) -> str:
    rows = []
    for f in sorted(glob.glob(str(ROOT / "experiments" / dirname /
                                  f"*__{mesh}.json"))):
        d = json.load(open(f))
        if d["status"] == "skipped":
            rows.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | "
                        f"skip | — | {d['reason'][:42]} |")
            continue
        if d["status"] != "ok":
            rows.append(f"| {d['arch']} | {d['shape']} | ERROR |||||||")
            continue
        r = d["roofline"]
        mem = d.get("memory_analysis") or {}
        gb = ((mem.get("temp_size_in_bytes") or 0)
              + (mem.get("argument_size_in_bytes") or 0)) / 1e9
        u = d.get("useful_flops_ratio") or 0
        rows.append(
            f"| {d['arch']} | {d['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['bottleneck']} | {u:.2f} | {gb:.1f} | |")
    head = ("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
            " | bound | useful | GB/chip | note |\n"
            "|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    print(table(args.dir, args.mesh))


if __name__ == "__main__":
    main()
