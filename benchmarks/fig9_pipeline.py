"""Paper Fig 9: pipeline-stage sweep — p close to batch N maximizes
utilization and TCO/token."""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import hardware, perf
from repro.core.workloads import PAPER_MODELS


def run() -> list[Row]:
    wl = PAPER_MODELS["gpt3-175b"]
    chip = hardware.ChipConfig(die_mm2=140, sram_mb=226, tflops=5.5)
    server = hardware.ServerConfig(chip=chip, chips_per_lane=17)
    rows: list[Row] = []
    for N in (16, 96):
        def work():
            out = {}
            for p in (1, 2, 4, 8, 16, 32, 48, 96):
                grid = [perf.Mapping(tp=server.num_chips, pp=p, batch=N,
                                     microbatches=n)
                        for n in (1, 2, 4, 8, 16, 32, 96) if n <= N]
                res = [r for r in perf.evaluate_grid(server, wl, 2048, grid)
                       if r]
                if res:
                    best = max(res, key=lambda r: r.tokens_per_s_per_chip)
                    out[p] = best.tokens_per_s_per_chip
            return out

        curve, us = timed(work)
        best_p = max(curve, key=curve.get)
        for p, v in curve.items():
            rows.append((f"fig9/batch{N}/pp_{p}", us / len(curve),
                         f"tokens_s_chip={v:.3f}"))
        rows.append((f"fig9/batch{N}/best_pp", 0.0,
                     f"pp={best_p};paper=close_to_batch"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
