"""Paper Fig 15: NRE break-even — required TCO/token improvement to justify
the $35M NRE at a given annual spend."""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import tco

CHATGPT_ANNUAL_TCO = 255e6  # [31], $/year on GPUs


def run() -> list[Row]:
    def work():
        out = {}
        for annual in (1e6, 10e6, 100e6, CHATGPT_ANNUAL_TCO, 1e9):
            # Break-even: savings over server life must cover NRE.
            years = tco.SECONDS_PER_YEAR and 1.5
            required = 1.0 / (1.0 - tco.NRE_TOTAL / (annual * years)) \
                if annual * years > tco.NRE_TOTAL else float("inf")
            out[annual] = required
        return out

    curve, us = timed(work)
    rows: list[Row] = []
    for annual, req in curve.items():
        rows.append((f"fig15/annual_spend_{annual:.0e}", us / len(curve),
                     f"required_improvement={req:.3f}x"))
    # Paper: ChatGPT at $255M/yr needs only 1.14x improvement east of NRE.
    rows.append(("fig15/chatgpt_breakeven", 0.0,
                 f"required={curve[CHATGPT_ANNUAL_TCO]:.2f}x;paper=1.14x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
