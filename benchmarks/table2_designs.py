"""Paper Table 2: TCO/token-optimal Chiplet Cloud designs per LLM."""
from __future__ import annotations

import json

from benchmarks.common import Row, servers, timed
from repro.core import explore
from repro.core.workloads import PAPER_MODELS

# Paper Table 2 reference values ($ per 1M tokens) for the report.
PAPER_TCO = {
    "gpt2-1.5b": 0.001, "megatron-8.3b": 0.008, "gpt3-175b": 0.161,
    "gopher-280b": 0.228, "mt-nlg-530b": 0.521, "bloom-176b": 0.141,
    "palm-540b": 0.245, "llama2-70b": 0.046,
}


def run() -> list[Row]:
    rows: list[Row] = []
    srv = servers()
    for name, wl in PAPER_MODELS.items():
        def work():
            return explore.phase2(srv, wl, ctx=2048, keep_all=False)
        res, us = timed(work)
        row = res.best.table_row()
        derived = (f"tco_per_mtoken={row['tco_per_mtoken']:.4f};"
                   f"paper={PAPER_TCO[name]};die={row['die_mm2']};"
                   f"mb={row['mb_per_chip']};tf={row['tflops_per_chip']};"
                   f"chips={row['chips_per_server']}x{row['num_servers']};"
                   f"batch={row['batch']}")
        rows.append((f"table2/{name}", us, derived))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
