"""Per-key delta report between two BENCH_serving.json artifacts.

CI runs this as a NON-BLOCKING report step after the smoke bench: the
committed artifact (the baseline the repo ships) next to the fresh run,
so a PR's perf movement is visible in the job log without gating merges
on CPU-runner timing noise.  Numeric leaves print old -> new with the
absolute and relative delta; non-numeric leaves print only when they
changed.

Artifact versions drift across PRs — a new bench section lands, an old
one is renamed — so keys present on one side only must never crash the
report or drown it: a top-level section present on only ONE side is
collapsed to a single ``(section added/removed: N keys)`` line instead
of one line per leaf, and stray added/removed leaves inside shared
sections are listed individually.

The report ends with a ONE-LINE regression summary classifying every
changed numeric leaf by metric direction (higher-is-better:
``tokens_per_s`` / ``goodput`` / ``hit_rate`` / ``acceptance_rate`` /
``concurrency`` / ``speedup`` / ``availability``; lower-is-better:
``ttft`` / ``itl`` /
other ``*_s`` latencies — SLO *configs* and counters are skipped), e.g.

  bench_diff summary: 7 improved, 2 regressed (worst: open_loop.moderate.client_p99_ttft_s +41.3%), 5 other changes

  PYTHONPATH=src python -m benchmarks.bench_diff BENCH_serving.json /tmp/fresh.json
"""
from __future__ import annotations

import argparse
import json


def _leaves(node, prefix=""):
    """Flatten nested dicts to {dotted.path: leaf} (lists are leaves)."""
    if isinstance(node, dict):
        out = {}
        for k, v in node.items():
            out.update(_leaves(v, f"{prefix}.{k}" if prefix else str(k)))
        return out
    return {prefix: node}


def _is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


# Metric-direction heuristics for the regression summary.  Checked in
# order: a throughput rate like "goodput_req_s" is higher-is-better even
# though it ends in "_s".
_HIGHER = ("tokens_per_s", "goodput", "hit_rate", "acceptance_rate",
           "concurrency", "speedup", "availability")
_LOWER = ("ttft", "itl")


def _direction(path: str):
    """'higher' / 'lower' for perf-relevant leaves, None for the rest
    (counters, configs, SLO targets)."""
    leaf = path.rsplit(".", 1)[-1]
    if leaf.startswith("slo_"):
        return None  # the SLO target is config, not a measurement
    if any(t in path for t in _HIGHER):
        return "higher"
    if any(t in path for t in _LOWER) or leaf.endswith("_s"):
        return "lower"
    return None


def diff_report(old: dict, new: dict) -> tuple[list[str], str]:
    """(per-leaf lines sorted by path, one-line regression summary).

    Whole sections (top-level keys) present on one side only collapse to
    a single added/removed line; their leaves never enter the summary —
    a section that didn't exist in the baseline cannot have regressed.
    """
    a, b = _leaves(old), _leaves(new)
    removed_secs = {k for k in old if isinstance(old, dict)} - set(new)
    added_secs = {k for k in new if isinstance(new, dict)} - set(old)
    lines = []
    improved, regressed, other = [], [], 0
    for sec in sorted(removed_secs):
        n = sum(1 for p in a if p == sec or p.startswith(sec + "."))
        lines.append(f"- {sec}.* (section removed: {n} keys)")
    for sec in sorted(added_secs):
        n = sum(1 for p in b if p == sec or p.startswith(sec + "."))
        lines.append(f"+ {sec}.* (section added: {n} keys)")

    def in_lone_section(path):
        top = path.split(".", 1)[0]
        return top in removed_secs or top in added_secs

    for path in sorted(a.keys() | b.keys()):
        if in_lone_section(path):
            continue
        if path not in b:
            lines.append(f"- {path}: {a[path]!r} (removed)")
            other += 1
        elif path not in a:
            lines.append(f"+ {path}: {b[path]!r} (added)")
            other += 1
        elif _is_num(a[path]) and _is_num(b[path]):
            o, n = a[path], b[path]
            if o == n:
                continue
            rel = f" ({(n - o) / o:+.1%})" if o else ""
            lines.append(f"~ {path}: {o:g} -> {n:g} [{n - o:+g}]{rel}")
            d = _direction(path)
            if d is None or not o:
                other += 1
                continue
            better = (n > o) if d == "higher" else (n < o)
            frac = abs(n - o) / abs(o)
            (improved if better else regressed).append((frac, path, o, n))
        elif a[path] != b[path]:
            lines.append(f"~ {path}: {a[path]!r} -> {b[path]!r}")
            other += 1

    if not (improved or regressed or other):
        summary = "bench_diff summary: no perf-relevant movement"
    elif regressed:
        frac, path, o, n = max(regressed)
        sign = "+" if n > o else "-"
        summary = (f"bench_diff summary: {len(improved)} improved, "
                   f"{len(regressed)} regressed "
                   f"(worst: {path} {sign}{frac:.1%}), "
                   f"{other} other changes")
    else:
        summary = (f"bench_diff summary: {len(improved)} improved, "
                   f"0 regressed, {other} other changes")
    return lines, summary


def diff_lines(old: dict, new: dict) -> list[str]:
    """Back-compat wrapper: just the per-leaf lines."""
    return diff_report(old, new)[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("old", help="baseline artifact (e.g. the committed "
                                "BENCH_serving.json)")
    ap.add_argument("new", help="fresh artifact (e.g. this run's --json)")
    args = ap.parse_args()
    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    lines, summary = diff_report(old, new)
    if not lines:
        print("bench_diff: no differences")
        return
    print(f"bench_diff: {len(lines)} differing keys "
          f"({args.old} -> {args.new})")
    for line in lines:
        print(f"  {line}")
    print(summary)


if __name__ == "__main__":
    main()
