"""Per-key delta report between two BENCH_serving.json artifacts.

CI runs this as a NON-BLOCKING report step after the smoke bench: the
committed artifact (the baseline the repo ships) next to the fresh run,
so a PR's perf movement is visible in the job log without gating merges
on CPU-runner timing noise.  Numeric leaves print old -> new with the
absolute and relative delta; non-numeric leaves print only when they
changed; keys present on one side only are listed as added/removed.

  PYTHONPATH=src python -m benchmarks.bench_diff BENCH_serving.json /tmp/fresh.json
"""
from __future__ import annotations

import argparse
import json


def _leaves(node, prefix=""):
    """Flatten nested dicts to {dotted.path: leaf} (lists are leaves)."""
    if isinstance(node, dict):
        out = {}
        for k, v in node.items():
            out.update(_leaves(v, f"{prefix}.{k}" if prefix else str(k)))
        return out
    return {prefix: node}


def _is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def diff_lines(old: dict, new: dict) -> list[str]:
    """One line per changed/added/removed leaf, sorted by path."""
    a, b = _leaves(old), _leaves(new)
    lines = []
    for path in sorted(a.keys() | b.keys()):
        if path not in b:
            lines.append(f"- {path}: {a[path]!r} (removed)")
        elif path not in a:
            lines.append(f"+ {path}: {b[path]!r} (added)")
        elif _is_num(a[path]) and _is_num(b[path]):
            o, n = a[path], b[path]
            if o == n:
                continue
            rel = f" ({(n - o) / o:+.1%})" if o else ""
            lines.append(f"~ {path}: {o:g} -> {n:g} [{n - o:+g}]{rel}")
        elif a[path] != b[path]:
            lines.append(f"~ {path}: {a[path]!r} -> {b[path]!r}")
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("old", help="baseline artifact (e.g. the committed "
                                "BENCH_serving.json)")
    ap.add_argument("new", help="fresh artifact (e.g. this run's --json)")
    args = ap.parse_args()
    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    lines = diff_lines(old, new)
    if not lines:
        print("bench_diff: no differences")
        return
    print(f"bench_diff: {len(lines)} differing keys "
          f"({args.old} -> {args.new})")
    for line in lines:
        print(f"  {line}")


if __name__ == "__main__":
    main()
