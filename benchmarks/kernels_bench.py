"""Kernel micro-benchmarks: wall time of the jnp oracles on CPU (the Pallas
kernels themselves target TPU; interpret-mode timing is not meaningful) plus
SCLD traffic accounting derived from the compression format."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.sclad_matmul.sclad_matmul import (
    TILE, UNIT_R, UNITS_PER_TILE, block_compress)
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def _time(fn, iters=3):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[Row]:
    rows: list[Row] = []
    ks = jax.random.split(jax.random.PRNGKey(0), 4)

    B, S, H, Hk, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, D), jnp.float32)
    f = jax.jit(lambda: attention_ref(q, k, v))
    us = _time(f)
    flops = 4 * B * S * S * H * D * 0.5
    rows.append(("kernels/flash_attention_ref_1k", us,
                 f"gflops_s={flops / us / 1e3:.1f}"))

    qd = q[:, 0]
    fd = jax.jit(lambda: decode_ref(qd, k, v, jnp.int32(S)))
    us = _time(fd)
    rows.append(("kernels/flash_decode_ref_1k", us,
                 f"kv_gb_s={2 * B * S * Hk * D * 4 / us / 1e3:.2f}"))

    BH, Sq, P, N = 8, 512, 64, 64
    xdt = jax.random.normal(ks[3], (BH, Sq, P), jnp.float32) * 0.1
    a = -jnp.abs(jax.random.normal(ks[0], (BH, Sq))) * 0.1
    bb = jax.random.normal(ks[1], (BH, Sq, N)) * 0.3
    cc = jax.random.normal(ks[2], (BH, Sq, N)) * 0.3
    fs = jax.jit(lambda: ssd_scan_ref(xdt, a, bb, cc)[0])
    us = _time(fs)
    rows.append(("kernels/ssd_scan_ref", us, f"tokens_s={BH * Sq / us * 1e6:.0f}"))

    # SCLD traffic accounting (store-compressed -> load-dense savings).
    wname = np.random.default_rng(0).standard_normal((1024, 1024)).astype(
        np.float32)
    for C in (16, 8, 6, 4):
        vals, rowsi = block_compress(wname, C)
        dense = wname.size * 2
        stored = vals.size * 2 + rowsi.size * 4
        rows.append((f"kernels/sclad_traffic_C{C}", 0.0,
                     f"sparsity={1 - C / UNITS_PER_TILE:.2f};"
                     f"bytes_ratio={stored / dense:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
