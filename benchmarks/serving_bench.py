"""Serving engine: continuous batching vs. the wave-batching baseline.

Runs the same multi-tenant trace (mixed prompt lengths, mixed completion
budgets) through both scheduler modes of ``serving.engine.ServingEngine``
on a tiny CPU config and reports decode tokens/s and slot occupancy —
the generate-stage utilization gap the paper's batching analysis (§4.2,
Fig 6/8) prices into TCO/token.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import EngineStats, ServingEngine

ARCH = "tinyllama-1.1b"
N_REQUESTS = 16
MAX_BATCH = 4
MAX_LEN = 64


def _trace(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 25))),
             int(rng.integers(4, 17))) for _ in range(N_REQUESTS)]


def _run_mode(cfg, params, reqs, mode) -> EngineStats:
    eng = ServingEngine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                        eos_id=-1, mode=mode)
    # Warm-up pass compiles the prefill buckets and the decode step so the
    # measured pass times steady-state scheduling, not XLA compiles.
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    eng.run()
    eng.stats = EngineStats()
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    out = eng.run()
    assert len(out) == len(reqs)
    return eng.stats


def run() -> list[Row]:
    cfg = get_config(ARCH).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _trace(cfg)
    rows: list[Row] = []
    stats = {}
    for mode in ("wave", "continuous"):
        s = _run_mode(cfg, params, reqs, mode)
        stats[mode] = s
        rows.append((f"serving/{mode}/tokens_per_s", s.decode_s * 1e6,
                     f"tok_s={s.tokens_per_s:.1f}"))
        rows.append((f"serving/{mode}/slot_occupancy", 0.0,
                     f"occupancy={s.slot_occupancy:.3f}"))
    speedup = stats["continuous"].tokens_per_s / \
        max(stats["wave"].tokens_per_s, 1e-9)
    rows.append(("serving/continuous_vs_wave", 0.0,
                 f"speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
