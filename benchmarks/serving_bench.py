"""Serving engine: paged KV + prefix caching + preemption vs slots vs waves.

Two multi-tenant traces through ``serving.engine.ServingEngine`` on a tiny
CPU config:

1. MIXED trace (long/short prompts, mixed budgets) through three scheduler
   configurations:
     * ``wave``  — the seed's lockstep wave batcher (baseline of PR 1);
     * ``slot``  — continuous batching with stripe-equivalent blocks
       (``block_size = max_len``: every request holds one full stripe);
     * ``paged`` — small blocks + chunked prefill on the SAME KV token
       budget but more lanes.
   Greedy outputs are asserted identical between slot and paged.

2. SHARED-PREFIX trace (one system prompt + short unique tails — the
   dominant traffic shape at "millions of users" scale) through the paged
   engine with the prefix cache OFF vs ON at the SAME ``num_blocks``:
   blocks holding the shared prompt are ref-counted and shared, so
   admission packs >= 1.2x more concurrent requests into the same pool and
   skips the shared prefill compute (reported as the prefix hit-rate).
   Outputs are asserted bit-identical ON vs OFF.

3. PREEMPTION probe: the same requests through an over-committed pool
   (optimistic admission, no reservation) vs an ample one — preempted
   requests are re-queued and recomputed, and their final outputs are
   asserted identical to the unpressured run.

4. SCLAD probe: the quantized KV pool (PAPER.md §CC-MEM store-as-
   compressed, load-as-dense) at a FIXED pool byte budget — the fp-exact
   bf16 pool next to an int8+scales pool holding the same number of
   device bytes (so more blocks).  Run on a head_dim=64 variant of the
   probe config (the full-model ratio: 128 B vs 68 B per token-head,
   1.88x; the reduced head_dim=16 would undersell it at 1.6x).  The
   compressed pool must admit >= 1.8x the concurrent requests before the
   first preemption, with ZERO divergent greedy tokens vs the fp run on
   this trace (the bench-side half of the quantization quality gate; the
   logit-error half lives in tests/test_kv_quant.py).

5. ATTN-KERNEL probe: the paged engine with the Pallas kernels (paged
   flash-decode AND paged flash-prefill with its fused K/V scatter)
   forced on (interpret mode on CPU — the parity path, NOT a speed
   claim) next to the jnp gather references.  Under the kernels the
   scheduler must stay bit-transparent (prefix cache on vs off asserted
   identical); kernel-vs-reference itself is a tolerance property owned
   by tests/test_kernels.py (fp32 online softmax vs bf16 two-pass).
   Prefill tok/s and mean TTFT (submit -> first token) are reported for
   both implementations so the prefill-side trajectory is visible next
   to the decode numbers.

6. OPEN-LOOP probes (the service posture of the paper's cloud-scale
   premise): Poisson arrivals through ``serving.frontend.AsyncFrontend``
   — requests arrive on a clock that does not wait for the scheduler and
   stream their tokens back, so the report is CLIENT-side tail latency
   (p50/p99 TTFT including admission queueing, p50/p99 inter-token gap)
   and goodput-under-SLO, next to reject/shed counts.  Two rates:
     * ``moderate`` — an arrival rate the engine absorbs: breaker stays
       closed, nothing shed, and every completed stream is asserted
       bit-identical to the same requests through closed-loop
       ``engine.run()`` (the frontend adds admission, not arithmetic);
     * ``saturating`` — a deliberate overload burst against a tight pool
       (optimistic admission preempts, pool saturates) followed by a
       late tail: the breaker must OPEN during the burst and SHED tail
       arrivals, while the requests it did admit still finish
       bit-identical to ``run()``.  (The full closed->open->half_open->
       closed recovery walk is pinned in tests/test_frontend.py; here
       the artifact records opens/sheds/transitions.)

7. SPECULATIVE-DECODING probe: the paged engine with ``spec_decode=
   "ngram"`` (draft from the request's own history -> verify the whole
   chunk in ONE flash-prefill pass -> roll rejected K/V back with
   ``BlockStore.truncate``) vs plain decode on two traces:
     * ``repetitive`` — greedy with a generous budget over prompts
       screened so the tiny random-init model locks into a short output
       cycle within a few tokens: exactly the repetitive/structured
       shape n-gram drafting wins on.  Outputs are asserted
       bit-identical to spec-off and per-request decode tok/s must
       improve >= 1.3x;
     * ``random`` — stochastic sampling over random prompts: drafts
       almost never match a temperature sample, so acceptance ~0 and the
       probe documents the neutral-to-slight-loss floor (outputs still
       asserted bit-identical — the verify pass re-samples each position
       with its positional key, so randomness never skews).

8. SCALE-OUT probe (PR 9): both rungs of the scale ladder.
     * sharded dispatch — a SUBPROCESS (``benchmarks.sharded_probe``)
       forces 2 host devices, shards the paged KV pool's kv-head axis
       over the "model" mesh axis via shard_map, and asserts greedy
       tokens + scheduler stats identical to the meshless engine
       (float32 params; bf16 TP psum noise flips greedy near-ties);
     * replica router — a shared-system-prompt open-loop trace through
       2 ``ReplicaRouter`` replicas: prefix-affinity placement must beat
       round-robin on aggregate prefix hit-rate (asserted — affinity
       pays ONE cold shared prefill, round-robin one per replica),
       completed streams are asserted bit-identical to a solo engine's
       closed-loop ``run()``, and aggregate goodput is reported next to
       a 1-replica baseline.

9. FAULT-TOLERANCE probe (PR 10): the same open-loop trace through a
   3-replica fleet twice — clean, then with replica 0 wrapped in a
   deterministic ``FaultPlan`` that crashes it mid-decode.  The router's
   health tracker must declare the replica dead and fail its in-flight
   requests over (resubmitted as prompt + already-emitted tokens, the
   preemption-recompute path), so the chaos run is asserted to keep
   availability at 1.0 with one dead replica AND to produce streams
   bit-identical to the clean run — the failover tripwire CI trips on
   under ``--smoke``.  Reported: availability, goodput under failure vs
   clean, failover count, and the failover p99 TTFT (death -> first
   replacement token) next to the clean/chaos client p99 TTFT delta.

Reported: decode tokens/s, prefill tokens/s, mean TTFT, lane occupancy,
mean concurrent requests, KV token utilization (can exceed 1.0 under
sharing — lanes serve more context than the pool stores), prefix hit-rate
and peak pool bytes — the generate-stage utilization gaps the paper's
batching analysis (§4.2, Fig 6/8) prices into TCO/token.

``--json PATH`` additionally writes the headline numbers as machine-
readable JSON (CI uploads ``BENCH_serving.json`` from the ``--smoke`` run
as an artifact, seeding the perf trajectory across PRs).

``--kv-dtype int8`` (or ``fp8``) rebuilds every engine in traces 1-3 and 5
on a quantized pool: all the bit-identity assertions (slot==paged, prefix
on==off, preemption recompute, kernel bit-transparency) must hold WITHIN
the encoding, and the SCLAD probe's fp-vs-int8 zero-divergence gate runs
regardless — CI uses this as the tripwire against silent quantization
regressions.  ``--spec-decode ngram`` is the same idea for speculation:
every continuous engine in traces 1-3 and 5 runs speculatively, so every
bit-identity assertion doubles as a speculation-regression tripwire (the
spec probe's own on-vs-off gate runs regardless).

Run directly (``--smoke`` keeps it CI-sized):
  PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] [--json PATH]
      [--kv-dtype {fp,int8,fp8}] [--spec-decode {off,ngram}] [--spec-k K]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from dataclasses import replace as dc_replace

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs.base import get_config
from repro.models import kv_quant
from repro.models import model as M
from repro.serving.engine import EngineStats, ServingEngine
from repro.serving.faults import FaultPlan, FaultyEngine
from repro.serving.frontend import CircuitBreaker
from repro.serving.openloop import TraceItem, poisson_trace, run_open_loop
from repro.serving.router import run_open_loop_router
from repro.serving.sampler import SamplerConfig
from repro.serving.spec import SPEC_DECODE_MODES
from repro.serving.warmup import trace_prompt_lens, warmup_prefill

ARCH = "tinyllama-1.1b"
MAX_LEN = 64
# One KV memory budget for the wave/slot/paged comparison: 4 stripes' worth.
KV_BUDGET_TOKENS = 4 * MAX_LEN


def _modes(n_requests):
    return {
        # mode -> ServingEngine kwargs
        "wave": dict(mode="wave", max_batch=4),
        "slot": dict(mode="continuous", max_batch=4, block_size=MAX_LEN,
                     num_blocks=KV_BUDGET_TOKENS // MAX_LEN,
                     prefill_chunk=None),
        # 6 lanes on the same 256-token pool: memory admits ~8 short
        # requests but 6 lanes balance per-step lane cost vs concurrency
        # on CPU.
        "paged": dict(mode="continuous", max_batch=6, block_size=8,
                      num_blocks=KV_BUDGET_TOKENS // 8, prefill_chunk=16),
    }


def _mixed_trace(cfg, n_requests, seed=0):
    """Mixed long/short prompts: the long ones are what strand stripe
    capacity under slot reservation."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        long = i % 4 == 0
        plen = int(rng.integers(33, 48)) if long else int(rng.integers(4, 17))
        reqs.append((rng.integers(1, cfg.vocab_size, size=plen),
                     int(rng.integers(4, 17))))
    return reqs


def _shared_trace(cfg, n_requests, seed=1):
    """One 32-token system prompt + short unique tails + mixed budgets."""
    rng = np.random.default_rng(seed)
    system = rng.integers(1, cfg.vocab_size, size=32)
    reqs = []
    for _ in range(n_requests):
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(4, 9)))
        reqs.append((np.concatenate([system, tail]),
                     int(rng.integers(6, 11))))
    return reqs


def _run_mode(cfg, params, reqs, kwargs):
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1, **kwargs)
    # Warm-up pass compiles the prefill buckets and the decode step so the
    # measured pass times steady-state scheduling, not XLA compiles.  (It
    # also warms the prefix-cache LRU pool, which is exactly the steady
    # state a long-running server sits in.)
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    eng.run()
    eng.stats = EngineStats()
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    results = eng.run()
    assert len(results) == len(reqs)
    return eng.stats, results


def _pool_block_bytes(cfg, block_size):
    """Device bytes ONE pool block occupies across every cache leaf
    (compressed payload + scale metadata for quantized kv_dtypes),
    measured on the allocated layout rather than re-derived."""
    cache = M.init_paged_cache(cfg, 1, block_size)
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in cache.values())


def _open_loop_section(cfg, params, trace, engine_kwargs, breaker,
                       max_queue_depth, slo_ttft_s):
    """One open-loop run + the closed-loop bit-identity cross-check.

    The engine is warmed closed-loop FOR EVERY ADMISSION GROUP SIZE
    first (``serving.warmup.warmup_prefill``, shared with ``launch.serve
    --frontend async``): prefill retraces per (group size, chunk
    bucket), and unlike the closed-loop sections an open-loop arrival
    process admits in groups of any size from 1 up to max_batch
    depending on timing — a group size first seen mid-run would stall a
    scheduler tick on a multi-second XLA compile and wreck both the
    latency distribution and the breaker's tick clock.  Traces here keep
    every prompt (and every preemption-recompute prompt) inside ONE
    chunk bucket, so warming g=1..max_batch covers the whole retrace
    space.  Completed streams
    are then asserted bit-identical to a fresh engine's ``run()`` over
    the same (prompt, budget) set — the frontend must add admission
    control, never arithmetic.
    """
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1,
                        **engine_kwargs)
    # The (group size, chunk bucket) coverage rule lives in ONE place
    # (serving.warmup.trace_prompt_lens) and is shared with
    # ``launch.serve --frontend async`` — see satellite note there.
    warmup_prefill(eng, cfg.vocab_size,
                   prompt_lens=trace_prompt_lens(trace, eng))
    report = run_open_loop(eng, trace, max_queue_depth=max_queue_depth,
                           breaker=breaker)
    # Bit-identity on the non-shed requests vs the in-process run() path.
    ref = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1,
                        **engine_kwargs)
    completed = [(it, rec) for it, rec in zip(trace, report.records)
                 if rec.status == "completed"]
    uids = [ref.submit(it.prompt, max_new_tokens=it.max_new_tokens)
            for it, _ in completed]
    ref_out = ref.run()
    for uid, (it, rec) in zip(uids, completed):
        assert rec.tokens == ref_out[uid], (
            "open-loop stream diverged from closed-loop run() greedy")
    summary = report.summary(slo_ttft_s)
    summary["bit_identical_to_run"] = True
    summary["engine"] = {
        "p50_ttft_s": eng.stats.p50_ttft_s,
        "p99_ttft_s": eng.stats.p99_ttft_s,
        "p50_itl_s": eng.stats.p50_itl_s,
        "p99_itl_s": eng.stats.p99_itl_s,
        "preemptions": eng.stats.preemptions,
        "cancellations": eng.stats.cancellations,
    }
    return report, summary


# Dotted required paths for the BENCH_serving.json artifact, checked
# before every write (and unit-pinned in tests/test_latency_stats.py) so
# a malformed artifact fails the bench instead of uploading silently.
# bool is checked exactly (bool is an int subclass — (int, float) would
# wave booleans through as numbers).
_NUM = (int, float)
BENCH_SCHEMA = [
    ("smoke", bool), ("arch", str), ("max_len", int), ("kv_dtype", str),
    ("decode_tokens_per_s", dict), ("prefill_tokens_per_s", dict),
    ("mean_ttft_s", dict), ("mean_active_requests", dict),
    ("prefix_cache.hit_rate", _NUM),
    ("prefix_cache.concurrency_vs_off_x", _NUM),
    ("preemption.tight_pool_preemptions", int),
    ("sclad.concurrency_vs_fp_x", _NUM),
    ("sclad.greedy_identical_to_fp", bool),
    ("attn_kernel.on_tokens_per_s", _NUM),
    ("attn_kernel.off_tokens_per_s", _NUM),
    ("open_loop.moderate.requests", int),
    ("open_loop.moderate.completed", int),
    ("open_loop.moderate.rejected_backpressure", int),
    ("open_loop.moderate.shed_breaker", int),
    ("open_loop.moderate.client_p50_ttft_s", _NUM),
    ("open_loop.moderate.client_p99_ttft_s", _NUM),
    ("open_loop.moderate.client_p50_itl_s", _NUM),
    ("open_loop.moderate.client_p99_itl_s", _NUM),
    ("open_loop.moderate.goodput.slo_ttft_s", _NUM),
    ("open_loop.moderate.goodput.goodput_req_s", _NUM),
    ("open_loop.moderate.goodput.goodput_tok_s", _NUM),
    ("open_loop.moderate.breaker.opens", int),
    ("open_loop.moderate.breaker.shed", int),
    ("open_loop.moderate.breaker.final_state", str),
    ("open_loop.moderate.bit_identical_to_run", bool),
    ("open_loop.moderate.engine.p99_ttft_s", _NUM),
    ("open_loop.moderate.engine.p99_itl_s", _NUM),
    ("open_loop.saturating.requests", int),
    ("open_loop.saturating.completed", int),
    ("open_loop.saturating.shed_breaker", int),
    ("open_loop.saturating.client_p99_ttft_s", _NUM),
    ("open_loop.saturating.goodput.goodput_req_s", _NUM),
    ("open_loop.saturating.breaker.opens", int),
    ("open_loop.saturating.breaker.shed", int),
    ("open_loop.saturating.breaker.transitions", list),
    ("open_loop.saturating.bit_identical_to_run", bool),
    ("spec_decode.mode", str), ("spec_decode.spec_k", int),
    ("spec_decode.repetitive.acceptance_rate", _NUM),
    ("spec_decode.repetitive.decode_tokens_per_s_on", _NUM),
    ("spec_decode.repetitive.decode_tokens_per_s_off", _NUM),
    ("spec_decode.repetitive.per_request_tokens_per_s_on", _NUM),
    ("spec_decode.repetitive.per_request_tokens_per_s_off", _NUM),
    ("spec_decode.repetitive.speedup_per_request_x", _NUM),
    ("spec_decode.repetitive.outputs_identical", bool),
    ("spec_decode.random.acceptance_rate", _NUM),
    ("spec_decode.random.decode_tokens_per_s_on", _NUM),
    ("spec_decode.random.decode_tokens_per_s_off", _NUM),
    ("spec_decode.random.outputs_identical", bool),
    ("scale_out.sharded.devices", int),
    ("scale_out.sharded.model_parallel", int),
    ("scale_out.sharded.requests", int),
    ("scale_out.sharded.single_decode_tokens_per_s", _NUM),
    ("scale_out.sharded.sharded_decode_tokens_per_s", _NUM),
    ("scale_out.sharded.greedy_identical", bool),
    ("scale_out.sharded.stats_identical", bool),
    ("scale_out.router.replicas", int),
    ("scale_out.router.affinity.prefix_hit_rate", _NUM),
    ("scale_out.router.affinity.affinity_hit_rate", _NUM),
    ("scale_out.router.affinity.per_replica_requests", list),
    ("scale_out.router.affinity.goodput_req_s", _NUM),
    ("scale_out.router.round_robin.prefix_hit_rate", _NUM),
    ("scale_out.router.round_robin.goodput_req_s", _NUM),
    ("scale_out.router.single.goodput_req_s", _NUM),
    ("scale_out.router.streams_identical_to_solo", bool),
    ("fault_tolerance.replicas", int),
    ("fault_tolerance.crash_tick", int),
    ("fault_tolerance.availability", _NUM),
    ("fault_tolerance.replica_deaths", int),
    ("fault_tolerance.failovers", int),
    ("fault_tolerance.outputs_identical_to_clean", bool),
    ("fault_tolerance.clean_goodput_req_s", _NUM),
    ("fault_tolerance.failure_goodput_req_s", _NUM),
    ("fault_tolerance.failover_p99_ttft_s", _NUM),
    ("fault_tolerance.client_p99_ttft_delta_s", _NUM),
]


def validate_bench(bench: dict) -> None:
    """Structural gate on the artifact: every schema path must exist and
    hold the right type, every number must be finite and >= 0 (a NaN
    percentile is a bug upstream, not a value to archive), and rates
    (paths ending ``acceptance_rate`` or ``availability``) must
    additionally be <= 1.  Raises ``ValueError`` listing ALL problems."""
    problems = []
    missing = object()
    for path, typ in BENCH_SCHEMA:
        node = bench
        for key in path.split("."):
            if not isinstance(node, dict) or key not in node:
                node = missing
                break
            node = node[key]
        if node is missing:
            problems.append(f"missing: {path}")
            continue
        if typ is bool or typ is int:
            ok = isinstance(node, typ) and not (
                typ is int and isinstance(node, bool))
        else:
            ok = isinstance(node, typ) and not isinstance(node, bool)
        if not ok:
            problems.append(f"wrong type: {path} = {node!r} (want {typ})")
        elif isinstance(node, _NUM) and not isinstance(node, bool):
            if not np.isfinite(node) or node < 0:
                problems.append(f"non-finite/negative: {path} = {node!r}")
            elif path.endswith(("acceptance_rate", "availability")) \
                    and node > 1:
                problems.append(f"rate > 1: {path} = {node!r}")
    if problems:
        raise ValueError("BENCH_serving.json schema violations:\n  "
                         + "\n  ".join(problems))


def run(smoke: bool = False, json_path: str | None = None,
        kv_dtype: str = "fp", spec_decode: str = "off",
        spec_k: int = 4) -> list[Row]:
    n_requests = 6 if smoke else 16
    cfg = get_config(ARCH).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rows: list[Row] = []
    bench: dict = {"smoke": smoke, "arch": ARCH, "max_len": MAX_LEN,
                   "kv_dtype": kv_dtype}
    # Pool-encoding override threaded into every trace engine ("fp" keeps
    # each config's fp-exact default — identical pools, identical greedy).
    q = {} if kv_dtype == "fp" else {"kv_dtype": kv_dtype}
    # Speculation override, same tripwire idea: "ngram" reruns every
    # continuous engine in traces 1-3 and 5 speculatively, so slot==paged,
    # prefix on==off, preemption recompute and kernel bit-transparency all
    # re-assert UNDER speculation (outputs must not move — the engine's
    # bit-identity contract).  The wave baseline has no spec path.
    if spec_decode != "off":
        q = dict(q, spec_decode=spec_decode, spec_k=spec_k)

    # -- 1. mixed trace: wave vs slot vs paged -------------------------------
    reqs = _mixed_trace(cfg, n_requests)
    stats, outs = {}, {}
    for mode, kwargs in _modes(n_requests).items():
        if mode != "wave":
            kwargs = dict(kwargs, **q)
        s, out = _run_mode(cfg, params, reqs, kwargs)
        stats[mode], outs[mode] = s, out
        rows.append((f"serving/{mode}/tokens_per_s", s.decode_s * 1e6,
                     f"tok_s={s.tokens_per_s:.1f}"))
        rows.append((f"serving/{mode}/slot_occupancy", 0.0,
                     f"occupancy={s.slot_occupancy:.3f}"))
        if mode != "wave":
            rows.append((f"serving/{mode}/mean_active_requests", 0.0,
                         f"concurrent={s.mean_active_requests:.2f}"))
            rows.append((f"serving/{mode}/block_utilization", 0.0,
                         f"blocks={s.block_utilization:.3f}"))
    # Same KV budget, greedy: paged must reproduce slot outputs exactly
    # while packing more concurrent requests into the pool.
    assert outs["paged"] == outs["slot"], "paged changed greedy outputs"
    rows.append(("serving/paged_vs_slot", 0.0,
                 f"speedup={stats['paged'].tokens_per_s / max(stats['slot'].tokens_per_s, 1e-9):.2f}x "
                 f"concurrency={stats['paged'].mean_active_requests / max(stats['slot'].mean_active_requests, 1e-9):.2f}x"))
    rows.append(("serving/continuous_vs_wave", 0.0,
                 f"speedup={stats['paged'].tokens_per_s / max(stats['wave'].tokens_per_s, 1e-9):.2f}x"))

    # -- 2. shared-prefix trace: prefix cache off vs on, same pool ----------
    shared = _shared_trace(cfg, n_requests)
    pool = dict(mode="continuous", max_batch=6, block_size=8,
                num_blocks=16, prefill_chunk=16, **q)
    s_off, out_off = _run_mode(cfg, params, shared,
                               dict(pool, prefix_cache=False))
    s_on, out_on = _run_mode(cfg, params, shared,
                             dict(pool, prefix_cache=True))
    assert out_on == out_off, "prefix caching changed greedy outputs"
    conc = s_on.mean_active_requests / max(s_off.mean_active_requests, 1e-9)
    rows.append(("serving/prefix_cache/hit_rate", 0.0,
                 f"hit_rate={s_on.prefix_hit_rate:.2f} "
                 f"cached_tok={s_on.cached_prompt_tokens}"))
    rows.append(("serving/prefix_cache/concurrency", 0.0,
                 f"concurrent={s_on.mean_active_requests:.2f} "
                 f"vs_nocache={conc:.2f}x"))
    rows.append(("serving/prefix_cache/utilization", 0.0,
                 f"logical_util={s_on.block_utilization:.2f} "
                 f"(>1.0 = sharing serves more context than the pool stores)"))
    rows.append(("serving/prefix_cache/tokens_per_s", 0.0,
                 f"tok_s={s_on.tokens_per_s:.1f} "
                 f"vs_nocache={s_on.tokens_per_s / max(s_off.tokens_per_s, 1e-9):.2f}x"))
    assert s_on.prefix_hit_rate > 0.5, (
        f"shared-prefix trace should mostly hit ({s_on.prefix_hit_rate:.2f})")
    assert conc >= 1.2, (
        f"prefix sharing should admit >=1.2x concurrent requests at the "
        f"same num_blocks (got {conc:.2f}x)")

    # -- 3. preemption probe: over-committed pool, identical outputs ---------
    probe = _mixed_trace(cfg, min(n_requests, 6), seed=2)
    ample = dict(mode="continuous", max_batch=3, block_size=8,
                 num_blocks=32, prefill_chunk=16, **q)
    tight = dict(ample, num_blocks=10)
    _, out_ample = _run_mode(cfg, params, probe, ample)
    s_tight, out_tight = _run_mode(cfg, params, probe, tight)
    assert s_tight.preemptions >= 1, "tight pool should force preemption"
    assert out_tight == out_ample, (
        "preemption-recompute changed a request's final output")
    rows.append(("serving/preemption", 0.0,
                 f"preemptions={s_tight.preemptions} "
                 f"outputs_identical=True"))

    # -- 4. SCLAD probe: quantized pool at a fixed byte budget ---------------
    # Equal device BYTES, not equal blocks: size the int8 pool to the fp
    # pool's footprint and let the compressed layout turn the spare bytes
    # into extra blocks (SCLAD stores compressed, loads dense — compute
    # never sees the encoding, so greedy outputs must not move).  Each
    # probe request occupies exactly 2 blocks for its whole life (prompt
    # 9-12 + 3 new tokens <= 16 = 2 blocks of 8), so under a 16-request
    # burst the pool — not lanes or prompt shape — caps concurrency.
    # Admission is optimistic (all 16 lanes fill before any block is
    # consumed), so the cap shows up as peak simultaneously DECODING
    # lanes: the prefill storm preempts exactly the overflow and the
    # survivors decode together — fp sustains pool_blocks/2 of them, the
    # int8 pool ~1.88x that from the same bytes.
    pcfg = dc_replace(cfg, head_dim=64)
    pparams = M.init_params(pcfg, jax.random.PRNGKey(1))
    fp_blocks = 16
    fp_bpb = _pool_block_bytes(pcfg, 8)
    i8_bpb = _pool_block_bytes(dc_replace(pcfg, kv_dtype="int8"), 8)
    pool_bytes = fp_blocks * fp_bpb
    i8_blocks = pool_bytes // i8_bpb
    rng5 = np.random.default_rng(2)
    sreqs = [(rng5.integers(1, pcfg.vocab_size,
                            size=int(rng5.integers(9, 13))), 3)
             for _ in range(16)]
    probe5 = dict(mode="continuous", max_batch=16, block_size=8,
                  prefill_chunk=8, prefix_cache=False)
    s_fp5, out_fp5 = _run_mode(pcfg, pparams, sreqs,
                               dict(probe5, num_blocks=fp_blocks))
    s_i85, out_i85 = _run_mode(pcfg, pparams, sreqs,
                               dict(probe5, num_blocks=int(i8_blocks),
                                    kv_dtype="int8"))
    assert out_i85 == out_fp5, (
        "int8 pool diverged from fp-exact greedy on the SCLAD probe trace")
    assert s_i85.kv_block_bytes < s_fp5.kv_block_bytes
    conc5 = s_i85.peak_decode_lanes / max(s_fp5.peak_decode_lanes, 1)
    assert conc5 >= 1.8, (
        f"int8 at the fp pool's byte budget should sustain >=1.8x the "
        f"concurrent requests before preemption (got {conc5:.2f}x)")
    assert s_i85.preemptions < s_fp5.preemptions
    rows.append(("serving/sclad/concurrency", 0.0,
                 f"pool_bytes={pool_bytes} "
                 f"concurrent_fp={s_fp5.peak_decode_lanes} "
                 f"concurrent_int8={s_i85.peak_decode_lanes} "
                 f"ratio={conc5:.2f}x greedy_identical=True"))
    rows.append(("serving/sclad/tokens_per_s", 0.0,
                 f"tok_s_fp={s_fp5.tokens_per_s:.1f} "
                 f"tok_s_int8={s_i85.tokens_per_s:.1f} "
                 f"preempt_fp={s_fp5.preemptions} "
                 f"preempt_int8={s_i85.preemptions}"))

    # -- 5. attn kernel probe ------------------------------------------------
    # Correctness tripwire: with the kernels ON (decode AND prefill), the
    # scheduler must stay bit-transparent (prefix cache on vs off — same
    # greedy outputs).  Kernel-vs-reference is a TOLERANCE property
    # (one-pass fp32 online softmax vs two-pass bf16 reference; near-tie
    # argmax can flip), so on-vs-off tok/s are reported side by side but
    # not token-compared — the per-kernel parity suite in
    # tests/test_kernels.py owns that.  The shared-prefix trace makes
    # every admission a prefix-hit CONTINUATION chunk, i.e. the exact path
    # the paged flash-prefill kernel fuses (table-walked context + in-
    # kernel K/V scatter); off-TPU both implementations run on CPU (the
    # kernels through the Pallas interpreter), so tok/s here tracks the
    # parity path's cost, not TPU speed.
    kreqs = _shared_trace(cfg, min(n_requests, 6), seed=4)
    kern = dict(mode="continuous", max_batch=4, block_size=8,
                num_blocks=KV_BUDGET_TOKENS // 8, prefill_chunk=16, **q)
    s_koff, _ = _run_mode(cfg, params, kreqs,
                          dict(kern, attn_kernel="off"))
    s_kon, out_kon = _run_mode(cfg, params, kreqs,
                               dict(kern, attn_kernel="on"))
    _, out_kon_np = _run_mode(
        cfg, params, kreqs, dict(kern, attn_kernel="on",
                                 prefix_cache=False))
    assert out_kon == out_kon_np, (
        "prefix caching changed greedy outputs under the kernel")
    rows.append(("serving/attn_kernel", 0.0,
                 f"tok_s_on={s_kon.tokens_per_s:.1f} "
                 f"tok_s_off={s_koff.tokens_per_s:.1f} "
                 f"prefill_tok_s_on={s_kon.prefill_tokens_per_s:.1f} "
                 f"prefill_tok_s_off={s_koff.prefill_tokens_per_s:.1f} "
                 f"ttft_on={s_kon.mean_ttft_s * 1e3:.1f}ms "
                 f"ttft_off={s_koff.mean_ttft_s * 1e3:.1f}ms "
                 f"prefix_invariant_under_kernel=True "
                 f"peak_pool_bytes={s_kon.peak_pool_bytes}"))

    # -- 6. open-loop probes: Poisson arrivals through the async frontend ----
    # Moderate rate, ample pool: the engine absorbs the offered load —
    # breaker closed, nothing rejected or shed, goodput == completion
    # rate.  (Rates are request clocks, not token clocks: CPU interpret-
    # mode tok/s is slow, so the SLO is generous — the artifact's value
    # is the DISTRIBUTION shape and the admission counts, not absolute
    # milliseconds.)
    ol_n = 6 if smoke else 12
    ol_kwargs = dict(mode="continuous", max_batch=4, block_size=8,
                     num_blocks=48, prefill_chunk=16, **q)
    # Prompt lengths pinned to (9, 16): every take pads to the SAME
    # 16-wide chunk bucket, so the group-size warmup in
    # _open_loop_section covers every retrace (see its docstring).
    mod_trace = poisson_trace(
        np.random.default_rng(6), ol_n, rate_req_s=4.0,
        vocab=cfg.vocab_size, prompt_len=(9, 16), budget=(3, 6))
    mod_breaker = CircuitBreaker(window=16, trip_pressure=4,
                                 sat_threshold=1.0, cooldown_ticks=8)
    _, mod = _open_loop_section(cfg, params, mod_trace, ol_kwargs,
                                mod_breaker, max_queue_depth=ol_n,
                                slo_ttft_s=30.0)
    assert mod["breaker"]["opens"] == 0, (
        "moderate open-loop rate should not trip the breaker")
    assert mod["completed"] == ol_n, (
        f"moderate rate should complete everything "
        f"({mod['completed']}/{ol_n})")
    rows.append(("serving/open_loop/moderate", 0.0,
                 f"completed={mod['completed']}/{ol_n} "
                 f"p99_ttft={mod['client_p99_ttft_s'] * 1e3:.0f}ms "
                 f"p99_itl={mod['client_p99_itl_s'] * 1e3:.0f}ms "
                 f"goodput={mod['goodput']['goodput_req_s']:.2f}req/s"))

    # Saturating: an arrival burst against a TIGHT pool (optimistic
    # admission preempts, saturation pins at 1.0) trips the breaker
    # open during the burst; a tail arriving 0.8s later meets an open or
    # half-open breaker — at most `probes` of it admitted, the rest shed.
    # The tail cannot close the breaker early: closing needs a completed
    # probe, and no probes exist before the tail arrives.
    # Prompt 9-12 + budget 4 keeps even a preemption-recompute prompt
    # (prompt + generated tokens) at <= 16 — one chunk bucket, warmed.
    rng6 = np.random.default_rng(7)
    burst = [TraceItem(arrival_s=float(i) * 1e-3,
                       prompt=rng6.integers(1, cfg.vocab_size,
                                            size=int(rng6.integers(9, 13))),
                       max_new_tokens=4)
             for i in range(8)]
    tail = [TraceItem(arrival_s=0.8 + float(i) * 1e-3,
                      prompt=rng6.integers(1, cfg.vocab_size,
                                           size=int(rng6.integers(9, 13))),
                      max_new_tokens=4)
            for i in range(4)]
    sat_kwargs = dict(ol_kwargs, max_batch=4, num_blocks=6)
    sat_breaker = CircuitBreaker(window=8, trip_pressure=2,
                                 sat_threshold=0.9, cooldown_ticks=12,
                                 probes=1)
    sat_report, sat = _open_loop_section(
        cfg, params, burst + tail, sat_kwargs, sat_breaker,
        max_queue_depth=16, slo_ttft_s=30.0)
    assert sat["breaker"]["opens"] >= 1, (
        "saturating burst must trip the breaker open")
    assert sat["shed_breaker"] >= 1, (
        "tail arrivals behind an open breaker must be shed")
    rows.append(("serving/open_loop/saturating", 0.0,
                 f"completed={sat['completed']}/{len(burst) + len(tail)} "
                 f"shed={sat['shed_breaker']} "
                 f"rejected={sat['rejected_backpressure']} "
                 f"breaker_opens={sat['breaker']['opens']} "
                 f"final={sat['breaker']['final_state']} "
                 f"bit_identical=True"))

    # -- 7. speculative decoding probe ---------------------------------------
    # spec on-vs-off over the SAME trace and pool (always ngram with the
    # trace-pinned spec_k below, independent of --spec-decode/--spec-k,
    # which govern traces 1-3/5/6).  Two shapes: REPETITIVE (greedy +
    # generous budget over prompts SCREENED so the tiny random-init model
    # settles into a short output cycle within a few tokens — the
    # structured shape the suffix-matching proposer feeds on, standing in
    # for code/JSON/template workloads; per-request decode tok/s must
    # improve >= 1.3x with outputs bit-identical) and RANDOM (stochastic
    # sampling — a temperature sample almost never equals the draft, so
    # acceptance ~0 and this documents the neutral-to-slight-loss floor;
    # outputs STILL bit-identical, because the verify pass re-samples
    # every position with its positional PRNG key).
    sp_n = 4 if smoke else 8
    sp_pool = dict(mode="continuous", max_batch=4, block_size=8,
                   num_blocks=48, prefill_chunk=16)
    # spec_k=6 amortizes best on the short-cycle trace (a verify pass
    # costs ~2 decode steps of host+dispatch overhead at smoke scale, so
    # the accepted-tokens-per-pass ratio has to clear that bar).
    sp_on = dict(sp_pool, spec_decode="ngram", spec_k=6)
    # Prompt seeds screened for greedy cycle onset <= 5 tokens under
    # params seed 0 (see the PR-8 trace notes): each prompt's plain
    # greedy continuation locks into a period-<=8 cycle almost
    # immediately, so acceptance reflects the proposer, not cycle onset.
    rep_seeds = (54, 76, 74, 53)
    rep_reqs = [(np.random.default_rng(1000 + s).integers(
                     1, cfg.vocab_size, size=8), 56)
                for s in (rep_seeds if smoke else rep_seeds * 2)]
    rng7 = np.random.default_rng(11)
    per_req = lambda s: s.tokens_per_s / max(s.mean_active_requests, 1e-9)
    # Best-of-2 timing: the measured interval is ~tens of scheduler
    # passes on a shared CPU runner, so a single sample can eat a noise
    # spike.  Correctness (bit-identity) is asserted on EVERY run; only
    # the throughput ratio takes the best sample.
    sp_speed = 0.0
    for _ in range(2):
        s_rep_off, out_rep_off = _run_mode(cfg, params, rep_reqs, sp_pool)
        s_rep_on, out_rep_on = _run_mode(cfg, params, rep_reqs, sp_on)
        assert out_rep_on == out_rep_off, (
            "speculation changed greedy outputs on the repetitive trace")
        sp_speed = max(sp_speed,
                       per_req(s_rep_on) / max(per_req(s_rep_off), 1e-9))
    assert s_rep_on.spec_acceptance_rate >= 0.3, (
        f"cycled greedy output should accept >=30% of n-gram drafts "
        f"(got {s_rep_on.spec_acceptance_rate:.2f})")
    assert sp_speed >= 1.3, (
        f"speculation should improve per-request decode tok/s >=1.3x on "
        f"the repetitive trace (got {sp_speed:.2f}x)")
    rows.append(("serving/spec_decode/repetitive", 0.0,
                 f"spec_k={sp_on['spec_k']} "
                 f"acc={s_rep_on.spec_acceptance_rate:.2f} "
                 f"per_req_tok_s_on={per_req(s_rep_on):.2f} "
                 f"per_req_tok_s_off={per_req(s_rep_off):.2f} "
                 f"speedup={sp_speed:.2f}x outputs_identical=True"))
    samp = {"sampler": SamplerConfig(temperature=0.8, top_k=10)}
    rand_reqs = [(rng7.integers(1, cfg.vocab_size,
                                size=int(rng7.integers(6, 11))), 12)
                 for _ in range(sp_n)]
    s_rand_off, out_rand_off = _run_mode(cfg, params, rand_reqs,
                                         dict(sp_pool, **samp))
    s_rand_on, out_rand_on = _run_mode(cfg, params, rand_reqs,
                                       dict(sp_on, **samp))
    assert out_rand_on == out_rand_off, (
        "speculation changed stochastic outputs on the random trace")
    rows.append(("serving/spec_decode/random", 0.0,
                 f"acc={s_rand_on.spec_acceptance_rate:.2f} "
                 f"tok_s_on={s_rand_on.tokens_per_s:.2f} "
                 f"tok_s_off={s_rand_off.tokens_per_s:.2f} "
                 f"outputs_identical=True"))

    # -- 8. scale-out: sharded dispatch + prefix-affinity replica router -----
    # Rung 1 (tensor scale-up) runs in a SUBPROCESS: jax fixes the device
    # topology at import time and this process owns one CPU device, so
    # ``benchmarks.sharded_probe`` forces 2 host devices before its jax
    # import, runs one trace through a meshless engine and one whose
    # paged pool (payload + SCLAD scales) is shard_map-sharded over the
    # "model" axis, and prints a single JSON line.  float32 params inside
    # the probe (bf16 TP psum reduction order flips greedy near-ties);
    # greedy tokens AND scheduler stats must match the single-device
    # engine exactly.  Timing is CPU parity-path cost, not a speed claim.
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.pop("XLA_FLAGS", None)  # the probe forces its own device count
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_probe",
         "--model-parallel", "2", "--requests", str(4 if smoke else 6),
         "--max-new", "4", "--kv-dtype", "fp"],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"sharded_probe failed (rc={proc.returncode}):\n"
        f"{proc.stdout}\n{proc.stderr}")
    shard = json.loads(proc.stdout.strip().splitlines()[-1])
    assert shard["greedy_identical"], "sharded dispatch changed greedy"
    assert shard["stats_identical"], "sharded dispatch changed scheduling"
    rows.append(("serving/scale_out/sharded", 0.0,
                 f"mp={shard['model_parallel']} "
                 f"tok_s_single={shard['single']['decode_tokens_per_s']:.1f} "
                 f"tok_s_sharded={shard['sharded']['decode_tokens_per_s']:.1f} "
                 f"greedy_identical=True stats_identical=True"))

    # Rung 2 (data-parallel scale-out): the SAME shared-system-prompt
    # open-loop trace through 2 replicas under prefix-affinity routing vs
    # round-robin, plus a 1-replica baseline for aggregate goodput.
    # Affinity converges shared-prefix traffic onto the replica already
    # holding its blocks (block pools do not gossip), so the fleet pays
    # ONE cold shared prefill where round-robin pays one per replica —
    # the aggregate prefix hit-rate gap asserted below.  The arrival rate
    # is moderate on purpose: affinity needs the first request's blocks
    # COMMITTED before later arrivals route (a burst outrunning prefill
    # would make every placement cold and the policies identical).
    rt_n = 12 if smoke else 20
    rt_prefix = np.random.default_rng(21).integers(
        1, cfg.vocab_size, size=24)
    rt_trace = poisson_trace(
        np.random.default_rng(22), rt_n, rate_req_s=5.0,
        vocab=cfg.vocab_size, prompt_len=(4, 8), budget=(3, 5),
        shared_prefix=rt_prefix, prefix_fraction=0.75)
    rt_pool = dict(mode="continuous", max_batch=4, block_size=8,
                   num_blocks=48, prefill_chunk=16, prefix_cache=True)

    def rt_engines(n):
        engines = []
        for _ in range(n):
            e = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1,
                              **rt_pool)
            warmup_prefill(e, cfg.vocab_size,
                           prompt_lens=trace_prompt_lens(
                               rt_trace, e, extra=(len(rt_prefix),)))
            engines.append(e)
        return engines

    aff_rep, aff_router = run_open_loop_router(
        rt_engines(2), rt_trace, policy="affinity", max_queue_depth=rt_n)
    rr_rep, rr_router = run_open_loop_router(
        rt_engines(2), rt_trace, policy="round_robin",
        max_queue_depth=rt_n)
    one_rep, _ = run_open_loop_router(
        rt_engines(1), rt_trace, policy="affinity", max_queue_depth=rt_n)
    aff, rr = aff_router.routing_report(), rr_router.routing_report()
    assert aff["prefix_hit_rate"] > rr["prefix_hit_rate"], (
        f"prefix-affinity routing must beat round-robin on aggregate "
        f"prefix hit-rate (affinity={aff['prefix_hit_rate']:.3f} "
        f"round_robin={rr['prefix_hit_rate']:.3f})")
    # The router never touches tokens: every completed affinity stream is
    # bit-identical to the same prompt through a closed-loop solo engine.
    ref = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1,
                        **rt_pool)
    rt_done = [(it, rec) for it, rec in zip(rt_trace, aff_rep.records)
               if rec.status == "completed"]
    rt_uids = [ref.submit(it.prompt, max_new_tokens=it.max_new_tokens)
               for it, _ in rt_done]
    rt_ref_out = ref.run()
    for uid, (it, rec) in zip(rt_uids, rt_done):
        assert rec.tokens == rt_ref_out[uid], (
            "routed stream diverged from solo-engine greedy")
    slo = 30.0
    aff_sum = aff_rep.summary(slo)
    rr_sum = rr_rep.summary(slo)
    one_sum = one_rep.summary(slo)
    rows.append(("serving/scale_out/router", 0.0,
                 f"replicas=2 "
                 f"hit_aff={aff['prefix_hit_rate']:.2f} "
                 f"hit_rr={rr['prefix_hit_rate']:.2f} "
                 f"affinity_hit_rate={aff['affinity_hit_rate']:.2f} "
                 f"per_replica={aff['per_replica_requests']} "
                 f"goodput2={aff_sum['goodput']['goodput_req_s']:.2f}req/s "
                 f"goodput1={one_sum['goodput']['goodput_req_s']:.2f}req/s "
                 f"streams_identical=True"))

    # -- 9. fault tolerance: crash one replica mid-decode, fail over ---------
    # The same trace through a 3-replica round-robin fleet twice: clean,
    # then with replica 0 under a deterministic crash plan (engine-step
    # clock, so warmup never consumes it — engines wrap AFTER priming).
    # Round-robin keeps placement identical across the two runs; greedy
    # sampling plus the emitted-prefix resubmission makes every failed-
    # over stream bit-identical to its clean twin, which is the assert.
    # Prompt 9-12 + budget 4 keeps failover recompute prompts (prompt +
    # emitted, always < prompt + budget) inside the one warmed 16-token
    # chunk bucket.
    ft_n = 6 if smoke else 10
    rng9 = np.random.default_rng(31)
    ft_trace = [TraceItem(arrival_s=float(i) * 1e-2,
                          prompt=rng9.integers(
                              1, cfg.vocab_size,
                              size=int(rng9.integers(9, 13))),
                          max_new_tokens=4)
                for i in range(ft_n)]

    def ft_engines():
        engines = []
        for _ in range(3):
            e = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1,
                              **rt_pool)
            warmup_prefill(e, cfg.vocab_size,
                           prompt_lens=trace_prompt_lens(ft_trace, e))
            engines.append(e)
        return engines

    ft_clean_rep, _ = run_open_loop_router(
        ft_engines(), ft_trace, policy="round_robin",
        max_queue_depth=ft_n)
    assert all(r.status == "completed" for r in ft_clean_rep.records)
    ft_crash_tick = 4
    chaos = ft_engines()
    chaos[0] = FaultyEngine(chaos[0], FaultPlan.crash_at(ft_crash_tick))
    ft_chaos_rep, ft_router = run_open_loop_router(
        chaos, ft_trace, policy="round_robin", max_queue_depth=ft_n)
    assert chaos[0].crashed, "the crash plan must actually fire"
    assert ft_router.stats.replica_deaths == 1
    assert ft_router.stats.failovers >= 1, (
        "the crash must strand in-flight requests for failover to rescue")
    assert ft_chaos_rep.availability == 1.0, (
        f"every request must complete via failover with one replica dead "
        f"(statuses: {[r.status for r in ft_chaos_rep.records]})")
    assert [r.tokens for r in ft_chaos_rep.records] \
        == [r.tokens for r in ft_clean_rep.records], (
        "failed-over streams must be bit-identical to the clean run")
    ft_clean_sum = ft_clean_rep.summary(slo)
    ft_chaos_sum = ft_chaos_rep.summary(slo)
    ft_fault = ft_chaos_sum["fault_tolerance"]
    ft_ttft_delta = max(0.0, ft_chaos_sum["client_p99_ttft_s"]
                        - ft_clean_sum["client_p99_ttft_s"])
    rows.append(("serving/fault_tolerance", 0.0,
                 f"replicas=3 crash_tick={ft_crash_tick} "
                 f"availability={ft_chaos_rep.availability:.2f} "
                 f"deaths={ft_fault['replica_deaths']} "
                 f"failovers={ft_fault['failovers']} "
                 f"goodput_clean={ft_clean_sum['goodput']['goodput_req_s']:.2f}req/s "
                 f"goodput_failure={ft_chaos_sum['goodput']['goodput_req_s']:.2f}req/s "
                 f"failover_p99_ttft={ft_fault['failover_p99_ttft_s'] * 1e3:.0f}ms "
                 f"bit_identical_to_clean=True"))

    # -- machine-readable summary (CI artifact) ------------------------------
    bench.update({
        "decode_tokens_per_s": {m: stats[m].tokens_per_s for m in stats},
        "prefill_tokens_per_s": {
            m: stats[m].prefill_tokens_per_s for m in stats},
        "mean_ttft_s": {m: stats[m].mean_ttft_s for m in stats},
        "mean_active_requests": {
            m: stats[m].mean_active_requests for m in stats if m != "wave"},
        "prefix_cache": {
            "hit_rate": s_on.prefix_hit_rate,
            "cached_prompt_tokens": s_on.cached_prompt_tokens,
            "concurrency_vs_off_x": conc,
            "block_utilization": s_on.block_utilization,
            "peak_pool_bytes_on": s_on.peak_pool_bytes,
            "peak_pool_bytes_off": s_off.peak_pool_bytes,
        },
        "preemption": {"tight_pool_preemptions": s_tight.preemptions,
                       "outputs_identical": True},
        # SCLAD probe: fp-exact vs int8+scales pools holding the SAME
        # device bytes (head_dim=64 layout; 1.88x blocks from the
        # compressed encoding).  greedy_identical_to_fp is the bench-side
        # quality gate CI trips on.
        "sclad": {
            "probe_head_dim": 64,
            "pool_bytes": int(pool_bytes),
            "fp": {
                "num_blocks": int(fp_blocks),
                "kv_block_bytes": s_fp5.kv_block_bytes,
                "peak_decode_lanes": s_fp5.peak_decode_lanes,
                "preemptions": s_fp5.preemptions,
                "decode_tokens_per_s": s_fp5.tokens_per_s,
            },
            "int8": {
                "num_blocks": int(i8_blocks),
                "kv_block_bytes": s_i85.kv_block_bytes,
                "peak_decode_lanes": s_i85.peak_decode_lanes,
                "preemptions": s_i85.preemptions,
                "decode_tokens_per_s": s_i85.tokens_per_s,
            },
            "concurrency_vs_fp_x": conc5,
            "greedy_identical_to_fp": True,
        },
        # One entry per attn_kernel mode exercised by the probe; the
        # legacy "decode_kernel" key is kept for artifact continuity
        # across PRs (same numbers, pre-PR-5 spelling).
        "attn_kernel": {
            "modes": {"probe_on": "on", "probe_off": "off",
                      "mixed_and_prefix_traces": "auto"},
            "on_tokens_per_s": s_kon.tokens_per_s,
            "off_tokens_per_s": s_koff.tokens_per_s,
            "on_prefill_tokens_per_s": s_kon.prefill_tokens_per_s,
            "off_prefill_tokens_per_s": s_koff.prefill_tokens_per_s,
            "on_mean_ttft_s": s_kon.mean_ttft_s,
            "off_mean_ttft_s": s_koff.mean_ttft_s,
            "prefix_invariant_under_kernel": True,
            "peak_pool_bytes": s_kon.peak_pool_bytes,
            "kv_block_bytes": s_kon.kv_block_bytes,
            "note": "kernel timing is Pallas interpret mode off-TPU "
                    "(parity path, not a speed claim)",
        },
        "decode_kernel": {
            "on_tokens_per_s": s_kon.tokens_per_s,
            "off_tokens_per_s": s_koff.tokens_per_s,
            "prefix_invariant_under_kernel": True,
            "peak_pool_bytes": s_kon.peak_pool_bytes,
            "kv_block_bytes": s_kon.kv_block_bytes,
            "note": "deprecated alias of attn_kernel",
        },
        # Open-loop service posture: client-side latency distributions,
        # goodput-under-SLO, and the admission-control counters.
        "open_loop": {"moderate": mod, "saturating": sat},
        # Speculative decoding probe: draft acceptance and the decode
        # throughput it buys (per-request AND aggregate) on the
        # repetitive shape vs the adversarial-random floor, with the
        # bit-identity gates CI trips on.
        "spec_decode": {
            "mode": "ngram", "spec_k": sp_on["spec_k"],
            "traces_1_3_5_spec_decode": spec_decode,
            "repetitive": {
                "acceptance_rate": s_rep_on.spec_acceptance_rate,
                "verify_passes": s_rep_on.spec_passes,
                "decode_tokens_per_s_on": s_rep_on.tokens_per_s,
                "decode_tokens_per_s_off": s_rep_off.tokens_per_s,
                "per_request_tokens_per_s_on": per_req(s_rep_on),
                "per_request_tokens_per_s_off": per_req(s_rep_off),
                "speedup_per_request_x": sp_speed,
                "outputs_identical": True,
            },
            "random": {
                "acceptance_rate": s_rand_on.spec_acceptance_rate,
                "verify_passes": s_rand_on.spec_passes,
                "decode_tokens_per_s_on": s_rand_on.tokens_per_s,
                "decode_tokens_per_s_off": s_rand_off.tokens_per_s,
                "outputs_identical": True,
            },
        },
        # Scale-out posture (PR 9): rung 1 = shard_map'd paged kernels
        # over the "model" mesh axis (subprocess probe, forced host
        # devices), rung 2 = replica router with prefix-affinity
        # placement vs round-robin vs one replica.
        "scale_out": {
            "sharded": {
                "devices": shard["devices"],
                "model_parallel": shard["model_parallel"],
                "requests": shard["requests"],
                "kv_dtype": shard["kv_dtype"],
                "single_decode_tokens_per_s":
                    shard["single"]["decode_tokens_per_s"],
                "sharded_decode_tokens_per_s":
                    shard["sharded"]["decode_tokens_per_s"],
                "single_prefill_tokens_per_s":
                    shard["single"]["prefill_tokens_per_s"],
                "sharded_prefill_tokens_per_s":
                    shard["sharded"]["prefill_tokens_per_s"],
                "greedy_identical": shard["greedy_identical"],
                "stats_identical": shard["stats_identical"],
                "note": shard["note"],
            },
            "router": {
                "replicas": 2,
                "trace_requests": rt_n,
                "shared_prefix_tokens": int(len(rt_prefix)),
                "affinity": {
                    "prefix_hit_rate": aff["prefix_hit_rate"],
                    "affinity_hit_rate": aff["affinity_hit_rate"],
                    "spillovers": aff["spillovers"],
                    "per_replica_requests": aff["per_replica_requests"],
                    "completed": aff_sum["completed"],
                    "goodput_req_s":
                        aff_sum["goodput"]["goodput_req_s"],
                },
                "round_robin": {
                    "prefix_hit_rate": rr["prefix_hit_rate"],
                    "per_replica_requests": rr["per_replica_requests"],
                    "completed": rr_sum["completed"],
                    "goodput_req_s":
                        rr_sum["goodput"]["goodput_req_s"],
                },
                "single": {
                    "completed": one_sum["completed"],
                    "goodput_req_s":
                        one_sum["goodput"]["goodput_req_s"],
                },
                "streams_identical_to_solo": True,
            },
        },
        # Fault-tolerance posture (PR 10): one replica crashed mid-decode
        # under a deterministic fault plan; failover must hold
        # availability at 1.0 with streams bit-identical to the clean
        # run.  The schema gate pins these paths, so CI trips if the
        # failover path ever degrades.
        "fault_tolerance": {
            "replicas": 3,
            "crash_tick": ft_crash_tick,
            "trace_requests": ft_n,
            "availability": ft_chaos_rep.availability,
            "replica_deaths": ft_fault["replica_deaths"],
            "failovers": ft_fault["failovers"],
            "retries": ft_fault["retries"],
            "health": ft_fault["health"],
            "outputs_identical_to_clean": True,
            "clean_goodput_req_s":
                ft_clean_sum["goodput"]["goodput_req_s"],
            "failure_goodput_req_s":
                ft_chaos_sum["goodput"]["goodput_req_s"],
            "failover_p50_ttft_s": ft_fault["failover_p50_ttft_s"],
            "failover_p99_ttft_s": ft_fault["failover_p99_ttft_s"],
            "client_p99_ttft_delta_s": ft_ttft_delta,
        },
    })
    # Structural gate before the artifact leaves the process: CI uploads
    # whatever lands in --json, so a malformed dict must fail HERE.
    validate_bench(bench)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests, same assertions")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the headline numbers as JSON "
                         "(e.g. BENCH_serving.json, uploaded by CI)")
    ap.add_argument("--kv-dtype", default="fp",
                    choices=[d for d in kv_quant.KV_DTYPES
                             if d in ("fp",) + kv_quant.QUANTIZED_KV_DTYPES],
                    help="pool encoding for the trace engines; the SCLAD "
                         "fp-vs-int8 probe runs either way (CI tripwire)")
    ap.add_argument("--spec-decode", default="off",
                    choices=list(SPEC_DECODE_MODES),
                    help="speculation mode for the trace engines in "
                         "sections 1-3/5/6 (every bit-identity assertion "
                         "then re-runs under speculation — CI tripwire); "
                         "the spec probe's own on-vs-off gate runs "
                         "either way")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per lane per verify pass")
    args = ap.parse_args()
    for r in run(smoke=args.smoke, json_path=args.json,
                 kv_dtype=args.kv_dtype, spec_decode=args.spec_decode,
                 spec_k=args.spec_k):
        print(",".join(map(str, r)))


if __name__ == "__main__":
    main()
