"""Serving engine: paged KV + prefix caching + preemption vs slots vs waves.

Two multi-tenant traces through ``serving.engine.ServingEngine`` on a tiny
CPU config:

1. MIXED trace (long/short prompts, mixed budgets) through three scheduler
   configurations:
     * ``wave``  — the seed's lockstep wave batcher (baseline of PR 1);
     * ``slot``  — continuous batching with stripe-equivalent blocks
       (``block_size = max_len``: every request holds one full stripe);
     * ``paged`` — small blocks + chunked prefill on the SAME KV token
       budget but more lanes.
   Greedy outputs are asserted identical between slot and paged.

2. SHARED-PREFIX trace (one system prompt + short unique tails — the
   dominant traffic shape at "millions of users" scale) through the paged
   engine with the prefix cache OFF vs ON at the SAME ``num_blocks``:
   blocks holding the shared prompt are ref-counted and shared, so
   admission packs >= 1.2x more concurrent requests into the same pool and
   skips the shared prefill compute (reported as the prefix hit-rate).
   Outputs are asserted bit-identical ON vs OFF.

3. PREEMPTION probe: the same requests through an over-committed pool
   (optimistic admission, no reservation) vs an ample one — preempted
   requests are re-queued and recomputed, and their final outputs are
   asserted identical to the unpressured run.

Reported: decode tokens/s, lane occupancy, mean concurrent requests, KV
token utilization (can exceed 1.0 under sharing — lanes serve more context
than the pool stores) and prefix hit-rate — the generate-stage utilization
gaps the paper's batching analysis (§4.2, Fig 6/8) prices into TCO/token.

Run directly (``--smoke`` keeps it CI-sized):
  PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import EngineStats, ServingEngine

ARCH = "tinyllama-1.1b"
MAX_LEN = 64
# One KV memory budget for the wave/slot/paged comparison: 4 stripes' worth.
KV_BUDGET_TOKENS = 4 * MAX_LEN


def _modes(n_requests):
    return {
        # mode -> ServingEngine kwargs
        "wave": dict(mode="wave", max_batch=4),
        "slot": dict(mode="continuous", max_batch=4, block_size=MAX_LEN,
                     num_blocks=KV_BUDGET_TOKENS // MAX_LEN,
                     prefill_chunk=None),
        # 6 lanes on the same 256-token pool: memory admits ~8 short
        # requests but 6 lanes balance per-step lane cost vs concurrency
        # on CPU.
        "paged": dict(mode="continuous", max_batch=6, block_size=8,
                      num_blocks=KV_BUDGET_TOKENS // 8, prefill_chunk=16),
    }


def _mixed_trace(cfg, n_requests, seed=0):
    """Mixed long/short prompts: the long ones are what strand stripe
    capacity under slot reservation."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        long = i % 4 == 0
        plen = int(rng.integers(33, 48)) if long else int(rng.integers(4, 17))
        reqs.append((rng.integers(1, cfg.vocab_size, size=plen),
                     int(rng.integers(4, 17))))
    return reqs


def _shared_trace(cfg, n_requests, seed=1):
    """One 32-token system prompt + short unique tails + mixed budgets."""
    rng = np.random.default_rng(seed)
    system = rng.integers(1, cfg.vocab_size, size=32)
    reqs = []
    for _ in range(n_requests):
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(4, 9)))
        reqs.append((np.concatenate([system, tail]),
                     int(rng.integers(6, 11))))
    return reqs


def _run_mode(cfg, params, reqs, kwargs):
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1, **kwargs)
    # Warm-up pass compiles the prefill buckets and the decode step so the
    # measured pass times steady-state scheduling, not XLA compiles.  (It
    # also warms the prefix-cache LRU pool, which is exactly the steady
    # state a long-running server sits in.)
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    eng.run()
    eng.stats = EngineStats()
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    results = eng.run()
    assert len(results) == len(reqs)
    return eng.stats, results


def run(smoke: bool = False) -> list[Row]:
    n_requests = 6 if smoke else 16
    cfg = get_config(ARCH).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rows: list[Row] = []

    # -- 1. mixed trace: wave vs slot vs paged -------------------------------
    reqs = _mixed_trace(cfg, n_requests)
    stats, outs = {}, {}
    for mode, kwargs in _modes(n_requests).items():
        s, out = _run_mode(cfg, params, reqs, kwargs)
        stats[mode], outs[mode] = s, out
        rows.append((f"serving/{mode}/tokens_per_s", s.decode_s * 1e6,
                     f"tok_s={s.tokens_per_s:.1f}"))
        rows.append((f"serving/{mode}/slot_occupancy", 0.0,
                     f"occupancy={s.slot_occupancy:.3f}"))
        if mode != "wave":
            rows.append((f"serving/{mode}/mean_active_requests", 0.0,
                         f"concurrent={s.mean_active_requests:.2f}"))
            rows.append((f"serving/{mode}/block_utilization", 0.0,
                         f"blocks={s.block_utilization:.3f}"))
    # Same KV budget, greedy: paged must reproduce slot outputs exactly
    # while packing more concurrent requests into the pool.
    assert outs["paged"] == outs["slot"], "paged changed greedy outputs"
    rows.append(("serving/paged_vs_slot", 0.0,
                 f"speedup={stats['paged'].tokens_per_s / max(stats['slot'].tokens_per_s, 1e-9):.2f}x "
                 f"concurrency={stats['paged'].mean_active_requests / max(stats['slot'].mean_active_requests, 1e-9):.2f}x"))
    rows.append(("serving/continuous_vs_wave", 0.0,
                 f"speedup={stats['paged'].tokens_per_s / max(stats['wave'].tokens_per_s, 1e-9):.2f}x"))

    # -- 2. shared-prefix trace: prefix cache off vs on, same pool ----------
    shared = _shared_trace(cfg, n_requests)
    pool = dict(mode="continuous", max_batch=6, block_size=8,
                num_blocks=16, prefill_chunk=16)
    s_off, out_off = _run_mode(cfg, params, shared,
                               dict(pool, prefix_cache=False))
    s_on, out_on = _run_mode(cfg, params, shared,
                             dict(pool, prefix_cache=True))
    assert out_on == out_off, "prefix caching changed greedy outputs"
    conc = s_on.mean_active_requests / max(s_off.mean_active_requests, 1e-9)
    rows.append(("serving/prefix_cache/hit_rate", 0.0,
                 f"hit_rate={s_on.prefix_hit_rate:.2f} "
                 f"cached_tok={s_on.cached_prompt_tokens}"))
    rows.append(("serving/prefix_cache/concurrency", 0.0,
                 f"concurrent={s_on.mean_active_requests:.2f} "
                 f"vs_nocache={conc:.2f}x"))
    rows.append(("serving/prefix_cache/utilization", 0.0,
                 f"logical_util={s_on.block_utilization:.2f} "
                 f"(>1.0 = sharing serves more context than the pool stores)"))
    rows.append(("serving/prefix_cache/tokens_per_s", 0.0,
                 f"tok_s={s_on.tokens_per_s:.1f} "
                 f"vs_nocache={s_on.tokens_per_s / max(s_off.tokens_per_s, 1e-9):.2f}x"))
    assert s_on.prefix_hit_rate > 0.5, (
        f"shared-prefix trace should mostly hit ({s_on.prefix_hit_rate:.2f})")
    assert conc >= 1.2, (
        f"prefix sharing should admit >=1.2x concurrent requests at the "
        f"same num_blocks (got {conc:.2f}x)")

    # -- 3. preemption probe: over-committed pool, identical outputs ---------
    probe = _mixed_trace(cfg, min(n_requests, 6), seed=2)
    ample = dict(mode="continuous", max_batch=3, block_size=8,
                 num_blocks=32, prefill_chunk=16)
    tight = dict(ample, num_blocks=10)
    _, out_ample = _run_mode(cfg, params, probe, ample)
    s_tight, out_tight = _run_mode(cfg, params, probe, tight)
    assert s_tight.preemptions >= 1, "tight pool should force preemption"
    assert out_tight == out_ample, (
        "preemption-recompute changed a request's final output")
    rows.append(("serving/preemption", 0.0,
                 f"preemptions={s_tight.preemptions} "
                 f"outputs_identical=True"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests, same assertions")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(map(str, r)))


if __name__ == "__main__":
    main()
