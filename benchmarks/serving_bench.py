"""Serving engine: paged KV + chunked prefill vs slot stripes vs waves.

Runs the same multi-tenant trace (mixed long/short prompts, mixed
completion budgets) through three scheduler configurations of
``serving.engine.ServingEngine`` on a tiny CPU config:

  * ``wave``  — the seed's lockstep wave batcher (baseline of PR 1);
  * ``slot``  — continuous batching with PR 1's reservation semantics:
    ``block_size = max_len`` makes every request reserve one full stripe,
    so concurrency is lanes-bound exactly like the slot engine;
  * ``paged`` — small blocks + chunked prefill on the SAME KV token budget
    but more lanes: requests reserve only their own worst case, so more of
    them share the pool concurrently.

Reported: decode tokens/s, lane occupancy, mean concurrent requests and KV
block utilization — the generate-stage utilization gap the paper's
batching analysis (§4.2, Fig 6/8) prices into TCO/token.  Greedy outputs
are asserted identical between slot and paged so the speedup is not bought
with a correctness change.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import EngineStats, ServingEngine

ARCH = "tinyllama-1.1b"
N_REQUESTS = 16
MAX_LEN = 64
# One KV memory budget for both continuous modes: 4 stripes' worth.
KV_BUDGET_TOKENS = 4 * MAX_LEN
MODES = {
    # mode -> ServingEngine kwargs
    "wave": dict(mode="wave", max_batch=4),
    "slot": dict(mode="continuous", max_batch=4, block_size=MAX_LEN,
                 num_blocks=KV_BUDGET_TOKENS // MAX_LEN, prefill_chunk=None),
    # 6 lanes on the same 256-token pool: memory admits ~8 short requests
    # but 6 lanes balance per-step lane cost vs concurrency on CPU.
    "paged": dict(mode="continuous", max_batch=6, block_size=8,
                  num_blocks=KV_BUDGET_TOKENS // 8, prefill_chunk=16),
}


def _trace(cfg, seed=0):
    """Mixed long/short prompts: the long ones are what strand stripe
    capacity under slot reservation."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(N_REQUESTS):
        long = i % 4 == 0
        plen = int(rng.integers(33, 48)) if long else int(rng.integers(4, 17))
        reqs.append((rng.integers(1, cfg.vocab_size, size=plen),
                     int(rng.integers(4, 17))))
    return reqs


def _run_mode(cfg, params, reqs, kwargs):
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1, **kwargs)
    # Warm-up pass compiles the prefill buckets and the decode step so the
    # measured pass times steady-state scheduling, not XLA compiles.
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    eng.run()
    eng.stats = EngineStats()
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    results = eng.run()
    assert len(results) == len(reqs)
    return eng.stats, results


def run() -> list[Row]:
    cfg = get_config(ARCH).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _trace(cfg)
    rows: list[Row] = []
    stats, outs = {}, {}
    for mode, kwargs in MODES.items():
        s, out = _run_mode(cfg, params, reqs, kwargs)
        stats[mode], outs[mode] = s, out
        rows.append((f"serving/{mode}/tokens_per_s", s.decode_s * 1e6,
                     f"tok_s={s.tokens_per_s:.1f}"))
        rows.append((f"serving/{mode}/slot_occupancy", 0.0,
                     f"occupancy={s.slot_occupancy:.3f}"))
        if mode != "wave":
            rows.append((f"serving/{mode}/mean_active_requests", 0.0,
                         f"concurrent={s.mean_active_requests:.2f}"))
            rows.append((f"serving/{mode}/block_utilization", 0.0,
                         f"blocks={s.block_utilization:.3f}"))
    # Same KV budget, greedy: paged must reproduce slot outputs exactly
    # while packing more concurrent requests into the pool.
    assert outs["paged"] == outs["slot"], "paged changed greedy outputs"
    rows.append(("serving/paged_vs_slot", 0.0,
                 f"speedup={stats['paged'].tokens_per_s / max(stats['slot'].tokens_per_s, 1e-9):.2f}x "
                 f"concurrency={stats['paged'].mean_active_requests / max(stats['slot'].mean_active_requests, 1e-9):.2f}x"))
    rows.append(("serving/continuous_vs_wave", 0.0,
                 f"speedup={stats['paged'].tokens_per_s / max(stats['wave'].tokens_per_s, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
