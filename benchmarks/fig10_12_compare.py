"""Paper Figs 10-12: Chiplet Cloud vs rented/fabricated GPU and TPU clouds,
with NRE amortization."""
from __future__ import annotations

from benchmarks.common import (
    A100_RENT_PER_HR, A100_TOKENS_PER_S_GPT3, GPU_OWNED_SAVINGS,
    PALM_TOKENS_PER_S_PER_TPU, Row, TPUV4_RENT_PER_HR, TPU_OWNED_SAVINGS,
    servers, timed)
from repro.core import explore, tco
from repro.core.workloads import PAPER_MODELS


def _rented_gpu_tco_per_mtoken() -> float:
    return A100_RENT_PER_HR / (A100_TOKENS_PER_S_GPT3 * 3600.0) * 1e6


def _rented_tpu_tco_per_mtoken() -> float:
    return TPUV4_RENT_PER_HR / (PALM_TOKENS_PER_S_PER_TPU * 3600.0) * 1e6


def run() -> list[Row]:
    rows: list[Row] = []
    srv = servers()

    def work():
        return {
            "gpt3": explore.phase2(srv, PAPER_MODELS["gpt3-175b"], ctx=2048,
                                   keep_all=False).best.tco_per_mtoken,
            "palm": explore.phase2(srv, PAPER_MODELS["palm-540b"], ctx=2048,
                                   keep_all=False).best.tco_per_mtoken,
        }

    ours, us = timed(work)
    gpu_rent = _rented_gpu_tco_per_mtoken()
    tpu_rent = _rented_tpu_tco_per_mtoken()
    gpu_own = gpu_rent / GPU_OWNED_SAVINGS
    tpu_own = tpu_rent / TPU_OWNED_SAVINGS

    rows.append(("fig10/gpt3_vs_rented_gpu", us / 4,
                 f"improvement={gpu_rent / ours['gpt3']:.1f}x;paper=97x"))
    rows.append(("fig10/palm_vs_rented_tpu", us / 4,
                 f"improvement={tpu_rent / ours['palm']:.1f}x;paper=18x"))
    rows.append(("fig11/gpt3_vs_owned_gpu", us / 4,
                 f"improvement={gpu_own / ours['gpt3']:.1f}x;paper=8.3x"))
    rows.append(("fig11/palm_vs_owned_tpu", us / 4,
                 f"improvement={tpu_own / ours['palm']:.1f}x;paper=3.7x"))

    # Fig 10's NRE amortization: (TCO+NRE)/token at Google-search scale.
    tokens_per_year = 99_000 * 500 * 3600 * 24 * 365.25
    nre = tco.nre_per_token(tokens_per_year) * 1e6
    with_nre = ours["gpt3"] + nre
    rows.append(("fig10/gpt3_with_nre_at_search_scale", 0.0,
                 f"improvement={gpu_rent / with_nre:.1f}x;"
                 f"nre_per_mtoken={nre:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
