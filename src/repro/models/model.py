"""Unified model: init / forward / loss / cache / decode for all families.

Families:
  dense   — GQA decoder-only transformer (tinyllama, stablelm, phi3, granite)
  moe     — dense attention + MoE FFN (qwen3-moe, qwen2-moe)
  ssm     — pure Mamba-2 stack (mamba2-1.3b)
  hybrid  — Mamba-2 backbone + shared attention block every N (zamba2)
  vlm     — dense LM backbone with stub patch-embedding prefix (internvl2)
  audio   — encoder-decoder with stub frame embeddings (whisper)

Layers are scanned (``lax.scan`` over stacked parameters) so the lowered HLO
is O(1) in depth — essential for the 94-layer dry-run compiles.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_prefill import ops as prefill_ops
from repro.models import kv_quant, layers, moe as moe_lib, ssm as ssm_lib
from repro.models.layers import DTYPE, embed_init
from repro.parallel import sharding

Params = Dict[str, Any]

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    per = cfg.hybrid.attn_every
    groups = cfg.num_layers // per
    tail = cfg.num_layers - groups * per
    return groups, per, tail


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    p: Params = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model)),
        "final_norm": layers.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(keys[1], (cfg.d_model, cfg.vocab_size))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["blocks"] = _stack_init(
            lambda k: layers.init_dense_block(cfg, k), keys[2], cfg.num_layers
        )
        if fam == "vlm":
            p["patch_proj"] = layers.dense_init(keys[3], (cfg.d_model, cfg.d_model))
    elif fam == "moe":
        def init_moe_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln_attn": layers.init_norm(cfg),
                "attn": layers.init_attention(cfg, k1),
                "ln_mlp": layers.init_norm(cfg),
                "moe": moe_lib.init_moe(cfg, k2),
            }
        p["blocks"] = _stack_init(init_moe_block, keys[2], cfg.num_layers)
    elif fam == "ssm":
        def init_ssm_block(k):
            return {
                "ln": layers.init_norm(cfg),
                "mamba": ssm_lib.init_mamba_block(cfg, k),
            }
        p["blocks"] = _stack_init(init_ssm_block, keys[2], cfg.num_layers)
    elif fam == "hybrid":
        groups, per, tail = _hybrid_layout(cfg)

        def init_ssm_block(k):
            return {
                "ln": layers.init_norm(cfg),
                "mamba": ssm_lib.init_mamba_block(cfg, k),
            }

        def init_group(k):
            return _stack_init(init_ssm_block, k, per)

        p["groups"] = _stack_init(init_group, keys[2], groups)
        if tail:
            p["tail"] = _stack_init(init_ssm_block, keys[3], tail)
        p["shared_attn"] = layers.init_dense_block(cfg, keys[4])
    elif fam == "audio":
        def init_enc_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln_attn": layers.init_norm(cfg),
                "attn": layers.init_attention(cfg, k1),
                "ln_mlp": layers.init_norm(cfg),
                "mlp": layers.init_mlp(cfg, k2),
            }

        def init_dec_block(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "ln_self": layers.init_norm(cfg),
                "self_attn": layers.init_attention(cfg, k1),
                "ln_cross": layers.init_norm(cfg),
                "cross_attn": layers.init_attention(cfg, k2),
                "ln_mlp": layers.init_norm(cfg),
                "mlp": layers.init_mlp(cfg, k3),
            }

        p["enc_blocks"] = _stack_init(init_enc_block, keys[2],
                                      cfg.encdec.num_encoder_layers)
        p["enc_norm"] = layers.init_norm(cfg)
        p["dec_blocks"] = _stack_init(init_dec_block, keys[3], cfg.num_layers)
    else:
        raise ValueError(fam)
    return p


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_count(cfg: ModelConfig) -> int:
    import math
    specs = param_specs(cfg)
    return sum(math.prod(x.shape) if x.shape else 1
               for x in jax.tree.leaves(specs))


def param_count_active(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE experts scaled by top-k/E)."""
    import numpy as _np
    specs = param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    total = 0.0
    for path, leaf in flat:
        keys = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        n = float(_np.prod(leaf.shape)) if leaf.shape else 1.0
        if cfg.moe is not None and "moe" in keys and "shared" not in keys \
                and keys[-1] in ("w_gate", "w_up", "w_down"):
            n *= cfg.moe.num_experts_per_tok / cfg.moe.num_experts
        total += n
    return int(total)


# ---------------------------------------------------------------------------
# Forward (train / prefill) — full-sequence
# ---------------------------------------------------------------------------

def _pad_to_multiple(h: jnp.ndarray, mult: int):
    S = h.shape[1]
    pad = (-S) % mult
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    return h, S


def _maybe_remat(fn, remat: bool):
    return jax.checkpoint(fn) if remat else fn


def _scan_blocks(body, h, blocks, remat: bool):
    def wrapped(c, b):
        out, aux = body(c, b)
        return sharding.constrain_tokens(out), aux

    wrapped = _maybe_remat(wrapped, remat)
    h, aux = jax.lax.scan(wrapped, h, blocks)
    return h, aux


def _attn_qkv(cfg: ModelConfig, blk: Params, x: jnp.ndarray,
              positions: jnp.ndarray):
    """Shared attention-input stage: norm, QKV projection, RoPE, sharding
    anchor.  positions: (S,) or (B, S) rope positions.  Returns (q, k, v)
    in compute dtype — ONE definition, so every caller's K/V matches the
    cache contents bit-for-bit."""
    xn = layers.apply_norm(cfg, blk["ln_attn"], x)
    q, k, v = layers._project_qkv(cfg, blk["attn"], xn, xn)
    q = layers.apply_rope(cfg, q, positions)
    k = layers.apply_rope(cfg, k, positions)
    return sharding.constrain_heads(q), k, v


def _attn_post(cfg: ModelConfig, blk: Params, x: jnp.ndarray,
               a: jnp.ndarray, moe_valid: Optional[jnp.ndarray] = None):
    """Shared attention-output stage: residual + output projection, then
    the MLP/MoE half.  moe_valid: (B, S) bool routing-validity mask
    (pads/dead lanes consume no expert capacity; moe family only).
    Returns (x_out, aux)."""
    x = x + a @ blk["attn"]["wo"]
    if "moe" in blk:
        y, aux = moe_lib.apply_moe(
            cfg, blk["moe"], layers.apply_norm(cfg, blk["ln_mlp"], x),
            valid=moe_valid)
    else:
        y = layers.apply_mlp(cfg, blk["mlp"],
                             layers.apply_norm(cfg, blk["ln_mlp"], x))
        aux = 0.0
    return x + y, aux


def _attn_block_body(cfg: ModelConfig, blk: Params, x: jnp.ndarray,
                     positions: jnp.ndarray):
    """ONE per-layer block body for the attention families (dense/moe/vlm)
    over a plain causal window.

    ``backbone`` (train/full forward) and ``prefill`` (wave cache build)
    run this body; ``prefill_slots`` (paged chunked admission) shares its
    ``_attn_qkv``/``_attn_post`` stages but routes the attention core
    through ``kernels.flash_prefill.ops.prefill_attention`` (cached-context
    table walk + left-pad masking + fused K/V scatter), so the greedy
    bit-identity contract pinned by tests/test_continuous_batching.py holds
    across all three by construction.

    Returns (x_out, k, v, aux) with k/v of this call's tokens (compute
    dtype — callers cast to the cache storage dtype).
    """
    q, k, v = _attn_qkv(cfg, blk, x, positions)
    S = x.shape[1]
    if S >= layers.CHUNKED_ATTN_THRESHOLD and S % layers.Q_CHUNK == 0:
        a = layers.chunked_attention(q, k, v, causal=True)
    else:
        mask = jnp.tril(jnp.ones((S, S), bool))[None]
        a = layers._sdpa(cfg, q, k, v, mask[:, None, None])
    x, aux = _attn_post(cfg, blk, x, a)
    return x, k, v, aux


def backbone(cfg: ModelConfig, params: Params, h: jnp.ndarray,
             positions: jnp.ndarray, remat: bool = False,
             encoder_out: Optional[jnp.ndarray] = None):
    """Runs the layer stack on embedded input h (B, S, d).

    Returns (h, aux_loss).
    """
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def body(x, blk):
            x, _, _, aux = _attn_block_body(cfg, blk, x, positions)
            return x, aux
        h, aux = _scan_blocks(body, h, params["blocks"], remat)
        return h, jnp.sum(aux)
    if fam == "ssm":
        def body(x, blk):
            y, _ = ssm_lib.apply_mamba_block(
                cfg, blk["mamba"], layers.apply_norm(cfg, blk["ln"], x))
            return x + y, 0.0
        h, aux = _scan_blocks(body, h, params["blocks"], remat)
        return h, jnp.sum(aux)
    if fam == "hybrid":
        shared = params["shared_attn"]

        def ssm_body(x, blk):
            y, _ = ssm_lib.apply_mamba_block(
                cfg, blk["mamba"], layers.apply_norm(cfg, blk["ln"], x))
            return x + y, 0.0

        def group_body(x, grp):
            x, _ = _scan_blocks(ssm_body, x, grp, remat)
            x = layers.apply_dense_block(cfg, shared, x, positions)
            return x, 0.0

        group_body = _maybe_remat(group_body, remat)
        h, _ = jax.lax.scan(group_body, h, params["groups"])
        if "tail" in params:
            h, _ = _scan_blocks(ssm_body, h, params["tail"], remat)
        return h, jnp.zeros(())
    if fam == "audio":
        assert encoder_out is not None

        def body(x, blk):
            x = x + layers.attention(
                cfg, blk["self_attn"],
                layers.apply_norm(cfg, blk["ln_self"], x),
                positions, causal=True, use_rope=False)
            xc = layers.apply_norm(cfg, blk["ln_cross"], x)
            B, F = encoder_out.shape[0], encoder_out.shape[1]
            ck = (encoder_out @ blk["cross_attn"]["wk"]).reshape(
                B, F, cfg.num_kv_heads, cfg.head_dim)
            cv = (encoder_out @ blk["cross_attn"]["wv"]).reshape(
                B, F, cfg.num_kv_heads, cfg.head_dim)
            x = x + layers.cross_attention(cfg, blk["cross_attn"], xc, ck, cv)
            x = x + layers.apply_mlp(cfg, blk["mlp"],
                                     layers.apply_norm(cfg, blk["ln_mlp"], x))
            return x, 0.0
        h, _ = _scan_blocks(body, h, params["dec_blocks"], remat)
        return h, jnp.zeros(())
    raise ValueError(fam)


def encode_audio(cfg: ModelConfig, params: Params, frames: jnp.ndarray,
                 remat: bool = False) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, F, d)."""
    F = frames.shape[1]
    h = frames + layers.sinusoidal_positions(F, cfg.d_model)[None]
    positions = jnp.arange(F)

    def body(x, blk):
        x = x + layers.attention(cfg, blk["attn"],
                                 layers.apply_norm(cfg, blk["ln_attn"], x),
                                 positions, causal=False, use_rope=False)
        x = x + layers.apply_mlp(cfg, blk["mlp"],
                                 layers.apply_norm(cfg, blk["ln_mlp"], x))
        return x, 0.0

    h, _ = _scan_blocks(body, h, params["enc_blocks"], remat)
    return layers.apply_norm(cfg, params["enc_norm"], h)


def unembed(cfg: ModelConfig, params: Params, h: jnp.ndarray) -> jnp.ndarray:
    h = layers.apply_norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    return sharding.constrain_logits(logits)


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. batch: tokens (B,S) [+ patch_embeds | frames].

    Returns (logits (B, S, vocab), aux_loss).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = sharding.constrain_tokens(params["embed"][tokens])
    encoder_out = None
    prefix = 0

    if cfg.family == "vlm":
        patches = batch["patch_embeds"] @ params["patch_proj"]
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)
        prefix = patches.shape[1]
    elif cfg.family == "audio":
        encoder_out = encode_audio(cfg, params, batch["frames"], remat)
        h = h + layers.sinusoidal_positions(S, cfg.d_model)[None]

    h, orig_len = _pad_to_multiple(h, layers.Q_CHUNK
                                   if h.shape[1] >= layers.CHUNKED_ATTN_THRESHOLD
                                   else 1)
    positions = jnp.arange(h.shape[1])
    h, aux = backbone(cfg, params, h, positions, remat, encoder_out)
    h = h[:, prefix: prefix + S]
    logits = unembed(cfg, params, h)
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    """Next-token cross-entropy (labels = batch['labels'])."""
    logits, aux = forward(cfg, params, batch, remat=True)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # Gather-free gold-logit extraction: elementwise mask + reduce stays local
    # on a vocab-sharded logits tensor (no all-gather of the logits).
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    nll = jnp.mean(logz - gold)
    return nll + MOE_AUX_COEF * aux


# ---------------------------------------------------------------------------
# KV / state caches + single-token decode
# ---------------------------------------------------------------------------

def kv_store_dtype(cfg: ModelConfig):
    """DENSE KV-cache storage dtype (bf16 default; f8 halves bytes).

    The SCLAD values ("int8"/"fp8") only change the PAGED pool layout
    (``init_paged_cache`` — compressed payload + scale leaves); dense
    stripes (wave mode, hybrid/audio caches) keep the bf16 default under
    them, so every family stays servable at any ``kv_dtype``.
    """
    return jnp.float8_e4m3fn if cfg.kv_dtype == "f8" else DTYPE


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    fam = cfg.family
    KVD = kv_store_dtype(cfg)
    hk, hd, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    if fam in ("dense", "moe", "vlm"):
        ctx = max_len + (cfg.num_patches if fam == "vlm" else 0)
        return {
            "k": jnp.zeros((L, batch, ctx, hk, hd), KVD),
            "v": jnp.zeros((L, batch, ctx, hk, hd), KVD),
        }
    if fam == "ssm":
        one = ssm_lib.init_mamba_cache(cfg, batch)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), one)
    if fam == "hybrid":
        groups, per, tail = _hybrid_layout(cfg)
        one = ssm_lib.init_mamba_cache(cfg, batch)
        c = {
            "groups": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (groups, per) + x.shape), one),
            "attn_k": jnp.zeros((groups, batch, max_len, hk, hd), KVD),
            "attn_v": jnp.zeros((groups, batch, max_len, hk, hd), KVD),
        }
        if tail:
            c["tail"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (tail,) + x.shape), one)
        return c
    if fam == "audio":
        F = cfg.encdec.encoder_seq_len
        return {
            "k": jnp.zeros((L, batch, max_len, hk, hd), KVD),
            "v": jnp.zeros((L, batch, max_len, hk, hd), KVD),
            "cross_k": jnp.zeros((L, batch, F, hk, hd), KVD),
            "cross_v": jnp.zeros((L, batch, F, hk, hd), KVD),
        }
    raise ValueError(fam)


def init_paged_cache(cfg: ModelConfig, num_blocks: int,
                     block_size: int, mesh=None) -> Params:
    """KV cache as a pool of fixed-size token blocks (attention families).

    Layout (L, num_blocks, block_size, Hk, hd): block ``b`` holds
    ``block_size`` consecutive token positions of whichever sequence(s)
    reference it per the host-side ``serving.paged.BlockStore`` — with
    prefix caching a block can appear in SEVERAL lanes' tables at once
    (ref-counted, read-only sharing), and retired blocks keep their payload
    while they sit in the store's LRU pool.  Block 0 is the trash block
    dead lanes write into.  ``layers.attention_decode`` and
    ``prefill_slots`` address the pool through per-row block tables; writes
    must target blocks the store reports exclusive (the engine's
    copy-on-write barrier guarantees this — see ``copy_cache_block``).

    With a SCLAD ``cfg.kv_dtype`` ("int8" / "fp8") the pool is stored
    compressed: the k/v leaves hold the quantized payload and two extra
    fp32 leaves ``k_scale`` / ``v_scale`` of shape (L, N, bs, Hk) hold the
    per-position-per-head scales (``models.kv_quant``).  Every pool
    reader/writer — ``layers.attention_decode``, ``prefill_slots``, the
    flash kernels and their jnp references — carries the scale leaves
    alongside the payload; block identity (hashing, sharing, COW, LRU) is
    over the (payload, scale) pair as one unit.

    With a ``mesh`` the pool is placed per ``cache_specs(paged=True)``:
    payload and scale leaves co-sharded on the KV-head axis over ``model``
    (so the shard_map'd kernels dequantize locally), everything else
    replicated.  ``sanitize_specs`` drops the head sharding when Hk does
    not divide the axis — the same gate ``attn_shard_size`` applies at
    dispatch, so placement and dispatch always agree.
    """
    fam = cfg.family
    if fam not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"paged KV caches cover the attention families, not {fam!r}")
    hk, hd, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    if kv_quant.is_quantized(cfg.kv_dtype):
        KVD = kv_quant.payload_dtype(cfg.kv_dtype)
        cache = {
            "k": jnp.zeros((L, num_blocks, block_size, hk, hd), KVD),
            "v": jnp.zeros((L, num_blocks, block_size, hk, hd), KVD),
            # All-zero payload rows carry scale 1.0 by the quantizer's
            # convention; zeros-init matches (0 * 1.0 == 0) but any init
            # works — unwritten positions are masked by lengths.
            "k_scale": jnp.ones((L, num_blocks, block_size, hk),
                                jnp.float32),
            "v_scale": jnp.ones((L, num_blocks, block_size, hk),
                                jnp.float32),
        }
    else:
        KVD = kv_store_dtype(cfg)
        cache = {
            "k": jnp.zeros((L, num_blocks, block_size, hk, hd), KVD),
            "v": jnp.zeros((L, num_blocks, block_size, hk, hd), KVD),
        }
    if mesh is None:
        return cache
    with sharding.use_axes(mesh):
        specs = sharding.cache_specs(cfg, cache, None, 1, paged=True)
        specs = sharding.sanitize_specs(specs, cache)
    return jax.device_put(cache, sharding.to_shardings(mesh, specs))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


@functools.lru_cache(maxsize=1)
def _copy_cache_block_fn():
    # Jitted with the cache DONATED so XLA aliases the pool and the copy is
    # an in-place one-block scatter — un-jitted `.at[].set` would
    # materialize a full copy of the whole pool per COW event.  (CPU has no
    # donation; skip it there to avoid warnings.)
    donate = (0,) if jax.default_backend() != "cpu" else ()

    def body(cache, src, dst):
        # tree.map so the quantized layout's scale leaves ride along with
        # the payload — a COW'd block is the (payload, scale) pair.
        return jax.tree.map(lambda x: x.at[:, dst].set(x[:, src]), cache)

    return jax.jit(body, donate_argnums=donate)


def copy_cache_block(cache: Params, src: int, dst: int) -> Params:
    """Copy one paged-KV block's payload across all layers (``src -> dst``).

    The copy-on-write half of block sharing: when the host-side
    ``serving.paged.BlockStore`` swaps a shared block for a fresh exclusive
    one (``ensure_writable``), the device payload must follow before the
    lane's next scatter.  Rare by construction — full-block-only sharing
    puts writes past the shared prefix — but each event must still cost
    O(block), not O(pool): the copy runs jitted with the pool donated, and
    src/dst passed as traced scalars (one compile covers every block pair).
    """
    return _copy_cache_block_fn()(cache, jnp.int32(src), jnp.int32(dst))


def prefill_slots(cfg: ModelConfig, params: Params, cache: Params,
                  tokens: jnp.ndarray, lengths: jnp.ndarray,
                  block_tables: jnp.ndarray,
                  start: Optional[jnp.ndarray] = None,
                  patch_embeds: Optional[jnp.ndarray] = None,
                  all_logits: bool = False,
                  mesh=None) -> Tuple[jnp.ndarray, Params]:
    """Prefill one left-padded prompt CHUNK per row into a paged KV cache.

    The continuous-batching admission path: a group of requests with
    *different* prompt lengths is left-padded to a common bucket length and
    prefilled in one call, each row writing its K/V into its own cache
    blocks at its own offset.  Long prompts are processed in fixed-size
    chunks across several calls (interleaved with decode iterations by the
    engine, so admission never stalls in-flight decodes): the first call
    passes ``start=None``, later calls pass each row's already-cached token
    count and the chunk attends to the cached context through its block
    table.

    Prefix caching rides the same ``start`` mechanism: a request admitted
    with ``cached_len`` prefix tokens already resident (shared blocks
    matched by ``serving.paged.BlockStore``) enters here as a continuation
    with ``start = cached_len`` — only the uncached tail is embedded and
    written, while the shared context (including a cached vlm patch prefix)
    is read through the block table.  The writes land strictly at
    positions >= ``start``, i.e. past every shared block.

    Per layer, the attention core AND the new-token K/V scatter dispatch
    through ``kernels.flash_prefill.ops.prefill_attention``, selected by
    ``cfg.attn_kernel``: on the kernel path the cached context is streamed
    block-by-block straight out of the shared pool (scalar-prefetched
    table walk — no dense per-lane ``k_pool[block_tables]`` copy, no dense
    (Bn, S, S) mask) and the compacted chunk K/V is scattered into the
    pool inside the same kernel invocation; the reference path gathers and
    scatters host-side, bit-exact with the pre-kernel engine.

    tokens:  (Bn, P) int32, each row's chunk LEFT-padded to P;
    lengths: (Bn,) true token count of this chunk (<= P);
    block_tables: (Bn, T) int32 rows of the paged block table
        (``serving.paged.BlockStore.block_table()``), grown by the
        caller to cover this chunk's writes;
    start:   None => every row starts at cache position 0 (first chunk; the
        vlm patch prefix is embedded and written here); else (Bn,) int32
        cache positions already filled per row (INCLUDING any vlm prefix);
    patch_embeds: (Bn, num_patches, d) for the vlm family (zeros if None;
        ignored on continuation chunks).

    Pad positions are masked out of the attention (so dense/vlm results are
    bit-identical to unpadded single-request prefill; for moe, co-admitted
    requests share expert-capacity buffers, so under *tight* capacity
    factors drops — and therefore logits — can differ from the solo run)
    and pad RoPE phases are clipped to each row's first real position.
    Per layer each row's K/V is left-compacted ([patches | chunk | junk])
    and scattered through its block table at positions ``start + i``;
    junk-tail writes are dropped, so nothing lands outside the row's own
    blocks.

    Families: dense / moe / vlm (attention KV caches).  MoE blocks receive
    the real-token mask as routing validity, so pad tokens consume no
    expert capacity and cannot displace live tokens.
    Returns (last-real-token logits (Bn, vocab), updated cache).  The
    logits are only meaningful on a row's FINAL chunk.

    ``all_logits=True`` instead returns per-position logits (Bn, P, vocab)
    over the CHUNK's token columns (the vlm patch prefix is excluded) —
    the speculative-decode verify entry point: the engine feeds
    [last-accepted | drafts] as a continuation chunk and needs the logits
    AT every drafted position to check each draft against what plain
    decode would have sampled.  Left padding means row positions < pad are
    junk; callers mask by ``lengths``.  The K/V write-through is identical
    either way (drafted K/V lands in the pool optimistically).
    """
    fam = cfg.family
    if fam not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"prefill_slots supports attention KV caches, not family {fam!r}")
    Bn, P = tokens.shape
    first = start is None
    pad = (P - lengths).astype(jnp.int32)  # (Bn,)
    h = params["embed"][tokens]
    prefix = 0
    if fam == "vlm" and first:
        if patch_embeds is None:
            patch_embeds = jnp.zeros((Bn, cfg.num_patches, cfg.d_model),
                                     DTYPE)
        patches = patch_embeds @ params["patch_proj"]
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)
        prefix = cfg.num_patches
    S = prefix + P
    start_v = jnp.zeros((Bn,), jnp.int32) if first \
        else start.astype(jnp.int32)

    tok_pos = start_v[:, None] + prefix \
        + jnp.maximum(jnp.arange(P)[None] - pad[:, None], 0)
    if prefix:
        positions = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(prefix)[None], (Bn, prefix)),
             tok_pos], axis=1)
    else:
        positions = tok_pos  # (Bn, S)

    # Real-token mask for MoE routing validity: pads/dead slots consume
    # no expert capacity.  (The attention-side causal/left-pad masking now
    # lives in kernels.flash_prefill, derived from the same scalars — on
    # the kernel path no dense (Bn, S, S) mask is ever materialized.)
    sidx = jnp.arange(S)
    real_key = (sidx[None] < prefix) | (sidx[None] >= prefix + pad[:, None])
    lengths = jnp.asarray(lengths, jnp.int32)

    quantized = kv_quant.is_quantized(cfg.kv_dtype)

    if quantized:
        def body(x, blk_kv):
            blk, kc, vc, ksc, vsc = blk_kv
            q, k, v = _attn_qkv(cfg, blk, x, positions)
            a, kc, vc, ksc, vsc = prefill_ops.prefill_attention(
                q, k, v, kc, vc, lengths, block_tables,
                start=None if first else start_v, prefix=prefix,
                kernel=cfg.attn_kernel, kv_scales=(ksc, vsc),
                kv_dtype=cfg.kv_dtype, mesh=mesh)
            x, _ = _attn_post(cfg, blk, x, a, moe_valid=real_key)
            return x, (kc, vc, ksc, vsc)

        h, (ks, vs, kss, vss) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        cache = dict(cache, k=ks, v=vs, k_scale=kss, v_scale=vss)
    else:
        def body(x, blk_kv):
            blk, kc, vc = blk_kv
            q, k, v = _attn_qkv(cfg, blk, x, positions)
            a, kc, vc = prefill_ops.prefill_attention(
                q, k, v, kc, vc, lengths, block_tables,
                start=None if first else start_v, prefix=prefix,
                kernel=cfg.attn_kernel, mesh=mesh)
            x, _ = _attn_post(cfg, blk, x, a, moe_valid=real_key)
            return x, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs)
    # Left padding aligns every row's last REAL token at index S-1.
    if all_logits:
        logits = unembed(cfg, params, h[:, prefix:])  # (Bn, P, vocab)
    else:
        logits = unembed(cfg, params, h[:, -1])
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jnp.ndarray, position: jnp.ndarray,
                active: Optional[jnp.ndarray] = None,
                block_tables: Optional[jnp.ndarray] = None,
                mesh=None) -> Tuple[jnp.ndarray, Params]:
    """One autoregressive step. tokens: (B, 1); position: scalar int32 OR a
    per-row (B,) int32 vector (index of each row's new token within the
    cache context — continuous batching runs rows at different offsets).
    Vector positions are supported for the dense/moe/vlm/ssm/hybrid
    families; audio requires a scalar.

    active: optional (B,) bool — rows marked False are dead lanes (retired
    serving slots).  For the moe family they are excluded from expert
    capacity so they cannot displace live rows' tokens; other families
    ignore the mask (dead lanes are already masked out by position).

    block_tables: optional (B, T) int32 — the cache is a paged block pool
    (``init_paged_cache``) addressed per row through this table instead of
    a dense (L, B, ctx) stripe (dense/moe/vlm only).  Per layer, attention
    reads dispatch through ``kernels.flash_decode.ops.decode_attention``
    with the (pool, block_tables, lengths = position + 1) calling
    convention: on the kernel path (``cfg.attn_kernel``) each row's
    blocks are walked through the table straight out of the shared pool —
    no dense per-lane copy of the pool is materialized.

    Returns (logits (B, 1, vocab), updated cache).
    """
    fam = cfg.family
    h = params["embed"][tokens]
    B = tokens.shape[0]
    if block_tables is not None and fam not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"paged decode covers the attention families, not {fam!r}")

    if fam in ("dense", "moe", "vlm"):
        pos = position + (cfg.num_patches if fam == "vlm" else 0)
        quantized = block_tables is not None \
            and kv_quant.is_quantized(cfg.kv_dtype)

        def ffn(x, blk):
            if fam == "moe":
                y, _ = moe_lib.apply_moe(
                    cfg, blk["moe"], layers.apply_norm(cfg, blk["ln_mlp"], x),
                    valid=None if active is None else active[:, None])
                return x + y
            return x + layers.apply_mlp(
                cfg, blk["mlp"], layers.apply_norm(cfg, blk["ln_mlp"], x))

        if quantized:
            def body(x, blk_kv):
                blk, kc, vc, ksc, vsc = blk_kv
                a, kc, vc, ksc, vsc = layers.attention_decode(
                    cfg, blk["attn"],
                    layers.apply_norm(cfg, blk["ln_attn"], x), kc, vc, pos,
                    block_tables=block_tables, kv_scales=(ksc, vsc),
                    mesh=mesh)
                x = ffn(x + a, blk)
                return x, (kc, vc, ksc, vsc)

            h, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
                body, h, (params["blocks"], cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]))
            new_cache = {"k": k_new, "v": v_new,
                         "k_scale": ks_new, "v_scale": vs_new}
        else:
            def body(x, blk_kv):
                blk, kc, vc = blk_kv
                a, kc, vc = layers.attention_decode(
                    cfg, blk["attn"],
                    layers.apply_norm(cfg, blk["ln_attn"], x), kc, vc, pos,
                    block_tables=block_tables, mesh=mesh)
                x = ffn(x + a, blk)
                return x, (kc, vc)

            h, (k_new, v_new) = jax.lax.scan(
                body, h, (params["blocks"], cache["k"], cache["v"]))
            new_cache = {"k": k_new, "v": v_new}
    elif fam == "ssm":
        def body(x, blk_c):
            blk, c = blk_c
            y, c = ssm_lib.apply_mamba_decode(
                cfg, blk["mamba"], c, layers.apply_norm(cfg, blk["ln"], x))
            return x + y, c

        h, new_c = jax.lax.scan(body, h, (params["blocks"], cache))
        new_cache = new_c
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def ssm_body(x, blk_c):
            blk, c = blk_c
            y, c = ssm_lib.apply_mamba_decode(
                cfg, blk["mamba"], c, layers.apply_norm(cfg, blk["ln"], x))
            return x + y, c

        def group_body(x, xs):
            grp, gc, kc, vc = xs
            x, gc = jax.lax.scan(ssm_body, x, (grp, gc))
            a, kc, vc = layers.attention_decode(
                cfg, shared["attn"],
                layers.apply_norm(cfg, shared["ln_attn"], x), kc, vc, position)
            x = x + a
            x = x + layers.apply_mlp(
                cfg, shared["mlp"], layers.apply_norm(cfg, shared["ln_mlp"], x))
            return x, (gc, kc, vc)

        h, (gc, kc, vc) = jax.lax.scan(
            group_body, h,
            (params["groups"], cache["groups"], cache["attn_k"], cache["attn_v"]))
        new_cache = {"groups": gc, "attn_k": kc, "attn_v": vc}
        if "tail" in cache:
            h, tc = jax.lax.scan(ssm_body, h, (params["tail"], cache["tail"]))
            new_cache["tail"] = tc
    elif fam == "audio":
        h = h + layers.sinusoidal_positions(
            int(cache["k"].shape[2]), cfg.d_model)[position][None, None]

        def body(x, xs):
            blk, kc, vc, ck, cv = xs
            a, kc, vc = layers.attention_decode(
                cfg, blk["self_attn"],
                layers.apply_norm(cfg, blk["ln_self"], x), kc, vc, position,
                use_rope=False)
            x = x + a
            x = x + layers.cross_attention(
                cfg, blk["cross_attn"],
                layers.apply_norm(cfg, blk["ln_cross"], x),
                ck.astype(x.dtype), cv.astype(x.dtype))
            x = x + layers.apply_mlp(
                cfg, blk["mlp"], layers.apply_norm(cfg, blk["ln_mlp"], x))
            return x, (kc, vc)

        h, (k_new, v_new) = jax.lax.scan(
            body, h, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, k=k_new, v=v_new)
    else:
        raise ValueError(fam)

    logits = unembed(cfg, params, h)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            max_len: int) -> Tuple[jnp.ndarray, Params]:
    """Process the prompt, returning (last-position logits (B, vocab), cache).

    Implemented as forward + recompute of K/V into the cache for attention
    families; SSM caches carry the final state from the chunked scan.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    fam = cfg.family
    cache = init_cache(cfg, B, max_len)
    h = params["embed"][tokens]
    prefix = 0
    encoder_out = None
    if fam == "vlm":
        patches = batch["patch_embeds"] @ params["patch_proj"]
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)
        prefix = patches.shape[1]
    elif fam == "audio":
        encoder_out = encode_audio(cfg, params, batch["frames"])
        h = h + layers.sinusoidal_positions(S, cfg.d_model)[None]

    positions = jnp.arange(h.shape[1])
    S_ctx = h.shape[1]

    if fam in ("dense", "moe", "vlm", "audio"):
        blocks = params["blocks"] if fam != "audio" else params["dec_blocks"]
        kvd = kv_store_dtype(cfg)

        if fam == "audio":
            def body(x, blk):
                xn = layers.apply_norm(cfg, blk["ln_self"], x)
                q, k, v = layers._project_qkv(cfg, blk["self_attn"], xn, xn)
                if S_ctx >= layers.CHUNKED_ATTN_THRESHOLD and \
                        S_ctx % layers.Q_CHUNK == 0:
                    a = layers.chunked_attention(q, k, v, causal=True)
                else:
                    mask = jnp.tril(
                        jnp.ones((S_ctx, S_ctx), bool))[None, None, None]
                    a = layers._sdpa(cfg, q, k, v, mask)
                x = x + a @ blk["self_attn"]["wo"]
                F = encoder_out.shape[1]
                ck = (encoder_out @ blk["cross_attn"]["wk"]).reshape(
                    B, F, cfg.num_kv_heads, cfg.head_dim)
                cv = (encoder_out @ blk["cross_attn"]["wv"]).reshape(
                    B, F, cfg.num_kv_heads, cfg.head_dim)
                xc = layers.apply_norm(cfg, blk["ln_cross"], x)
                x = x + layers.cross_attention(cfg, blk["cross_attn"], xc,
                                               ck, cv)
                x = x + layers.apply_mlp(
                    cfg, blk["mlp"], layers.apply_norm(cfg, blk["ln_mlp"], x))
                return x, dict(k=k.astype(kvd), v=v.astype(kvd),
                               cross_k=ck.astype(kvd), cross_v=cv.astype(kvd))
        else:
            # K/V for the cache is recomputed by the shared block body
            # (weights are cheap to re-apply and this keeps the layer math
            # single-sourced with backbone/prefill_slots).
            def body(x, blk):
                x, k, v, _ = _attn_block_body(cfg, blk, x, positions)
                return x, dict(k=k.astype(kvd), v=v.astype(kvd))

        h, kv = jax.lax.scan(body, h, blocks)
        pad = cache["k"].shape[2] - S_ctx
        k_full = jnp.pad(kv["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v_full = jnp.pad(kv["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = dict(cache, k=k_full, v=v_full)
        if fam == "audio":
            cache["cross_k"] = kv["cross_k"]
            cache["cross_v"] = kv["cross_v"]
    elif fam == "ssm":
        def body(x, blk):
            y, st = ssm_lib.apply_mamba_block(
                cfg, blk["mamba"], layers.apply_norm(cfg, blk["ln"], x))
            return x + y, st

        h, states = jax.lax.scan(body, h, params["blocks"])
        cache = states  # stacked {"state", "conv"} matches init_cache layout
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def ssm_body(x, blk):
            y, st = ssm_lib.apply_mamba_block(
                cfg, blk["mamba"], layers.apply_norm(cfg, blk["ln"], x))
            return x + y, st

        def group_body(x, grp):
            x, st = jax.lax.scan(ssm_body, x, grp)
            xn = layers.apply_norm(cfg, shared["ln_attn"], x)
            q, k, v = layers._project_qkv(cfg, shared["attn"], xn, xn)
            q = layers.apply_rope(cfg, q, positions)
            k = layers.apply_rope(cfg, k, positions)
            if S_ctx >= layers.CHUNKED_ATTN_THRESHOLD and \
                    S_ctx % layers.Q_CHUNK == 0:
                a = layers.chunked_attention(q, k, v, causal=True)
            else:
                mask = jnp.tril(jnp.ones((S_ctx, S_ctx), bool))[None, None, None]
                a = layers._sdpa(cfg, q, k, v, mask)
            x = x + a @ shared["attn"]["wo"]
            x = x + layers.apply_mlp(
                cfg, shared["mlp"], layers.apply_norm(cfg, shared["ln_mlp"], x))
            return x, (st, k.astype(kv_store_dtype(cfg)),
                       v.astype(kv_store_dtype(cfg)))

        h, (gst, gk, gv) = jax.lax.scan(group_body, h, params["groups"])
        pad = cache["attn_k"].shape[2] - S_ctx
        cache["attn_k"] = jnp.pad(gk, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["attn_v"] = jnp.pad(gv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["groups"] = gst
        if "tail" in params:
            h, tst = jax.lax.scan(ssm_body, h, params["tail"])
            cache["tail"] = tst
    else:
        raise ValueError(fam)

    logits = unembed(cfg, params, h[:, -1])
    return logits, cache
