"""SCLAD KV quantization: Store-as-Compressed, Load-as-Dense block payloads.

CC-MEM's signature mechanism (PAPER.md §CC-MEM) keeps payloads compressed
in the memory system and expands them on the load path, so compute units
only ever see dense values.  The repo already models SCLAD for *weights*
(``kernels.sclad_matmul`` / ``core.sparsity``); this module is the KV-cache
side: the paged serving pool (``model.init_paged_cache``) stores an int8 /
fp8 payload plus per-position-per-head fp32 scales, and every reader —
the jnp references AND the Pallas kernels — dequantizes on load.

ONE quantization definition, shared by every writer:

  * ``layers.attention_decode``   — the decode-step single-token scatter;
  * ``kernels.flash_prefill.ref.scatter_new_kv_ref`` — the host-side
    chunk scatter (``attn_kernel="off"`` / "auto" off-TPU);
  * ``kernels.flash_prefill.flash_prefill`` — the fused in-kernel scatter
    (quantizes the chunk's new K/V in VMEM before the
    ``input_output_aliases`` write-back).

The arithmetic is deliberately PATH-INDEPENDENT: each token's payload and
scale are a pure function of that token's dense K/V row (fp32 view of the
compute-dtype value, amax over the head dim, symmetric round-to-nearest).
No running block amax, no requantization — so the compressed bytes a token
leaves in the pool are bitwise identical whether it arrived via a first
chunk, a continuation chunk, a decode step or a preemption recompute.
That bit-determinism is what makes the ``BlockStore`` hash chain (token
ids + chain root) a sound content address FOR the compressed payload, and
what lets kernel-vs-reference tests compare pools bitwise.

Consequently the compute side can be made path-independent too: readers
always observe a token through ``dequantize(quantize(x))``.  The prefill
paths "fake-quantize" the chunk's own in-flight K/V before attending to it
(see ``fake_quant``), so a key scores identically whether it is read from
the quantized pool or seen in-chunk — preserving the serving engine's
greedy bit-identity matrix (prefix cache on/off, chunk sizes, preemption
recompute) under quantization.

Scales are per (token position, kv head): shape ``pool.shape[:-1]`` — for
the (N, bs, Hk, D) pool that is (N, bs, Hk) fp32.  Per-head granularity
matches the "per-block-per-head scale metadata" the CC-MEM decompressor
would hold; per-position granularity is what keeps writes path-independent
(a per-block amax would depend on write history and stale recycled
content).  fp8 payloads reuse the float8_e4m3fn dtype the dense-cache
``kv_dtype="f8"`` path already ships.
"""
from __future__ import annotations

import jax.numpy as jnp

#: Every accepted ``ModelConfig.kv_dtype`` spelling.
#:   "fp"   — fp-exact pool (storage dtype via ``model.kv_store_dtype``);
#:   "bf16" — legacy alias of "fp" (the pre-SCLAD default spelling);
#:   "f8"   — legacy DENSE-cache storage override (float8 stripes, no
#:            scales; paged pools treat it as fp-exact f8 storage);
#:   "int8" — SCLAD paged pool: int8 payload + fp32 scales;
#:   "fp8"  — SCLAD paged pool: float8_e4m3fn payload + fp32 scales.
KV_DTYPES = ("fp", "bf16", "f8", "int8", "fp8")

#: The subset that stores the paged pool as compressed payload + scales.
QUANTIZED_KV_DTYPES = ("int8", "fp8")


def is_quantized(kv_dtype: str) -> bool:
    """True iff the paged pool stores compressed payload + scale leaves."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype {kv_dtype!r} not in {KV_DTYPES}")
    return kv_dtype in QUANTIZED_KV_DTYPES


def payload_dtype(kv_dtype: str):
    """On-device dtype of the compressed pool payload."""
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8":
        return jnp.float8_e4m3fn
    raise ValueError(f"{kv_dtype!r} is not a quantized kv_dtype")


def qmax(kv_dtype: str) -> float:
    """Largest representable payload magnitude the scale normalizes to."""
    if kv_dtype == "int8":
        return 127.0
    if kv_dtype == "fp8":
        return 448.0  # float8_e4m3fn max normal
    raise ValueError(f"{kv_dtype!r} is not a quantized kv_dtype")


def quantize(x: jnp.ndarray, kv_dtype: str):
    """Compress ``x`` (..., D) -> (payload (..., D), scales (...,) fp32).

    Symmetric per-row (last axis) quantization: ``scale = amax / qmax``
    (1.0 for all-zero rows so dequantization is exact), payload
    ``round(x / scale)`` for int8 (|q| <= 127 by construction — no clip
    needed) or a saturating fp8 cast.  All arithmetic runs in fp32 from
    the compute-dtype input, and is reproduced operation-for-operation by
    the fused in-kernel scatter — the two writers are BITWISE identical.
    """
    qm = qmax(kv_dtype)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    # amax * (1/qm), NOT amax / qm: XLA rewrites division by a constant
    # into reciprocal multiplication under jit but not in eager mode, so
    # a division here would make the scale depend on the tracing context
    # (1-ulp drift between the engine's jitted writers and eagerly-built
    # test pools).  An explicit constant multiply is bitwise identical
    # everywhere.  round(xf/scale) still can't exceed qmax + 0.5, so the
    # int8 cast below stays clip-free.
    scale = jnp.where(amax > 0, amax * jnp.float32(1.0 / qm),
                      1.0).astype(jnp.float32)
    q = xf / scale[..., None]
    if kv_dtype == "int8":
        payload = jnp.round(q).astype(jnp.int8)
    else:
        payload = q.astype(jnp.float8_e4m3fn)
    return payload, scale


def dequantize(payload: jnp.ndarray, scale: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    """Expand payload (..., D) with scales (...,) back to dense ``dtype``.

    The load-path half of SCLAD: ``payload * scale`` in fp32, then one
    cast to the requested compute dtype — the SAME cast chain the kernels
    use, so a value dequantized host-side and in-kernel agrees bitwise in
    fp32 (and to the cast's rounding in bf16).
    """
    out = payload.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
    return out.astype(dtype)


def fake_quant(x: jnp.ndarray, kv_dtype: str) -> jnp.ndarray:
    """``dequantize(quantize(x))`` in x's dtype — the quantization a reader
    will observe once ``x`` lands in the pool.

    The prefill attention paths run the chunk's own K/V through this
    before attending, so a token's keys/values score identically in-chunk
    and from-pool: greedy outputs stay bit-identical across chunk sizes,
    prefix-cache hits and preemption recomputes even under quantization.
    """
    payload, scale = quantize(x, kv_dtype)
    return dequantize(payload, scale, x.dtype)
