"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Implements the chunked SSD algorithm in pure jnp: intra-chunk contributions in
the quadratic "attention-like" dual form, inter-chunk contributions via a
linear state recurrence (lax.scan over chunks), plus the O(1)-state single
token decode update.  Heads are sharded over the ``model`` mesh axis.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import DTYPE, dense_init, rmsnorm_gated

Params = Dict[str, jnp.ndarray]


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.d_inner(cfg.d_model)
    H = s.nheads(cfg.d_model)
    G, N, P = s.ngroups, s.state_size, s.head_dim
    conv_dim = d_inner + 2 * G * N
    return d_inner, H, G, N, P, conv_dim


def init_mamba_block(cfg: ModelConfig, key) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, G, N, P, conv_dim = dims(cfg)
    d_in_proj = 2 * d_inner + 2 * G * N + H
    ks = jax.random.split(key, 3)
    # A in [1, 16) as in the reference implementation.
    a0 = jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
    return {
        "in_proj": dense_init(ks[0], (d, d_in_proj)),
        "conv_w": dense_init(ks[1], (s.conv_kernel, conv_dim), in_axis=0),
        "conv_b": jnp.zeros((conv_dim,), DTYPE),
        "A_log": jnp.log(a0),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), DTYPE),
        "out_proj": dense_init(ks[2], (d_inner, d)),
    }


def _causal_conv(w: jnp.ndarray, b: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d via k shifted adds. x: (B, S, C); w: (k, C)."""
    k = w.shape[0]
    out = x * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return jax.nn.silu(out + b)


def _segsum_decay(a_cum: jnp.ndarray) -> jnp.ndarray:
    """exp(a_cum[..., i] - a_cum[..., j]) masked to i >= j (lower-tri).

    a_cum: (..., Q) -> (..., Q, Q).
    """
    Q = a_cum.shape[-1]
    diff = a_cum[..., :, None] - a_cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # Mask BEFORE exp: exp of the (positive) upper-triangle diffs overflows
    # to inf, and inf * 0 cotangents would poison the backward pass.
    return jnp.exp(jnp.where(mask, diff, -jnp.inf))


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """SSD scan.

    x:  (b, S, H, P)  — inputs per head
    dt: (b, S, H)     — positive step sizes (already softplus'ed)
    A:  (H,)          — negative decay rates
    B:  (b, S, G, N)  — input projections (G groups, H % G == 0)
    C:  (b, S, G, N)  — output projections
    Returns (y (b, S, H, P), final_state (b, H, P, N)).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Hg = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    f32 = jnp.float32
    xdt = (x.astype(f32) * dt[..., None].astype(f32))  # (b,S,H,P)
    a_bar = dt.astype(f32) * A.astype(f32)  # (b,S,H)

    # Chunked views.
    def ch(t, shape):
        return t.reshape((b, nc, Q) + shape)

    x_c = ch(xdt, (G, Hg, P))
    a_c = ch(a_bar, (G, Hg))  # (b,nc,Q,G,Hg)
    B_c = ch(B.astype(f32), (G, N))
    C_c = ch(C.astype(f32), (G, N))

    a_cum = jnp.cumsum(a_c, axis=2)  # (b,nc,Q,G,Hg)
    a_last = a_cum[:, :, -1]  # (b,nc,G,Hg)

    # Intra-chunk (quadratic dual form).
    L = _segsum_decay(jnp.moveaxis(a_cum, 2, -1))  # (b,nc,G,Hg,Q,Q)
    y_diag = jnp.einsum(
        "bcqgn,bckgn,bcghqk,bckghp->bcqghp", C_c, B_c, L, x_c
    )

    # Chunk input states: contribution of each chunk to the carried state.
    decay_states = jnp.exp(a_last[:, :, None] - a_cum)  # (b,nc,Q,G,Hg)
    states = jnp.einsum("bckgn,bckgh,bckghp->bcghpn", B_c, decay_states, x_c)

    # Inter-chunk recurrence.
    chunk_decay = jnp.exp(a_last)  # (b,nc,G,Hg)
    if initial_state is None:
        s0 = jnp.zeros((b, G, Hg, P, N), f32)
    else:
        s0 = initial_state.reshape(b, G, Hg, P, N).astype(f32)

    def step(s, inp):
        new, dec = inp  # (b,G,Hg,P,N), (b,G,Hg)
        s_prev = s
        s = s * dec[..., None, None] + new
        return s, s_prev

    states_t = jnp.moveaxis(states, 1, 0)  # (nc,b,G,Hg,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)
    final, prev_states = jax.lax.scan(step, s0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,G,Hg,P,N)

    # Inter-chunk output: state at chunk start decayed to position q.
    out_decay = jnp.exp(a_cum)  # (b,nc,Q,G,Hg)
    y_off = jnp.einsum(
        "bcqgn,bcghpn,bcqgh->bcqghp", C_c, prev_states, out_decay
    )

    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, final.reshape(b, H, P, N)


def apply_mamba_block(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                      initial_state=None):
    """x: (B, S, d) -> (out (B, S, d), cache dict with final ssm 'state'
    (B,H,P,N) and raw 'conv' window (B, k-1, conv_dim))."""
    s = cfg.ssm
    d_inner, H, G, N, P, conv_dim = dims(cfg)
    B_, S_, _ = x.shape

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: d_inner + conv_dim]
    dt_raw = zxbcdt[..., -H:]

    conv_tail = xBC[:, -(s.conv_kernel - 1):, :]  # raw inputs for decode
    xBC = _causal_conv(p["conv_w"], p["conv_b"], xBC)
    x_ssm = xBC[..., :d_inner].reshape(B_, S_, H, P)
    B_ssm = xBC[..., d_inner: d_inner + G * N].reshape(B_, S_, G, N)
    C_ssm = xBC[..., d_inner + G * N:].reshape(B_, S_, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, state = ssd_chunked(x_ssm, dt, A, B_ssm, C_ssm, s.chunk_size,
                           initial_state)
    y = y + x_ssm.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, S_, d_inner).astype(x.dtype)
    y = rmsnorm_gated(p["norm_scale"], y, z)
    return y @ p["out_proj"], {"state": state, "conv": conv_tail}


# ---------------------------------------------------------------------------
# Decode (O(1) per token)
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner, H, G, N, P, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), DTYPE),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def apply_mamba_decode(cfg: ModelConfig, p: Params, cache, x: jnp.ndarray):
    """x: (B, 1, d). Returns (out (B, 1, d), cache)."""
    s = cfg.ssm
    d_inner, H, G, N, P, conv_dim = dims(cfg)
    B_ = x.shape[0]

    zxbcdt = x[:, 0] @ p["in_proj"]  # (B, d_in_proj)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: d_inner + conv_dim]
    dt_raw = zxbcdt[..., -H:]

    # Rolling conv state: window = [conv_cache, xBC].
    win = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B,k,C)
    conv_out = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_conv = win[:, 1:]

    x_ssm = xBC[..., :d_inner].reshape(B_, H, P)
    B_ssm = xBC[..., d_inner: d_inner + G * N].reshape(B_, G, N)
    C_ssm = xBC[..., d_inner + G * N:].reshape(B_, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (B,H)

    Hg = H // G
    xdt = x_ssm.astype(jnp.float32) * dt[..., None]  # (B,H,P)
    inc = jnp.einsum("bgn,bghp->bghpn", B_ssm.astype(jnp.float32),
                     xdt.reshape(B_, G, Hg, P)).reshape(B_, H, P, N)
    state = cache["state"] * dA[..., None, None] + inc
    y = jnp.einsum("bgn,bghpn->bghp", C_ssm.astype(jnp.float32),
                   state.reshape(B_, G, Hg, P, N)).reshape(B_, H, P)
    y = y + x_ssm.astype(jnp.float32) * p["D"][None, :, None]

    y = y.reshape(B_, 1, d_inner).astype(x.dtype)
    y = rmsnorm_gated(p["norm_scale"], y, z[:, None, :])
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "state": state}
