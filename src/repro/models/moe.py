"""Mixture-of-Experts FFN with token-choice top-k routing.

Dispatch is *gather-based* (argsort-free scatter of token slots into per-expert
capacity buffers) rather than the classic one-hot einsum dispatch: the einsum
formulation costs O(T * E * C * d) MACs which at trillion-token scale dwarfs
the expert FLOPs themselves, whereas gathers are bandwidth-only.  This is the
first beyond-paper efficiency decision — see DESIGN.md §3.

Sharding contract (see parallel/sharding.py): expert dim E is sharded over the
``model`` mesh axis, expert hidden dim over ``data``; tokens enter sharded over
``data`` — XLA inserts the all-to-all at the gather.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as _P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import DTYPE, dense_init

Params = Dict[str, jnp.ndarray]


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    m = cfg.moe
    c = int(math.ceil(m.num_experts_per_tok * num_tokens / m.num_experts
                      * m.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8, min 8


def init_moe(cfg: ModelConfig, key) -> Params:
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), in_axis=-2),
        "w_up": dense_init(ks[2], (E, d, f), in_axis=-2),
        "w_down": dense_init(ks[3], (E, f, d), in_axis=-2),
    }
    if m.shared_d_ff:
        p["shared"] = layers.init_mlp(cfg, ks[4], d_ff=m.shared_d_ff)
    return p


def route(cfg: ModelConfig, router_w: jnp.ndarray, x: jnp.ndarray):
    """x: (T, d) -> (weights (T,k), experts (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ router_w)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, m.num_experts_per_tok)
    weights = weights / jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss.
    T = x.shape[0]
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    one_hot = jax.nn.one_hot(experts[:, 0], m.num_experts, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)  # fraction of tokens routed (top-1)
    aux = m.num_experts * jnp.sum(me * ce)
    return weights.astype(jnp.float32), experts, aux


def _num_groups(B: int, S: int) -> int:
    """Dispatch groups: one per data shard so slot assignment stays local."""
    from repro.parallel import sharding as _sh
    dp = _sh._axes_size_hint(_sh.data_axes()) or 1
    if B % dp == 0:
        return dp
    return 1


def _dispatch_indices(cfg: ModelConfig, experts: jnp.ndarray, C: int,
                      valid: jnp.ndarray = None):
    """Assign each (group, token, k) a slot in its expert capacity buffer.

    experts: (G, T, k) int32.  valid: optional (G, T) bool — tokens marked
    False (pad tokens, retired continuous-batching lanes) are routed to the
    drop bin and consume NO expert capacity, so they cannot displace live
    tokens.  Returns (slot (G,T,k) in [0,C] (C = dropped),
    buf_tok (G, E, C) int32 index into tokens of that group, T = empty).
    """
    m = cfg.moe
    G, T, k = experts.shape
    E = m.num_experts
    flat_e = experts.reshape(G, T * k)  # token-major, k-minor
    # FIFO position of each assignment within its expert — local cumsum.
    one_hot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, T*k, E)
    if valid is not None:
        flat_v = jnp.repeat(valid, k, axis=1)  # token-major matches flat_e
        one_hot = one_hot * flat_v[..., None].astype(jnp.int32)
    pos_in_e = jnp.cumsum(one_hot, axis=1) - 1
    slot = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    slot_c = jnp.where(slot < C, slot, C)  # dropped -> sentinel C
    if valid is not None:
        slot_c = jnp.where(flat_v, slot_c, C)  # dead -> drop bin
    # Scatter token ids into (G, E, C+1); column C is the drop bin.
    buf = jnp.full((G, E, C + 1), T, jnp.int32)
    g_idx = jnp.arange(G, dtype=jnp.int32)[:, None]
    tok_ids = (jnp.arange(T * k, dtype=jnp.int32) // k)[None, :]
    buf = buf.at[jnp.broadcast_to(g_idx, flat_e.shape), flat_e, slot_c].set(
        jnp.broadcast_to(tok_ids, flat_e.shape), mode="drop")
    return slot_c.reshape(G, T, k), buf[:, :, :C]


def apply_moe(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              valid: jnp.ndarray = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss).

    valid: optional (B, S) bool — False tokens (pads, retired serving
    slots) consume no expert capacity and get zero expert output (the
    shared expert still runs on them; their output is dead anyway).
    Only the auto-partitioned path supports it — the manual-collective
    path is skipped when a mask is given.
    """
    m = cfg.moe
    B, S, d = x.shape
    if valid is None and manual_path_available(cfg, B * S):
        return apply_moe_manual(cfg, p, x)
    E = m.num_experts
    G = _num_groups(B, S)
    T = (B * S) // G  # tokens per group
    xt = x.reshape(G, T, d)
    C = capacity(cfg, T)

    weights, experts, aux = route(cfg, p["router"], xt.reshape(G * T, d))
    weights = weights.reshape(G, T, -1)
    experts = experts.reshape(G, T, -1)
    slot, buf_tok = _dispatch_indices(
        cfg, experts, C,
        valid.reshape(G, T) if valid is not None else None)

    # Gather tokens into per-expert buffers: (G, E, C, d).  Clip+mask instead
    # of a sentinel pad row: padding (T+1) would break the GSPMD tiling of the
    # token dim and force an all-gather.
    empty = buf_tok >= T  # (G, E, C)
    idx = jnp.minimum(buf_tok, T - 1)
    expert_in = jnp.take_along_axis(
        xt[:, :, None, :], idx.reshape(G, E * C, 1, 1), axis=1
    ).reshape(G, E, C, d)
    expert_in = jnp.where(empty[..., None], 0, expert_in)

    # Anchor the expert-parallel layout: E over ``data`` (matches the expert
    # weight sharding), hidden over ``model``.  The gather above is therefore
    # the all-to-all from token-sharding to expert-sharding.
    from repro.parallel import sharding as _sh
    ep = "data" if _sh.axis_size("data") > 1 else None
    tp = _sh.tp_axis()
    expert_in = _sh.constrain(expert_in, _P(None, ep, None, None))

    # Expert FFN (SwiGLU), batched over (group, expert).
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    h = _sh.constrain(h, _P(None, ep, None, tp))
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])  # (G, E, C, d)
    expert_out = _sh.constrain(expert_out, _P(None, ep, None, None))

    # Combine: scatter-add each expert slot's weighted output back to its
    # token.  A gather formulation (token -> slot) makes GSPMD all-gather
    # the expert-sharded outputs; the scatter formulation reshards the
    # updates from expert-sharding to token-sharding — an all-to-all, the
    # same wire pattern as the dispatch.  bf16 throughout (k <= 8 terms).
    k = weights.shape[-1]
    w_flat = weights.reshape(G, T * k).astype(x.dtype)
    flat_e = experts.reshape(G, T * k)
    slot_f = slot.reshape(G, T * k)
    g_idx = jnp.arange(G, dtype=jnp.int32)[:, None]
    w_slot = jnp.zeros((G, E, C + 1), x.dtype)
    w_slot = w_slot.at[jnp.broadcast_to(g_idx, flat_e.shape), flat_e,
                       slot_f].set(w_flat, mode="drop")[:, :, :C]
    contrib = expert_out * w_slot[..., None]  # (G, E, C, d), E-sharded

    tok_idx = jnp.minimum(buf_tok, T - 1).reshape(G, E * C)
    updates = jnp.where((buf_tok < T).reshape(G, E * C, 1),
                        contrib.reshape(G, E * C, d), 0)
    out = jnp.zeros((G, T, d), x.dtype).at[
        jnp.broadcast_to(g_idx, tok_idx.shape), tok_idx].add(updates)

    if "shared" in p:
        out = out + apply_shared(cfg, p["shared"], xt.reshape(G * T, d)
                                 ).reshape(G, T, d)
    return out.reshape(B, S, d), aux


def apply_shared(cfg: ModelConfig, p: Params, xt: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(xt @ p["w_gate"]) * (xt @ p["w_up"])
    return h @ p["w_down"]


def E_total(cfg: ModelConfig) -> int:
    return cfg.moe.num_experts


# ---------------------------------------------------------------------------
# Manual-collective MoE (shard_map): the §Perf H8 optimization.
#
# The auto-partitioned path pays two structural penalties at scale:
#   1. GSPMD cannot infer the token<->expert redistribution as an all-to-all
#      in every direction (the combine gather becomes an all-gather of the
#      full expert output buffer);
#   2. the tensor-parallel psum of the expert FFN runs on the
#      capacity-expanded slot space (E*C*d ~ top_k * cf * token volume).
#
# This path makes both explicit: local top-k -> lax.all_to_all over the
# expert-parallel axes -> manual-TP expert FFN (NO psum) -> reverse
# all_to_all -> local combine -> ONE psum over `model` in token space.
# Wire bytes per layer: 2 * T*d (a2a) + 2 * T*d (psum) instead of
# ~10-80x that.
# ---------------------------------------------------------------------------

def _manual_axes():
    from repro.parallel import sharding as _sh
    st = _sh.axis_state()
    ep = tuple(a for a in ("pod", "data") if st.size(a) > 1)
    tp = st.tp if st.size(st.tp) > 1 else None
    ep_n = 1
    for a in ep:
        ep_n *= st.size(a)
    tp_n = st.size(tp) if tp else 1
    return ep, ep_n, tp, tp_n


def manual_path_available(cfg: ModelConfig, T: int) -> bool:
    ep, ep_n, tp, tp_n = _manual_axes()
    m = cfg.moe
    return (ep_n > 1 and tp is not None
            and m.num_experts % ep_n == 0
            and T % ep_n == 0
            and cfg.d_ff % tp_n == 0
            and cfg.d_model % tp_n == 0)


def apply_moe_manual(cfg: ModelConfig, p: Params, x: jnp.ndarray):
    """x: (B, S, d) -> (out, aux). Requires manual_path_available()."""
    from repro.parallel import sharding as _sh_compat

    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E = m.num_experts
    ep, ep_n, tp, tp_n = _manual_axes()
    mesh = _sh_compat.current_mesh()
    T_loc = T // ep_n
    C = capacity(cfg, T_loc)
    E_loc = E // ep_n

    router_w = p["router"]
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]

    d_loc = d // tp_n

    def local(xt, rw, wg_l, wu_l, wd_l):
        # xt: (T_loc, d_loc) — the dispatch payload is sharded over `model`
        # so the expert all-to-all is NOT replicated across TP shards
        # (H8 residual (a): 16x wire saving on the dispatch direction).
        # wg_l/wu_l: (E_loc, d, f_loc); wd_l: (E_loc, f_loc, d).
        tp_i = jax.lax.axis_index(tp)

        # Routing needs full-d logits: psum of the partial router matmul
        # ((T_loc, E) fp32 — tiny). All TP shards then agree on the top-k.
        logits = jax.lax.psum(xt.astype(jnp.float32) @
                              jax.lax.dynamic_slice_in_dim(
                                  rw, tp_i * d_loc, d_loc, 0), tp)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, experts = jax.lax.top_k(probs, m.num_experts_per_tok)
        weights = weights / jnp.maximum(
            jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(experts[:, 0], E, dtype=jnp.float32),
                      axis=0)
        aux = E * jnp.sum(me * ce)

        slot, buf_tok = _dispatch_indices(
            cfg, experts[None], C)  # add a singleton group dim
        slot, buf_tok = slot[0], buf_tok[0]  # (T_loc, k), (E, C)

        # Local dispatch into (E, C, d_loc).
        empty = buf_tok >= T_loc
        idx = jnp.minimum(buf_tok, T_loc - 1)
        expert_in = xt[idx.reshape(-1)].reshape(E, C, d_loc)
        expert_in = jnp.where(empty[..., None], 0, expert_in)

        # token-shards -> expert-shards (payload d-sharded over tp).
        expert_in = jax.lax.all_to_all(
            expert_in, ep, split_axis=0, concat_axis=1, tiled=True
        )  # (E_loc, C*ep_n, d_loc)

        # Manual-TP expert FFN. Weights are d-sharded over tp (matching the
        # payload): the up-projections are d-partial and reduced ONCE at
        # h-volume; the down-projection is then exact with a d_loc-sliced
        # output, so the reverse all-to-all also carries d/tp payloads and
        # no further reduction is needed.
        g_part = jnp.einsum("ecd,edf->ecf", expert_in, wg_l)
        u_part = jnp.einsum("ecd,edf->ecf", expert_in, wu_l)
        g_full, u_full = jax.lax.psum((g_part, u_part), tp)
        h = jax.nn.silu(g_full) * u_full
        y_part = jnp.einsum("ecf,efd->ecd", h, wd_l)  # exact, d_loc output

        # expert-shards -> token-shards (d_loc payload).
        y_exact = jax.lax.all_to_all(
            y_part, ep, split_axis=1, concat_axis=0, tiled=True
        )  # (E, C, d_loc)

        # Local combine: scatter-add of weighted slots. No trailing psum —
        # y is exact, sharded over tp along d like the input.
        k = weights.shape[-1]
        w_flat = weights.reshape(T_loc * k).astype(x.dtype)
        flat_e = experts.reshape(T_loc * k)
        slot_f = slot.reshape(T_loc * k)
        w_slot = jnp.zeros((E, C + 1), x.dtype)
        w_slot = w_slot.at[flat_e, slot_f].set(w_flat, mode="drop")[:, :C]
        contrib = (y_exact * w_slot[..., None]).reshape(E * C, d_loc)
        tok_idx = jnp.minimum(buf_tok, T_loc - 1).reshape(E * C)
        contrib = jnp.where((buf_tok < T_loc).reshape(E * C, 1), contrib, 0)
        y = jnp.zeros((T_loc, d_loc), x.dtype).at[tok_idx].add(contrib)
        aux = jax.lax.pmean(aux, ep + (tp,))
        return y, aux

    P_ = _P
    fn = _sh_compat.shard_map(
        local, mesh=mesh,
        in_specs=(P_(ep, tp), P_(None, None),
                  P_(ep, tp, None), P_(ep, tp, None), P_(ep, None, tp)),
        out_specs=(P_(ep, tp), P_()),
        check_vma=False)
    out, aux = fn(x.reshape(T, d), router_w, wg, wu, wd)
    out = out.reshape(B, S, d)
    if "shared" in p:
        out = out + apply_shared(cfg, p["shared"], x.reshape(T, d)
                                 ).reshape(B, S, d)
    return out, aux
