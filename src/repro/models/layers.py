"""Core transformer layers: norms, rotary embeddings, GQA attention, MLPs.

Pure functions over explicit parameter pytrees (no flax).  All layers take a
``ModelConfig`` and operate in bf16 with fp32 accumulation where it matters
(norm statistics, softmax, loss).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis=-2, dtype=DTYPE):
    """Scaled normal init (fan-in)."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=DTYPE):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), DTYPE)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), DTYPE)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_gated(scale: jnp.ndarray, x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Mamba2 gated RMSNorm: norm(x * silu(z))."""
    xf = (x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (partial rotary supported, stablelm2 style)
# ---------------------------------------------------------------------------

def rope_frequencies(cfg: ModelConfig) -> Tuple[int, jnp.ndarray]:
    """Returns (rotary_dim, inv_freq[rotary_dim//2])."""
    rot = int(cfg.head_dim * cfg.rope_fraction)
    rot -= rot % 2
    if cfg.rope_theta <= 0 or rot == 0:
        return 0, jnp.zeros((0,), jnp.float32)
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return rot, jnp.asarray(inv)


def apply_rope(cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    rot, inv = rope_frequencies(cfg)
    if rot == 0:
        return x
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, rot/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_positions(seq_len: int, d: int) -> jnp.ndarray:
    """Whisper-style absolute sinusoidal embeddings (S, d)."""
    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    dim = np.arange(d // 2, dtype=np.float32)[None, :]
    ang = pos / np.power(10_000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), dtype=DTYPE
    )


# ---------------------------------------------------------------------------
# Attention (MHA / GQA / MQA; optional cross attention)
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key) -> Params:
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, hk * hd)),
        "wv": dense_init(ks[2], (d, hk * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), DTYPE)
        p["bk"] = jnp.zeros((hk * hd,), DTYPE)
        p["bv"] = jnp.zeros((hk * hd,), DTYPE)
    return p


def _project_qkv(cfg: ModelConfig, p: Params, xq: jnp.ndarray, xkv: jnp.ndarray):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, h, hd)
    k = k.reshape(B, Skv, hk, hd)
    v = v.reshape(B, Skv, hk, hd)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask=None):
    """q: (B,Sq,H,D), k/v: (B,Skv,Hk,D). fp32 softmax."""
    B, Sq, H, D = q.shape
    Hk = k.shape[2]
    rep = H // Hk
    qg = q.reshape(B, Sq, Hk, rep, D)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v)
    return out.reshape(B, Sq, H * D)


# Above this sequence length attention runs blockwise (flash-style online
# softmax) so peak memory is O(S * chunk) instead of O(S^2).
CHUNKED_ATTN_THRESHOLD = 2048
Q_CHUNK = 512
K_CHUNK = 1024


def chunked_attention(q, k, v, causal: bool, q_chunk=Q_CHUNK, k_chunk=K_CHUNK):
    """Blockwise attention with online softmax (pure jnp oracle of flash attn).

    q: (B, Sq, H, D); k, v: (B, Skv, Hk, D) with H % Hk == 0.
    Memory-bounded: never materializes the (Sq, Skv) score matrix.
    """
    B, Sq, H, D = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    rep = H // Hk
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Skv)
    while Sq % q_chunk:
        q_chunk //= 2
    while Skv % k_chunk:
        k_chunk //= 2
    assert q_chunk >= 1 and k_chunk >= 1
    nq, nk = Sq // q_chunk, Skv // k_chunk
    scale = 1.0 / math.sqrt(D)

    # (nq, B, qc, Hk, rep, D)
    qc = q.reshape(B, nq, q_chunk, Hk, rep, D).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, k_chunk, Hk, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, k_chunk, Hk, D).transpose(1, 0, 2, 3, 4)

    q_pos = jnp.arange(Sq).reshape(nq, q_chunk)
    k_pos = jnp.arange(Skv).reshape(nk, k_chunk)

    @jax.checkpoint
    def kv_step(carry, xs):
        acc, m, denom, qi, qp = carry
        ki, vi, kp = xs
        s = jnp.einsum("bqhrd,bkhd->bqhrk", qi, ki).astype(jnp.float32) * scale
        if causal:
            mask = qp[:, None] >= kp[None, :]  # (qc, kc)
            s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhrk,bkhd->bqhrd", p.astype(qi.dtype), vi
        ).astype(jnp.float32)
        return (acc, m_new, denom, qi, qp), None

    def q_block(args):
        qi, qp = args
        acc0 = jnp.zeros((B, q_chunk, Hk, rep, D), jnp.float32)
        m0 = jnp.full((B, q_chunk, Hk, rep), -jnp.inf, jnp.float32)
        d0 = jnp.zeros((B, q_chunk, Hk, rep), jnp.float32)
        (acc, _, denom, _, _), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0, qi, qp), (kc, vc, k_pos)
        )
        return (acc / jnp.maximum(denom, 1e-30)[..., None]).astype(q.dtype)

    out = jax.lax.map(q_block, (qc, q_pos))  # (nq, B, qc, Hk, rep, D)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H * D)
    return out


def attention(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    causal: bool = True,
    use_rope: bool = True,
) -> jnp.ndarray:
    """Full self-attention over x (B, S, d)."""
    q, k, v = _project_qkv(cfg, p, x, x)
    if use_rope:
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    from repro.parallel import sharding as _sh
    q = _sh.constrain_heads(q)
    S = x.shape[1]
    if S >= CHUNKED_ATTN_THRESHOLD and S % Q_CHUNK == 0:
        out = chunked_attention(q, k, v, causal)
    else:
        mask = None
        if causal:
            mask = jnp.tril(jnp.ones((S, S), bool))[None, None, None]
        out = _sdpa(cfg, q, k, v, mask)
    return out @ p["wo"]


def cross_attention(cfg: ModelConfig, p: Params, x: jnp.ndarray, ctx_k, ctx_v):
    """x: (B,Sq,d); ctx_k/ctx_v: precomputed (B,Skv,Hk,D)."""
    B, Sq, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, Sq, h, hd)
    out = _sdpa(cfg, q, ctx_k, ctx_v, mask=None)
    return out @ p["wo"]


def attention_decode(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    position: jnp.ndarray,
    use_rope: bool = True,
    block_tables: Optional[jnp.ndarray] = None,
    kv_scales: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    mesh=None,
):
    """Single-token decode with in-place cache update.

    x: (B, 1, d); position: scalar int OR a per-row (B,) int vector — rows
    of a batch may sit at different sequence offsets (continuous batching).
    The new K/V is scattered into each row's own cache index, then the
    attention READ dispatches through the single
    ``kernels.flash_decode.ops.decode_attention`` entry point (per-row
    lengths = position + 1), selected by ``cfg.attn_kernel``: the Pallas
    flash-decode kernel on TPU (interpret mode when forced on elsewhere) or
    the jnp reference.

    Two cache layouts:
      * dense (block_tables=None): k_cache/v_cache are (B, S_max, Hk, D)
        slot stripes, row b's position j lives at [b, j];
      * paged: k_cache/v_cache are (N, bs, Hk, D) pools of fixed-size token
        blocks shared by all rows, and ``block_tables`` (B, T) int32 maps
        row b's block index j//bs to a pool block (serving.paged hands these
        out; unallocated entries point at the trash block).  The new K/V is
        scattered through the table and the kernel walks each row's blocks
        through the table directly out of the shared pool — no dense
        per-lane copy of the pool is materialized on this path (the
        ``"off"`` fallback gathers, as the pre-kernel engine did).  With
        prefix caching, SEVERAL rows' tables may name the same
        (ref-counted) block: concurrent reads are safe because the
        host-side store guarantees the scattered write position always
        lands in a block exclusive to its row (fresh growth or
        copy-on-write — ``BlockStore.ensure_writable``).

    kv_scales: (k_scale, v_scale) (N, bs, Hk) fp32 leaves of a SCLAD
    quantized pool (paged layout only, ``cfg.kv_dtype`` in "int8"/"fp8").
    When given, the new token's K/V is quantized (``models.kv_quant``) and
    the payload + per-head scales scattered through the table; readers
    dequantize on load.  The quantized write runs here in jnp for BOTH
    ``attn_kernel`` read paths, so the pool bytes a decode step leaves
    behind are identical whichever kernel serves the read.

    mesh: threaded to ``decode_attention`` on the paged branches — when it
    carries a nontrivial ``model`` axis that divides Hk, the pool read
    runs shard_mapped over the KV heads (the scatter above stays outside:
    a sharded pool's ``.at[].set`` is itself a local per-shard write).

    Returns (out (B,1,d), k_cache, v_cache) — plus (k_scale, v_scale)
    appended when ``kv_scales`` is given.
    """
    from repro.kernels.flash_decode import ops as decode_ops

    B = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x, x)
    pos = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (B,))
    if use_rope:
        q = apply_rope(cfg, q, pos[:, None])
        k = apply_rope(cfg, k, pos[:, None])
    lengths = pos + 1  # row b's valid cache positions, incl. the new token
    if block_tables is None:
        assert kv_scales is None, "kv_scales is a paged-pool layout"
        rows = jnp.arange(B)
        k_cache = k_cache.at[rows, pos].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, pos].set(v[:, 0].astype(v_cache.dtype))
        out = decode_ops.decode_attention(
            q[:, 0], k_cache.astype(x.dtype), v_cache.astype(x.dtype),
            lengths, kernel=cfg.attn_kernel)
    else:
        bs = k_cache.shape[1]
        rows = jnp.arange(B)
        # Dead lanes carry all-trash tables, so their writes land in the
        # trash block and cannot clobber a block re-assigned to a live lane
        # (their stale ``lengths`` only ever cover trash blocks, which the
        # caller's active mask keeps out of every live result).
        blk = block_tables[rows, pos // bs]
        if kv_scales is not None:
            from repro.models import kv_quant
            k_scale, v_scale = kv_scales
            kq, ks1 = kv_quant.quantize(k[:, 0], cfg.kv_dtype)  # (B,Hk,D)/(B,Hk)
            vq, vs1 = kv_quant.quantize(v[:, 0], cfg.kv_dtype)
            k_cache = k_cache.at[blk, pos % bs].set(kq)
            v_cache = v_cache.at[blk, pos % bs].set(vq)
            k_scale = k_scale.at[blk, pos % bs].set(ks1)
            v_scale = v_scale.at[blk, pos % bs].set(vs1)
            out = decode_ops.decode_attention(
                q[:, 0], k_cache, v_cache, lengths,
                block_tables=block_tables, kernel=cfg.attn_kernel,
                kv_scales=(k_scale, v_scale), mesh=mesh)
            return (out.reshape(B, 1, -1).astype(x.dtype) @ p["wo"],
                    k_cache, v_cache, k_scale, v_scale)
        k_cache = k_cache.at[blk, pos % bs].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[blk, pos % bs].set(v[:, 0].astype(v_cache.dtype))
        out = decode_ops.decode_attention(
            q[:, 0], k_cache, v_cache, lengths, block_tables=block_tables,
            kernel=cfg.attn_kernel, mesh=mesh)
    return out.reshape(B, 1, -1).astype(x.dtype) @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, f)),
            "w_up": dense_init(ks[1], (d, f)),
            "w_down": dense_init(ks[2], (f, d)),
        }
    return {"w_up": dense_init(ks[0], (d, f)), "w_down": dense_init(ks[1], (f, d))}


def apply_mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Dense decoder block (pre-norm)
# ---------------------------------------------------------------------------

def init_dense_block(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": init_norm(cfg),
        "attn": init_attention(cfg, k1),
        "ln_mlp": init_norm(cfg),
        "mlp": init_mlp(cfg, k2),
    }


def apply_dense_block(cfg: ModelConfig, p: Params, x, positions):
    x = x + attention(cfg, p["attn"], apply_norm(cfg, p["ln_attn"], x), positions)
    x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln_mlp"], x))
    return x


def apply_dense_block_decode(cfg: ModelConfig, p: Params, x, k_cache, v_cache, position):
    a, k_cache, v_cache = attention_decode(
        cfg, p["attn"], apply_norm(cfg, p["ln_attn"], x), k_cache, v_cache, position
    )
    x = x + a
    x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln_mlp"], x))
    return x, k_cache, v_cache
