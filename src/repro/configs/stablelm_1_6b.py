"""stablelm-1.6b — [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig, register

_SKIP = (("long_500k",
          "pure full-attention arch: 500k decode requires sub-quadratic "
          "attention; skipped per assignment"),)


@register("stablelm-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        num_layers=24,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=5632,
        vocab_size=100_352,
        norm="layernorm",
        activation="swiglu",
        rope_theta=10_000.0,
        rope_fraction=0.25,  # stablelm-2 partial rotary
        skip_shapes=_SKIP,
        source="hf:stabilityai/stablelm-2-1_6b; 24L d=2048 32H MHA",
    )
