"""zamba2-7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register


@register("zamba2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14_336,  # shared transformer block FFN
        vocab_size=32_000,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=10_000.0,
        ssm=SSMConfig(state_size=64, head_dim=64, expand=2, conv_kernel=4,
                      ngroups=1, chunk_size=256),
        hybrid=HybridConfig(attn_every=6),
        source="arXiv:2411.15242; 81L d=3584 hybrid mamba2+shared attn, "
               "ssm_state=64",
    )
