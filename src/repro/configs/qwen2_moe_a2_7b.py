"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig, MoEConfig, register

_SKIP = (("long_500k",
          "full-attention MoE: 500k decode requires sub-quadratic attention; "
          "skipped per assignment"),)


@register("qwen2-moe-a2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,  # per-expert intermediate size
        vocab_size=151_936,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=1_000_000.0,
        qkv_bias=True,
        moe=MoEConfig(num_experts=60, num_experts_per_tok=4,
                      num_shared_experts=4, shared_d_ff=5632,
                      capacity_factor=1.25),
        skip_shapes=_SKIP,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B; 24L d=2048 16H 60e top-4 + shared",
    )
