"""Model configuration system.

Every assigned architecture is a ``ModelConfig`` registered under its id
(``--arch <id>``).  Configs are plain frozen dataclasses so they can be
hashed into jit static args, serialized into checkpoints, and consumed by
both the JAX runtime and the analytic co-design engine in ``repro.core``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Shape grid (assigned): every LM arch is exercised under these four shapes.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One (seq_len, global_batch) cell of the assigned shape grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0  # intermediate size of the shared expert (0 = none)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Layers that are dense instead of MoE (e.g. first layer in some models).
    first_dense_layers: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 / SSD block hyperparameters (arXiv:2405.21060)."""

    state_size: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    ngroups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: mamba backbone + a shared attention block every N."""

    attn_every: int = 6  # apply the shared attention block every N ssm layers


@dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper: 30s audio -> 1500 frames


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    # Ops / norm variants (paper §2.1: LLMs differ in these).
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    activation: str = "swiglu"  # "swiglu" | "geglu" | "gelu"
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # stablelm2 uses partial rotary (0.25)
    tie_embeddings: bool = False
    qkv_bias: bool = False
    # Sub-family configs.
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    # VLM stub frontend: number of visual patch embeddings prepended.
    num_patches: int = 0
    # KV-cache storage representation.  Dense stripes accept the legacy
    # values: "bf16" (default; alias "fp") or "f8" (float8_e4m3fn storage
    # — halves decode KV bytes/capacity, KVQuant-style).  The PAGED pool
    # (serving engine) additionally accepts the SCLAD quantized layouts
    # "int8" / "fp8": the pool is stored as a compressed payload plus
    # per-position-per-head fp32 scales (models.kv_quant), dequantized on
    # the load path by both the jnp references and the Pallas kernels —
    # PAPER.md §CC-MEM's Store-as-Compressed, Load-as-Dense applied to
    # the serving KV footprint.  Composes with ``attn_kernel``.
    kv_dtype: str = "bf16"
    # Attention-kernel implementation for BOTH serving hot paths — paged
    # flash-decode (kernels.flash_decode.ops) and paged flash-prefill
    # (kernels.flash_prefill.ops):
    #   "auto" — the Pallas kernels on TPU, jnp references elsewhere;
    #   "on"   — always the kernels (interpret mode off-TPU: the CI path);
    #   "off"  — always the jnp references (the dense-gather fallbacks).
    # (Formerly ``decode_kernel``, which remains readable as a property.)
    attn_kernel: str = "auto"
    # Which shapes this arch skips (with reason) — see DESIGN.md §4.
    skip_shapes: Tuple[Tuple[str, str], ...] = ()
    # Citation provenance for the config values.
    source: str = ""

    # -- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        assert self.family in FAMILIES, self.family
        assert self.kv_dtype in ("fp", "bf16", "f8", "int8", "fp8"), \
            self.kv_dtype
        assert self.attn_kernel in ("auto", "on", "off"), self.attn_kernel
        if self.num_heads and self.num_kv_heads:
            assert self.num_heads % self.num_kv_heads == 0

    @property
    def decode_kernel(self) -> str:
        """Deprecated alias of ``attn_kernel`` (the knob now selects the
        prefill kernel too).  Kept readable so pre-PR-5 call sites keep
        working; new code should read ``attn_kernel``."""
        return self.attn_kernel

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run 500k-token decode (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def shape_supported(self, shape: str) -> Tuple[bool, str]:
        for s, why in self.skip_shapes:
            if s == shape:
                return False, why
        return True, ""

    # -- parameter counting (used by core/ and roofline) --------------------
    def param_count(self) -> int:
        """Exact parameter count of the JAX implementation."""
        from repro.models import model as _model  # lazy, avoids jax at import

        return _model.param_count(self)

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.family == "ssm" or self.family == "hybrid":
            kw["d_ff"] = 128 if self.d_ff else 0
        out = replace(self, **kw)
        if self.moe is not None:
            out = replace(
                out,
                moe=replace(
                    self.moe,
                    num_experts=4,
                    num_experts_per_tok=2,
                    shared_d_ff=64 if self.moe.shared_d_ff else 0,
                    # Smoke configs route ~T/2 tokens per expert; a generous
                    # capacity keeps prefill/decode numerically identical.
                    capacity_factor=4.0,
                ),
            )
        if self.ssm is not None:
            out = replace(
                out, ssm=replace(self.ssm, state_size=16, head_dim=16, chunk_size=32)
            )
        if self.hybrid is not None:
            out = replace(out, hybrid=replace(self.hybrid, attn_every=2))
        if self.encdec is not None:
            out = replace(
                out, encdec=replace(self.encdec, num_encoder_layers=2, encoder_seq_len=16)
            )
        if self.num_patches:
            out = replace(out, num_patches=4)
        return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    _ensure_imported()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    _ensure_imported()
    return sorted(_REGISTRY)


_IMPORTED = False


def _ensure_imported():
    global _IMPORTED
    if _IMPORTED:
        return
    # Import every config module so registrations run.
    from repro.configs import (  # noqa: F401
        mamba2_1_3b,
        qwen3_moe_235b_a22b,
        qwen2_moe_a2_7b,
        stablelm_1_6b,
        tinyllama_1_1b,
        phi3_medium_14b,
        granite_3_8b,
        zamba2_7b,
        internvl2_26b,
        whisper_base,
    )

    _IMPORTED = True
