"""internvl2-26b — InternViT + InternLM2 [arXiv:2404.16821].

Per the assignment, only the transformer BACKBONE (InternLM2-20B decoder) is
modeled; the InternViT frontend is a STUB — ``input_specs()`` provides
precomputed patch embeddings that are prepended to the token sequence.
"""
from repro.configs.base import ModelConfig, register

_SKIP = (("long_500k",
          "full-attention VLM backbone: 500k decode requires sub-quadratic "
          "attention; skipped per assignment"),)


@register("internvl2-26b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16_384,
        vocab_size=92_553,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=1_000_000.0,
        num_patches=256,  # stub InternViT: 256 patch embeddings per image
        skip_shapes=_SKIP,
        source="arXiv:2404.16821; LM backbone 48L d=6144 48H GQA(kv=8)",
    )
