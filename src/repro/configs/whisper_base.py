"""whisper-base — enc-dec, conv frontend (stub) [arXiv:2212.04356].

Per the assignment the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, 1500, d_model) feeding the
bidirectional encoder; the decoder is autoregressive with self + cross
attention.
"""
from repro.configs.base import EncDecConfig, ModelConfig, register

_SKIP = (("long_500k",
          "full-attention enc-dec: 500k decode requires sub-quadratic "
          "attention (and whisper has no 500k context); skipped per "
          "assignment"),)


@register("whisper-base")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        num_layers=6,  # decoder layers
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=51_865,
        norm="layernorm",
        activation="gelu",
        rope_theta=0.0,  # whisper uses learned/sinusoidal absolute positions
        encdec=EncDecConfig(num_encoder_layers=6, encoder_seq_len=1500),
        skip_shapes=_SKIP,
        source="arXiv:2212.04356; whisper-base 6L enc + 6L dec d=512 8H",
    )
