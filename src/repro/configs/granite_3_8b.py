"""granite-3-8b — GQA [hf:ibm-granite/granite-3.0 family]."""
from repro.configs.base import ModelConfig, register

_SKIP = (("long_500k",
          "pure full-attention arch: 500k decode requires sub-quadratic "
          "attention; skipped per assignment"),)


@register("granite-3-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=12_800,
        vocab_size=49_155,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        skip_shapes=_SKIP,
        source="hf:ibm-granite/granite-3.0-8b-base; 40L d=4096 32H GQA(kv=8)",
    )
