"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family]."""
from repro.configs.base import ModelConfig, MoEConfig, register

_SKIP = (("long_500k",
          "full-attention MoE: 500k single-token decode requires sub-quadratic "
          "attention; skipped per assignment"),)


@register("qwen3-moe-235b-a22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,  # qwen3 uses head_dim=128 (not d_model/num_heads)
        d_ff=1536,  # per-expert intermediate size
        vocab_size=151_936,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=128, num_experts_per_tok=8,
                      num_shared_experts=0, shared_d_ff=0,
                      capacity_factor=1.25),
        skip_shapes=_SKIP,
        source="hf:Qwen/Qwen3-235B-A22B; 94L d=4096 64H GQA(kv=4) 128e top-8",
    )
