"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385]."""
from repro.configs.base import ModelConfig, register

_SKIP = (("long_500k",
          "pure full-attention arch: 500k decode requires sub-quadratic "
          "attention; skipped per assignment"),)


@register("tinyllama-1.1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        family="dense",
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=5632,
        vocab_size=32_000,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=10_000.0,
        skip_shapes=_SKIP,
        source="arXiv:2401.02385; 22L d=2048 32H GQA(kv=4)",
    )
