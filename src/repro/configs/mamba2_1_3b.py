"""mamba2-1.3b — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("mamba2-1.3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        head_dim=1,  # unused (attention-free)
        d_ff=0,  # attention-free, no separate FFN: mamba2 block only
        vocab_size=50_280,
        norm="rmsnorm",
        tie_embeddings=True,
        ssm=SSMConfig(state_size=128, head_dim=64, expand=2, conv_kernel=4,
                      ngroups=1, chunk_size=256),
        source="arXiv:2405.21060 (mamba2-1.3b); attn-free, ssm_state=128",
    )
