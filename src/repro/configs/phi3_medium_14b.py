"""phi3-medium-14b — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.configs.base import ModelConfig, register

_SKIP = (("long_500k",
          "pure full-attention arch: 500k decode requires sub-quadratic "
          "attention; skipped per assignment"),)


@register("phi3-medium-14b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        d_ff=17_920,
        vocab_size=100_352,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=10_000.0,
        skip_shapes=_SKIP,
        source="arXiv:2404.14219; 40L d=5120 40H GQA(kv=10) d_ff=17920",
    )
