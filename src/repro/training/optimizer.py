"""AdamW with global-norm clipping. Pure pytree functions (no optax dep).

Moments are fp32 and inherit the parameter sharding (FSDP over ``data`` +
TP over ``model``), i.e. ZeRO-3-style fully sharded optimizer state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    m: Any  # fp32 pytree like params
    v: Any  # fp32 pytree like params


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def state_specs(param_spec_tree) -> AdamWState:
    """Optimizer state PartitionSpecs mirroring parameter specs."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_spec_tree, v=param_spec_tree)


def _schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
