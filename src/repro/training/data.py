"""Deterministic synthetic data pipeline.

A seeded, restartable token stream: batch `i` is a pure function of
(seed, i), so a job restarted from a checkpoint at step k reproduces the
exact remaining stream — the property the fault-tolerance story needs.
The generator mimics Zipfian token statistics so losses are non-degenerate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class TokenStream:
    """Stateless-per-index batch source (restartable at any step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipfian unigram distribution over the vocab.
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = (p / p.sum()).astype(np.float64)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ index)
        toks = rng.choice(cfg.vocab_size, size=(cfg.global_batch,
                                                cfg.seq_len + 1), p=self._p)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def shard_for_host(batch: Dict[str, np.ndarray], host_index: int,
                   host_count: int) -> Dict[str, np.ndarray]:
    """Per-host slice of the global batch (multi-host data loading)."""
    def slc(x):
        n = x.shape[0]
        per = n // host_count
        return x[host_index * per: (host_index + 1) * per]

    return {k: slc(v) for k, v in batch.items()}
