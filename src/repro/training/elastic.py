"""Elastic scaling + straggler mitigation policies.

On a 1000+ node deployment the failure domains are: chip, host, pod,
interconnect.  The framework's contract (implemented across
training/checkpoint.py, training/data.py and launch/mesh.py):

  * **Node failure** -> job restarts from the last atomic checkpoint; the
    data stream is index-pure so no samples are skipped or repeated.
  * **Elastic rescale** -> ``checkpoint.restore`` device_puts full arrays
    against the *new* mesh's NamedShardings; optimizer state re-shards with
    its parameters (same specs), so going 2 pods -> 1 pod is a restore.
  * **Straggler mitigation** -> the StragglerMonitor below tracks per-step
    wall times and flags slow outliers; the launcher's policy is to drop the
    afflicted pod from the ``pod`` axis (data-parallel replicas are
    independent) and continue at reduced world size until the replacement
    arrives, then rescale back.

This module provides the measurement + decision logic; the mechanism (mesh
rebuild + restore) already exists in the launcher.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class StragglerMonitor:
    """Flags steps (or peers) whose duration is a robust outlier."""

    window: int = 50
    threshold: float = 2.0  # x median
    durations: List[float] = field(default_factory=list)
    _t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self) -> Optional[str]:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self.durations.append(dt)
        if len(self.durations) > self.window:
            self.durations.pop(0)
        if len(self.durations) >= 10:
            med = statistics.median(self.durations)
            if dt > self.threshold * med:
                return (f"straggler: step took {dt:.3f}s vs median "
                        f"{med:.3f}s (> {self.threshold}x)")
        return None


@dataclass
class ElasticPlan:
    """Decides the mesh for a given healthy-pod count."""

    pods_total: int
    data: int = 16
    model: int = 16

    def mesh_shape(self, healthy_pods: int):
        if healthy_pods >= 2:
            return (healthy_pods, self.data, self.model), ("pod", "data",
                                                           "model")
        return (self.data, self.model), ("data", "model")

    def global_batch_scale(self, healthy_pods: int) -> float:
        """Keep per-pod batch constant: global batch scales with pods."""
        return healthy_pods / self.pods_total
