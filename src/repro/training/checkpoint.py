"""Checkpointing: atomic, async, keep-last-k, mesh-portable.

Layout: <dir>/step_<n>/ containing
  * meta.json           — step, arch name, pytree structure
  * arrays.npz          — flattened leaves keyed by path

Writes go to a temp dir then are atomically renamed, so a job killed
mid-checkpoint never corrupts the latest restore point (node-failure
tolerance).  ``save_async`` runs serialization on a background thread so the
training loop only blocks on the device->host copy.

Arrays are saved unsharded (fetched to host); ``restore`` can therefore load
into any mesh shape — elastic rescaling is a restore-with-different-mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz can't serialize ml_dtypes
            arr = arr.astype(np.float32)
        out.append((key, arr))
    return out


def save(ckpt_dir: str, step: int, tree, keep: int = 3,
         extra_meta: Optional[Dict[str, Any]] = None) -> str:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves = _flatten(tree)
    np.savez(tmp / "arrays.npz", **{k: v for k, v in leaves})
    meta = {"step": step, "keys": [k for k, _ in leaves],
            "time": time.time(), **(extra_meta or {})}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish

    # Retention: keep the most recent `keep` checkpoints.
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*"))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)
    return str(final)


class AsyncCheckpointer:
    """Serializes on a background thread; at most one outstanding save."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, ckpt_dir: str, step: int, tree, keep: int = 3) -> None:
        self.wait()
        # Device->host copy happens here (blocking, consistent snapshot);
        # file IO happens on the thread.
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            self.last_path = save(ckpt_dir, step, host_tree, keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like) -> Any:
    """Restore into the structure (and shardings) of `like`.

    `like` may be a pytree of arrays or ShapeDtypeStructs; arrays are
    device-put against each leaf's sharding when present — this is how a
    checkpoint taken on one mesh is reloaded onto another (elastic restart).
    """
    path = Path(ckpt_dir) / f"step_{step}" / "arrays.npz"
    data = np.load(path)
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves, treedef = flat[0], flat[1]
    out = []
    for p, leaf in leaves:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            out.append(jax.device_put(arr.astype(leaf.dtype), sharding))
        else:
            out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
