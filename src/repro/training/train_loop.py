"""Training loop: jit'd step, async checkpointing, restart, metrics.

Fault-tolerance contract:
  * checkpoints are atomic and keep-last-k (training/checkpoint.py);
  * the data stream is a pure function of the step index (training/data.py),
    so restart at step k reproduces the exact remaining stream;
  * restore() re-shards onto whatever mesh the restarted job has — scaling
    the pod count between runs is a restore, not a migration.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training import checkpoint as ckpt_lib
from repro.training import data as data_lib
from repro.training import optimizer as opt_lib
from repro.launch import steps as steps_lib


@dataclass
class TrainConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    opt: opt_lib.AdamWConfig = field(default_factory=opt_lib.AdamWConfig)


@dataclass
class TrainState:
    params: Any
    opt_state: opt_lib.AdamWState
    step: int


def init_state(cfg: ModelConfig, seed: int = 0) -> TrainState:
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    return TrainState(params=params, opt_state=opt_lib.init(params), step=0)


def train(cfg: ModelConfig, tcfg: TrainConfig,
          state: Optional[TrainState] = None,
          hooks: Optional[List[Callable[[int, Dict], None]]] = None
          ) -> TrainState:
    stream = data_lib.TokenStream(data_lib.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
        global_batch=tcfg.global_batch, seed=tcfg.seed))

    start_step = 0
    if state is None:
        if tcfg.ckpt_dir and (ls := ckpt_lib.latest_step(tcfg.ckpt_dir)) \
                is not None:
            state = init_state(cfg, tcfg.seed)
            restored = ckpt_lib.restore(
                tcfg.ckpt_dir, ls,
                {"params": state.params, "opt": state.opt_state})
            state = TrainState(params=restored["params"],
                               opt_state=restored["opt"], step=ls)
            start_step = ls
        else:
            state = init_state(cfg, tcfg.seed)
    else:
        start_step = state.step

    step_fn = jax.jit(steps_lib.make_train_step(cfg, tcfg.opt))
    saver = ckpt_lib.AsyncCheckpointer()
    params, opt_state = state.params, state.opt_state

    losses = []
    t0 = time.perf_counter()
    for i in range(start_step, tcfg.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (tcfg.global_batch, cfg.num_patches, cfg.d_model),
                jnp.bfloat16)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (tcfg.global_batch, cfg.encdec.encoder_seq_len, cfg.d_model),
                jnp.bfloat16)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if hooks:
            for h in hooks:
                h(i, {k: float(v) for k, v in metrics.items()})
        if tcfg.log_every and (i + 1) % tcfg.log_every == 0:
            dt = time.perf_counter() - t0
            tps = tcfg.global_batch * tcfg.seq_len * tcfg.log_every / dt
            print(f"step {i+1:5d} loss {loss:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"({tps:,.0f} tok/s)", flush=True)
            t0 = time.perf_counter()
        if tcfg.ckpt_dir and (i + 1) % tcfg.ckpt_every == 0:
            saver.save(tcfg.ckpt_dir, i + 1,
                       {"params": params, "opt": opt_state})
    saver.wait()
    return TrainState(params=params, opt_state=opt_state, step=tcfg.steps)
