"""TCO model (paper §4.2, following Barroso et al. warehouse-scale model).

TCO = CapEx + Life x OpEx, expressed here as a $/second rate per server so
TCO/token = rate x servers / throughput.

Assumptions (documented constants): electricity $0.07/kWh, PUE 1.1,
datacenter CapEx $11/W amortized over 12 years, server life 1.5 years
(Table 1), average power = 75% TDP while serving.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.hardware import SERVER_LIFE_YEARS, ServerConfig

ELECTRICITY_PER_KWH = 0.07
PUE = 1.1
DC_CAPEX_PER_W = 11.0
DC_AMORT_YEARS = 12.0
AVG_POWER_FRACTION = 0.75
MAINTENANCE_FRACTION = 0.05  # of server CapEx per year

SECONDS_PER_YEAR = 365.25 * 24 * 3600.0

# NRE model (paper §6.4, extended from Moonwalk to 7nm).
NRE_TOTAL = 35e6


@dataclass(frozen=True)
class TCOBreakdown:
    capex_rate: float  # $/s
    opex_rate: float  # $/s

    @property
    def rate(self) -> float:
        return self.capex_rate + self.opex_rate

    @property
    def capex_fraction(self) -> float:
        return self.capex_rate / max(self.rate, 1e-30)


def server_tco(server: ServerConfig) -> TCOBreakdown:
    capex = server.capex()
    life_s = SERVER_LIFE_YEARS * SECONDS_PER_YEAR
    dc_capex_rate = (DC_CAPEX_PER_W * server.tdp) / (
        DC_AMORT_YEARS * SECONDS_PER_YEAR)
    capex_rate = capex / life_s + dc_capex_rate

    avg_w = server.tdp * AVG_POWER_FRACTION * PUE
    energy_rate = avg_w / 1000.0 * ELECTRICITY_PER_KWH / 3600.0
    maint_rate = MAINTENANCE_FRACTION * capex / SECONDS_PER_YEAR
    return TCOBreakdown(capex_rate=capex_rate,
                        opex_rate=energy_rate + maint_rate)


def tco_per_mtoken(server: ServerConfig, servers: int,
                   tokens_per_s: float) -> float:
    """$ per 1M generated tokens for a deployment of `servers` servers."""
    rate = server_tco(server).rate * servers
    return rate / max(tokens_per_s, 1e-30) * 1e6


def nre_per_token(total_tokens: float) -> float:
    return NRE_TOTAL / max(total_tokens, 1.0)
