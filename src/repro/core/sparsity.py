"""Store-as-Compressed, Load-as-Dense (SCLD) — paper §3.2, Fig 4 & Fig 13.

Weights are stored in a tile-based compressed sparse row format (tiles of
32x8; each non-zero value is a 24-bit word: 16b value + 5b row + 3b col) and
decoded to dense tiles at load time, so compute units stay sparsity-agnostic.

This module provides:
  * the storage/bandwidth cost model used by the co-design engine,
  * a functional numpy codec for the tile-CSR format — the oracle for the
    Pallas SCLD matmul kernel in ``repro/kernels/sclad_matmul``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

TILE_R, TILE_C = 32, 8
BITS_VALUE = 16
BITS_ROW = 5  # log2(TILE_R)
BITS_COL = 3  # log2(TILE_C)
BITS_SPARSE_WORD = BITS_VALUE + BITS_ROW + BITS_COL  # 24
BITS_TILE_INDEX = 40  # start+end pointers per tile in the index memory


def storage_factor(sparsity: float) -> float:
    """Stored bytes / dense bytes for a given weight sparsity.

    Each layer chooses the smaller encoding (dense vs tile-CSR), exactly the
    store-side flexibility the CC-MEM decoder CSRs allow, so the factor never
    exceeds 1 (plus the tiny tile-index overhead).
    """
    dense_bits = BITS_VALUE
    sparse_bits = (1.0 - sparsity) * BITS_SPARSE_WORD \
        + BITS_TILE_INDEX / (TILE_R * TILE_C)
    return min(1.0, sparse_bits / dense_bits)


def max_model_scale(sparsity: float) -> float:
    """How much larger a model fits at this sparsity (paper Fig 13 bottom)."""
    return 1.0 / storage_factor(sparsity)


# Perplexity of OPT-175B under SparseGPT unstructured sparsity (paper Fig 13
# top, values approximated from SparseGPT [15]).
OPT175B_PERPLEXITY: Dict[float, float] = {
    0.0: 8.34, 0.1: 8.34, 0.2: 8.34, 0.3: 8.35, 0.4: 8.37, 0.5: 8.40,
    0.6: 8.60, 0.7: 9.67, 0.8: 18.3,
}


# ---------------------------------------------------------------------------
# Functional tile-CSR codec (numpy oracle for the Pallas kernel)
# ---------------------------------------------------------------------------

@dataclass
class TileCSR:
    """Tile-compressed weight matrix (row-major tiles of TILE_R x TILE_C)."""

    shape: Tuple[int, int]
    values: np.ndarray  # (nnz,) float16/float32 non-zero values
    rows: np.ndarray  # (nnz,) uint8 row index within tile
    cols: np.ndarray  # (nnz,) uint8 col index within tile
    tile_ptr: np.ndarray  # (ntiles+1,) int32 — CSR-style offsets per tile

    @property
    def ntiles(self) -> int:
        return len(self.tile_ptr) - 1

    def stored_bits(self) -> int:
        return len(self.values) * BITS_SPARSE_WORD \
            + self.ntiles * BITS_TILE_INDEX


def encode(w: np.ndarray) -> TileCSR:
    """Dense (M, N) -> tile-CSR. M % 32 == 0, N % 8 == 0."""
    M, N = w.shape
    assert M % TILE_R == 0 and N % TILE_C == 0, (M, N)
    tiles = w.reshape(M // TILE_R, TILE_R, N // TILE_C, TILE_C)
    tiles = tiles.transpose(0, 2, 1, 3).reshape(-1, TILE_R, TILE_C)
    vals, rows, cols, ptr = [], [], [], [0]
    for t in tiles:
        r, c = np.nonzero(t)
        vals.append(t[r, c])
        rows.append(r.astype(np.uint8))
        cols.append(c.astype(np.uint8))
        ptr.append(ptr[-1] + len(r))
    return TileCSR(
        shape=(M, N),
        values=np.concatenate(vals) if vals else np.zeros(0, w.dtype),
        rows=np.concatenate(rows) if rows else np.zeros(0, np.uint8),
        cols=np.concatenate(cols) if cols else np.zeros(0, np.uint8),
        tile_ptr=np.asarray(ptr, np.int32),
    )


def decode(t: TileCSR, dtype=np.float32) -> np.ndarray:
    """Load-as-dense: reconstruct the dense matrix."""
    M, N = t.shape
    tr, tc = M // TILE_R, N // TILE_C
    out = np.zeros((tr * tc, TILE_R, TILE_C), dtype)
    for i in range(tr * tc):
        s, e = t.tile_ptr[i], t.tile_ptr[i + 1]
        out[i, t.rows[s:e], t.cols[s:e]] = t.values[s:e]
    out = out.reshape(tr, tc, TILE_R, TILE_C).transpose(0, 2, 1, 3)
    return out.reshape(M, N)


def sparsify(w: np.ndarray, sparsity: float, seed: int = 0) -> np.ndarray:
    """Magnitude-prune to the target unstructured sparsity."""
    flat = np.abs(w).ravel()
    k = int(len(flat) * sparsity)
    if k == 0:
        return w
    thresh = np.partition(flat, k)[k]
    return np.where(np.abs(w) < thresh, 0.0, w).astype(w.dtype)
