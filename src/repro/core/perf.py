"""Analytic inference simulation + software-mapping search (paper §4.2).

Given a server design and an LLM workload, searches tensor-parallel size,
pipeline stages, batch and micro-batch count for the TCO/token-optimal
mapping, using the paper's pipelined-generation model:

    l_token = max(l_mb, n * l_s)          (Fig 6)
    throughput = N / l_token

Per-layer decode latency is the max of a compute term, a CC-MEM bandwidth
term (weights + KV streamed from SRAM) and the tensor-parallel all-reduce
(ring, slowest-link bound, with the 2D weight-stationary O(1/sqrt(n))
variant of Pope et al. [37]).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.core.hardware import CHIP_IO_GBS, ServerConfig
from repro.core.tco import server_tco
from repro.core.workloads import LLMWorkload

BYTES_PER_PARAM = 2.0  # fp16/bf16 weights
BYTES_PER_KV = 2.0
ETHERNET_GBS = 12.5e9  # 100GbE between servers
ALLREDUCE_INIT_S = 1e-6
SRAM_USABLE_FRACTION = 0.9
# Compute-array efficiency on SRAM-streamed GEMV/GEMM. With the CC-MEM's
# banked bandwidth the SIMD arrays stay fed even at micro-batch 1 (Brainwave
# style), so efficiency is a constant, not a function of batch; end-to-end
# utilization losses come from the pipeline-bubble model.
COMPUTE_EFFICIENCY = 0.8


@dataclass(frozen=True)
class Mapping:
    tp: int
    pp: int
    batch: int
    microbatches: int

    @property
    def microbatch(self) -> int:
        return self.batch // self.microbatches

    @property
    def chips(self) -> int:
        return self.tp * self.pp


@dataclass(frozen=True)
class PerfResult:
    mapping: Mapping
    tokens_per_s: float
    latency_per_token: float
    util: float
    mem_per_chip_mb: float
    bound: str  # compute | memory | interconnect

    @property
    def tokens_per_s_per_chip(self) -> float:
        return self.tokens_per_s / self.mapping.chips


def _divisors(n: int, cap: int = 10 ** 9) -> List[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def evaluate(server: ServerConfig, wl: LLMWorkload, ctx: int,
             mapping: Mapping, use_2d_weight_stationary: bool = True
             ) -> Optional[PerfResult]:
    """Latency/throughput for one mapping; None if infeasible."""
    arr = evaluate_grid(server, wl, ctx, [mapping],
                        use_2d_weight_stationary)
    return arr[0] if arr else None


def evaluate_grid(server: ServerConfig, wl: LLMWorkload, ctx: int,
                  mappings: Iterable[Mapping],
                  use_2d_weight_stationary: bool = True
                  ) -> List[Optional[PerfResult]]:
    """Vectorized evaluation of many mappings on one server design."""
    maps = list(mappings)
    if not maps:
        return []
    tp = np.array([m.tp for m in maps], float)
    pp = np.array([m.pp for m in maps], float)
    N = np.array([m.batch for m in maps], float)
    n = np.array([m.microbatches for m in maps], float)
    m_tok = N / n  # microbatch tokens

    chip = server.chip
    L = wl.num_layers
    chips = tp * pp

    # --- capacity check (the CC-MEM constraint: everything resident) -------
    # SCLD: weights are stored compressed (storage factor <= 1) and decoded
    # to dense at load time by the CC-MEM decoder (paper §3.2).
    w_bytes = wl.params * BYTES_PER_PARAM * wl.weight_storage_factor
    kv_bytes = N * ctx * wl.kv_bytes_per_token(BYTES_PER_KV)
    act_bytes = 4.0 * N * wl.d_model * BYTES_PER_KV  # small
    mem_per_chip = (w_bytes + kv_bytes) / chips + act_bytes / tp
    mem_ok = mem_per_chip <= chip.sram_mb * 1e6 * SRAM_USABLE_FRACTION

    # --- per-layer decode latency ------------------------------------------
    # FC path (everything except attention reads): active params stream once
    # per microbatch from CC-MEM.
    fc_params_layer = (wl.active - wl.vocab * wl.d_model) / L
    fc_flops = 2.0 * m_tok * fc_params_layer
    util = np.full_like(m_tok, COMPUTE_EFFICIENCY)
    t_fc_compute = fc_flops / (tp * chip.tflops * 1e12 * util)
    t_fc_mem = (fc_params_layer * BYTES_PER_PARAM
                * wl.weight_storage_factor / tp) / chip.mem_bw

    # Attention: read this layer's KV for every row of the microbatch.
    kv_layer_row = ctx * wl.kv_bytes_per_token(BYTES_PER_KV) / L
    t_attn_mem = (m_tok * kv_layer_row / tp) / chip.mem_bw
    # A decode step attends over the FULL KV prefix (ctx keys); the causal
    # ctx/2 average only applies to prefill, which this generate-stage model
    # does not price.  2 MACs x (QK^T + PV) = 4 flops per key per d_model.
    attn_flops = 4.0 * m_tok * ctx * wl.d_model
    t_attn_compute = attn_flops / (tp * chip.tflops * 1e12 * util)

    # Tensor-parallel all-reduce (2 per layer). Link bw: slowest in group.
    link = np.where(tp <= server.num_chips, CHIP_IO_GBS, ETHERNET_GBS)
    ar_bytes = m_tok * wl.d_model * BYTES_PER_KV
    if use_2d_weight_stationary:
        eff = 2.0 * (np.sqrt(tp) - 1.0) / np.sqrt(tp)
    else:
        eff = 2.0 * (tp - 1.0) / tp
    t_ar = 2.0 * (ar_bytes * eff / link + ALLREDUCE_INIT_S)
    t_ar = np.where(tp > 1, t_ar, 0.0)

    t_layer = (np.maximum.reduce([t_fc_compute, t_fc_mem])
               + np.maximum.reduce([t_attn_compute, t_attn_mem]) + t_ar)

    # Pipeline schedule (paper Fig 6).
    t_send = np.where(pp > 1, m_tok * wl.d_model * BYTES_PER_KV / link
                      + ALLREDUCE_INIT_S, 0.0)
    l_s = (L / pp) * t_layer + t_send
    l_mb = pp * l_s
    l_token = np.maximum(l_mb, n * l_s)
    tokens_per_s = N / l_token

    # Bound classification for reporting.
    comp = t_fc_compute + t_attn_compute
    memb = t_fc_mem + t_attn_mem
    bounds = np.where(t_ar > np.maximum(comp, memb), 2,
                      np.where(memb > comp, 1, 0))

    ok = mem_ok & (pp <= L) & (n <= N) & (m_tok >= 1)
    out: List[Optional[PerfResult]] = []
    names = ("compute", "memory", "interconnect")
    for i, mp in enumerate(maps):
        if not ok[i]:
            out.append(None)
            continue
        out.append(PerfResult(
            mapping=mp,
            tokens_per_s=float(tokens_per_s[i]),
            latency_per_token=float(l_token[i]),
            util=float(util[i]),
            mem_per_chip_mb=float(mem_per_chip[i] / 1e6),
            bound=names[int(bounds[i])],
        ))
    return out


def mapping_grid(server: ServerConfig, wl: LLMWorkload,
                 batches: Iterable[int] = (1, 2, 4, 8, 16, 32, 64, 128, 256,
                                           512, 1024),
                 tp_choices: Optional[Iterable[int]] = None) -> List[Mapping]:
    """The paper's search space: tp x pp x batch x microbatches."""
    nc = server.num_chips
    if tp_choices is None:
        tp_choices = sorted({nc, nc // 2, nc // 4, max(nc // 8, 1)})
    pps = _divisors(wl.num_layers)
    out = []
    for tp in tp_choices:
        if tp < 1 or nc % tp:
            continue
        for pp in pps:
            for N in batches:
                for n in _divisors(int(N), cap=64):
                    out.append(Mapping(tp=tp, pp=pp, batch=int(N),
                                       microbatches=n))
    return out


@dataclass(frozen=True)
class DesignPoint:
    server: ServerConfig
    perf: PerfResult
    tco_per_mtoken: float
    servers: int

    def table_row(self) -> dict:
        c = self.server.chip
        m = self.perf.mapping
        return {
            "die_mm2": c.die_mm2,
            "mb_per_chip": round(c.sram_mb, 1),
            "tflops_per_chip": round(c.tflops, 2),
            "bw_tb_s": round(c.mem_bw / 1e12, 2),
            "chips_per_server": self.server.num_chips,
            "num_servers": self.servers,
            "tp": m.tp,
            "pp": m.pp,
            "batch": m.batch,
            "microbatch": m.microbatch,
            "tokens_s_chip": round(self.perf.tokens_per_s_per_chip, 2),
            "tco_per_mtoken": self.tco_per_mtoken,
            "bound": self.perf.bound,
        }


def best_mapping(server: ServerConfig, wl: LLMWorkload, ctx: int,
                 batches=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024),
                 ) -> Optional[DesignPoint]:
    """TCO/token-optimal mapping for one server design."""
    from repro.core import tco as tco_lib

    grid = mapping_grid(server, wl, batches)
    results = evaluate_grid(server, wl, ctx, grid)
    best: Optional[DesignPoint] = None
    rate = server_tco(server).rate
    for r in results:
        if r is None:
            continue
        servers = math.ceil(r.mapping.chips / server.num_chips)
        cost = rate * servers / max(r.tokens_per_s, 1e-30) * 1e6
        if best is None or cost < best.tco_per_mtoken:
            best = DesignPoint(server=server, perf=r, tco_per_mtoken=cost,
                               servers=servers)
    return best
