"""CC-MEM behavioral model (paper §3.1, Fig 3a).

A cycle-approximate simulator of the Chiplet Cloud memory system: SRAM bank
groups behind a pipelined crossbar, with burst-mode sequential access and
the SCLD compression decoder per bank group.  This is the component-level
model that justifies the bandwidth numbers the co-design engine assumes —
the engine's ``ChipConfig.mem_bw`` is the peak; this module predicts the
*achieved* fraction under bank conflicts and burst lengths.

Modeling choices (all from the paper's description):
  * each bank group is a virtual single-port memory: one word/cycle;
  * the crossbar sustains 100 % throughput absent bank conflicts
    (low-latency, conflict = stall for the losing requester);
  * burst mode amortizes the request path: a burst of B sequential words
    issues 1 request and streams B cycles from one group — GEMM weight
    streams are bursts, attention gathers are not;
  * the SCLD decoder emits up to 8 dense words/cycle from compressed tiles,
    so compressed streams deliver dense-equivalent words at
    min(8, 1/(1-s)) x the raw port rate... capped by the dense port width.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class CCMEMConfig:
    num_bank_groups: int = 64
    words_per_cycle_per_group: int = 8  # dense words (port width)
    crossbar_latency_cycles: int = 4  # pipeline depth
    burst_overhead_cycles: int = 2  # CSR setup per burst
    decoder_words_per_cycle: int = 8  # SCLD dense-output rate

    @property
    def peak_words_per_cycle(self) -> int:
        return self.num_bank_groups * self.words_per_cycle_per_group


@dataclass(frozen=True)
class AccessStream:
    """A stream of accesses from one compute port.

    kind: "burst" (sequential weight stream), "strided" (activation rows)
    or "random" (gather).  `words` is the dense word count; `sparsity` > 0
    means the stream reads SCLD-compressed data.
    """

    words: int
    kind: str = "burst"
    burst_len: int = 512
    sparsity: float = 0.0


def _effective_burst(stream: AccessStream) -> int:
    return stream.burst_len if stream.kind != "random" \
        else min(stream.burst_len, 32)


def _group_sequence(stream: AccessStream, cfg: CCMEMConfig,
                    rng: np.random.Generator) -> np.ndarray:
    """Bank-group id per burst for this stream."""
    n_bursts = max(1, stream.words // max(_effective_burst(stream), 1))
    if stream.kind == "burst":
        # Sequential interleave across groups.
        start = int(rng.integers(cfg.num_bank_groups))
        return (start + np.arange(n_bursts)) % cfg.num_bank_groups
    if stream.kind == "strided":
        stride = int(rng.choice([2, 4, 8, 16]))
        start = int(rng.integers(cfg.num_bank_groups))
        return (start + stride * np.arange(n_bursts)) % cfg.num_bank_groups
    return rng.integers(0, cfg.num_bank_groups, size=n_bursts)


def simulate(streams: Sequence[AccessStream], cfg: CCMEMConfig = CCMEMConfig(),
             seed: int = 0) -> dict:
    """Estimate cycles to drain all streams and the achieved bandwidth.

    Conflict model: per round, every stream proposes its next burst's bank
    group; groups serve one burst per round (virtual single-port), losers
    retry next round.  A round costs the burst duration of the longest
    admitted burst (groups are pipelined, so admitted bursts overlap).
    """
    rng = np.random.default_rng(seed)
    seqs: List[np.ndarray] = [_group_sequence(s, cfg, rng) for s in streams]
    ptrs = [0] * len(streams)
    cycles = cfg.crossbar_latency_cycles
    served_words = 0.0
    remaining = [float(s.words) for s in streams]
    total_words = float(sum(s.words for s in streams))

    def burst_cycles(s: AccessStream) -> float:
        # Dense-equivalent words per cycle out of one group.  SCLD streams
        # read (1-s)*24/16 bits per dense word (paper §3.2: same banks, same
        # peak bit rate, extra index bits per word), decoded at up to the
        # 8-wide decoder output.  Sparse reads are therefore never *faster*
        # than dense — the win is capacity — and are slower below ~33%.
        rate = float(cfg.words_per_cycle_per_group)
        if s.sparsity > 0:
            from repro.core.sparsity import storage_factor
            rate = min(float(cfg.decoder_words_per_cycle),
                       rate / max(storage_factor(s.sparsity), 1e-6))
        burst = _effective_burst(s)
        return cfg.burst_overhead_cycles + burst / rate, burst

    active = [i for i in range(len(streams)) if len(seqs[i])]
    while active:
        claims = {}
        for i in active:
            g = int(seqs[i][ptrs[i]])
            claims.setdefault(g, []).append(i)
        winners = [min(v) for v in claims.values()]  # deterministic arb
        round_cost = 0.0
        for i in winners:
            c, burst = burst_cycles(streams[i])
            round_cost = max(round_cost, c)
            # The final burst of a stream is short: credit only the words
            # actually remaining, so served_words can never exceed
            # total_words.
            served = min(float(burst), remaining[i])
            served_words += served
            remaining[i] -= served
            ptrs[i] += 1
        cycles += round_cost
        active = [i for i in active if ptrs[i] < len(seqs[i])]

    peak_cycles = total_words / cfg.peak_words_per_cycle
    return {
        "cycles": cycles,
        "peak_cycles": peak_cycles,
        "achieved_fraction": min(1.0, peak_cycles / max(cycles, 1e-9)),
        "served_words": served_words,
    }


def gemm_streams(m: int, k: int, n: int, tile: int = 128,
                 sparsity: float = 0.0) -> List[AccessStream]:
    """The access pattern of a weight-stationary GEMM on CC-MEM: one long
    weight burst stream + strided activation reads."""
    return [
        AccessStream(words=k * n, kind="burst", burst_len=tile * 4,
                     sparsity=sparsity),
        AccessStream(words=m * k, kind="strided", burst_len=tile),
        AccessStream(words=m * n, kind="strided", burst_len=tile),
    ]


def attention_decode_streams(ctx: int, d: int, kv_heads: int,
                             head_dim: int) -> List[AccessStream]:
    """Decode attention: long sequential KV reads (burst-friendly)."""
    return [
        AccessStream(words=2 * ctx * kv_heads * head_dim, kind="burst",
                     burst_len=head_dim * 8),
        AccessStream(words=4 * d, kind="random", burst_len=32),
    ]
