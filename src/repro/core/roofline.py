"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs  / (chips * PEAK_FLOPS)
  memory     = HLO_bytes  / (chips * HBM_BW)
  collective = wire_bytes / (chips * LINK_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  wire_bytes is
derived by parsing collective ops out of the optimized HLO: for each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute we
take the instruction's result shape and convert to ring-algorithm wire bytes
(see ``_WIRE_FACTORS``), then multiply by the number of participating devices
to get a global figure.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assigned constants).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[128,4096]' or a tuple."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUP_V2_RE.search(line)
    if m:  # replica_groups=[num_groups,group_size]<=[...]
        return int(m.group(2))
    m = _GROUP_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclass
class CollectiveStats:
    # op -> [count, result_bytes_total, wire_bytes_global]
    by_op: Dict[str, List[float]] = field(default_factory=dict)

    @property
    def wire_bytes(self) -> float:
        return sum(v[2] for v in self.by_op.values())


def _wire_factor(op: str, g: int) -> float:
    """Per-device ring wire bytes as a multiple of the *result* bytes."""
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return (g - 1) / g  # result is the gathered (big) buffer
    if op == "all-reduce":
        return 2 * (g - 1) / g  # reduce-scatter + all-gather of same size
    if op == "reduce-scatter":
        return g - 1  # result is the scattered (small) shard
    if op == "all-to-all":
        return (g - 1) / g
    if op == "collective-permute":
        return 1.0
    return 1.0


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "<name> = <shape> <op>(" — op may have -start/-done variants
        m = re.search(r"=\s+((?:\([^)]*\)|\S+))\s+(" + "|".join(_COLLECTIVES)
                      + r")(?:-start)?\(", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        rb = _shape_bytes(shape_str)
        if rb == 0:
            continue
        g = _group_size(s, default=total_devices)
        wire_per_dev = rb * _wire_factor(op, g)
        ent = stats.by_op.setdefault(op, [0, 0.0, 0.0])
        ent[0] += 1
        ent[1] += rb
        # every device participates in some group for this instruction
        ent[2] += wire_per_dev * total_devices
    return stats


@dataclass
class RooflineTerms:
    flops: float
    bytes_hbm: float
    wire_bytes: float
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops,
            "bytes_hbm": self.bytes_hbm,
            "wire_bytes": self.wire_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def model_flops(param_count: int, tokens: int, train: bool) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for a forward/serve pass."""
    return (6.0 if train else 2.0) * param_count * tokens
