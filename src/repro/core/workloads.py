"""LLM workload descriptions for the co-design engine (paper Table 2 models).

These are the paper's eight case-study models plus adapters for our ten
assigned architectures, described by the hyperparameters the analytic
inference simulator needs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class LLMWorkload:
    name: str
    d_model: int
    num_layers: int
    num_heads: int
    kv_heads: int  # == num_heads for MHA, 1 for MQA, groups for GQA
    d_ff: int
    vocab: int
    params: float  # total parameter count
    # MoE (active expert params already folded into `params_active`).
    params_active: Optional[float] = None
    # SCLD: stored-bytes / dense-bytes for the weights (core.sparsity).
    weight_storage_factor: float = 1.0

    @property
    def active(self) -> float:
        return self.params_active or self.params

    def kv_bytes_per_token(self, bytes_per=2) -> float:
        """KV-cache bytes appended per generated token (whole model)."""
        head_dim = self.d_model // self.num_heads
        return 2 * self.num_layers * self.kv_heads * head_dim * bytes_per

    def flops_per_token(self, ctx: int) -> float:
        """Decode FLOPs per generated token at context length ctx."""
        dense = 2.0 * self.active
        attn = 4.0 * self.num_layers * ctx * self.d_model
        return dense + attn


def _ff(d, mult=4):
    return d * mult


# Paper Table 2 rows (public hyperparameters).
PAPER_MODELS: Dict[str, LLMWorkload] = {
    "gpt2-1.5b": LLMWorkload("gpt2-1.5b", 1600, 48, 25, 25, _ff(1600), 50257,
                             1.5e9),
    "megatron-8.3b": LLMWorkload("megatron-8.3b", 3072, 72, 32, 32, _ff(3072),
                                 51200, 8.3e9),
    "gpt3-175b": LLMWorkload("gpt3-175b", 12288, 96, 96, 96, _ff(12288),
                             50257, 175e9),
    "gopher-280b": LLMWorkload("gopher-280b", 16384, 80, 128, 128, _ff(16384),
                               32000, 280e9),
    "mt-nlg-530b": LLMWorkload("mt-nlg-530b", 20480, 105, 128, 128,
                               _ff(20480), 50257, 530e9),
    "bloom-176b": LLMWorkload("bloom-176b", 14336, 70, 112, 112, _ff(14336),
                              250880, 176e9),
    # PaLM: multi-query attention (kv_heads=1), ff mult 4.
    "palm-540b": LLMWorkload("palm-540b", 18432, 118, 48, 1, _ff(18432),
                             256000, 540e9),
    # Llama-2 70B: GQA with 8 kv heads, SwiGLU ff 28672.
    "llama2-70b": LLMWorkload("llama2-70b", 8192, 80, 64, 8, 28672, 32000,
                              70e9),
}


def from_model_config(cfg) -> LLMWorkload:
    """Adapter: repro.configs.base.ModelConfig -> LLMWorkload."""
    from repro.models import model as M

    heads = cfg.num_heads or max(cfg.d_model // 128, 1)
    kv = cfg.num_kv_heads or heads
    return LLMWorkload(
        name=cfg.name,
        d_model=cfg.d_model,
        num_layers=cfg.num_layers,
        num_heads=heads,
        kv_heads=kv,
        d_ff=cfg.d_ff,
        vocab=cfg.vocab_size,
        params=float(M.param_count(cfg)),
        params_active=float(M.param_count_active(cfg)),
    )
