"""Two-phase design-space exploration (paper §4, Fig 5).

Phase 1 (hardware): bottom-up, LLM-agnostic sweep of chip (die size, CC-MEM
split, bank ratio) and server (chips/lane) design points under floorplan,
power and thermal constraints -> thousands of feasible servers.

Phase 2 (software): for each feasible server and a given LLM workload,
search the software mapping (tp, pp, batch, micro-batch) with the analytic
inference simulator and the TCO model; emit TCO/token-optimal design points.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core import hardware, perf, tco
from repro.core.workloads import LLMWorkload, PAPER_MODELS


@dataclass
class ExplorationResult:
    workload: LLMWorkload
    ctx: int
    best: perf.DesignPoint
    # All evaluated optima per server (for Fig 7-style scatter plots).
    frontier: List[perf.DesignPoint]


def phase1_servers(**kw) -> List[hardware.ServerConfig]:
    return hardware.sweep_servers(hardware.sweep_chips(**kw))


def phase2(servers: Sequence[hardware.ServerConfig], wl: LLMWorkload,
           ctx: int = 2048,
           batches: Iterable[int] = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512,
                                     1024),
           keep_all: bool = True) -> ExplorationResult:
    best: Optional[perf.DesignPoint] = None
    frontier: List[perf.DesignPoint] = []
    for s in servers:
        dp = perf.best_mapping(s, wl, ctx, batches)
        if dp is None:
            continue
        if keep_all:
            frontier.append(dp)
        if best is None or dp.tco_per_mtoken < best.tco_per_mtoken:
            best = dp
    if best is None:
        raise RuntimeError(f"no feasible design for {wl.name} ctx={ctx}")
    return ExplorationResult(workload=wl, ctx=ctx, best=best,
                             frontier=frontier)


def explore(wl: LLMWorkload, ctx: int = 2048,
            servers: Optional[Sequence[hardware.ServerConfig]] = None,
            **kw) -> ExplorationResult:
    servers = servers if servers is not None else phase1_servers()
    return phase2(servers, wl, ctx, **kw)


def explore_all_paper_models(ctx: int = 2048) -> Dict[str, ExplorationResult]:
    servers = phase1_servers()
    return {name: phase2(servers, wl, ctx, keep_all=False)
            for name, wl in PAPER_MODELS.items()}


def multi_model_optimum(workloads: Sequence[LLMWorkload], ctx: int = 2048,
                        servers: Optional[Sequence[hardware.ServerConfig]]
                        = None):
    """Fig 14: one chip for all models — minimize geomean TCO/token."""
    servers = servers if servers is not None else phase1_servers()
    best_server, best_geo, best_points = None, float("inf"), None
    for s in servers:
        pts = []
        for wl in workloads:
            dp = perf.best_mapping(s, wl, ctx)
            if dp is None:
                break
            pts.append(dp)
        if len(pts) != len(workloads):
            continue
        geo = math.exp(sum(math.log(p.tco_per_mtoken) for p in pts)
                       / len(pts))
        if geo < best_geo:
            best_server, best_geo, best_points = s, geo, pts
    return best_server, best_geo, best_points
