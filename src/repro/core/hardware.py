"""Chiplet Cloud hardware model (paper §3, §4.1, Table 1).

Models a single accelerator chiplet (CC-MEM SRAM + SIMD compute + chip IO),
the 1U server that carries lanes of chiplets, and their fabrication cost
(yield-aware die cost via the negative-binomial model).

All constants trace to Table 1 of the paper or are calibrated against the
Table 2 design points (see tests/test_core_engine.py):
  * compute density 2.65 mm^2/TFLOPS, power 1.3 W/TFLOPS, <1 W/mm^2
  * SRAM macro density ~2.0 MB/mm^2 at 7nm (calibrated: Table 2 die sizes)
  * wafer $10,000 (300mm), defect density 0.1/cm^2
  * chip IO 25 GB/s x 4 links; 8 lanes/server; <=20 chips, <=6000 mm^2,
    <=250 W per lane; 100GbE $450; PSU/DCDC efficiency 0.95
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

# --- Table 1 constants -----------------------------------------------------
TECH = "7nm"
WAFER_COST = 10_000.0  # $
WAFER_DIAMETER_MM = 300.0
DEFECT_DENSITY_MM2 = 0.1 / 100.0  # 0.1 per cm^2
YIELD_ALPHA = 4.0  # cluster parameter
DIE_TEST_COST = 2.0  # $/die (assumption, documented)

COMPUTE_MM2_PER_TFLOP = 2.65
POWER_W_PER_TFLOP = 1.3
MAX_POWER_DENSITY_W_MM2 = 1.0

SRAM_MB_PER_MM2 = 2.0  # calibrated against Table 2 (see module docstring)
SRAM_LEAKAGE_W_PER_MB = 0.5e-3
SRAM_PJ_PER_BYTE = 1.0  # access energy (12nm->7nm scaled, conservative)
# CC-MEM crossbar: routing rides over the SRAM arrays (NoC symbiosis), but
# decoder + bank control still cost area that grows with the bank count.
CCMEM_AREA_OVERHEAD_BASE = 0.08
CCMEM_BW_PER_MB_BASE = 16.0e9  # bytes/s per MB at the base bank ratio

CHIP_IO_LINKS = 4
CHIP_IO_GBS = 25.0e9  # bytes/s per link
AUX_AREA_MM2 = 4.0  # PHYs, controller, misc per chip

LANES_PER_SERVER = 8
MAX_CHIPS_PER_LANE = 20
MAX_SILICON_PER_LANE_MM2 = 6000.0
MAX_POWER_PER_LANE_W = 250.0
PSU_EFFICIENCY = 0.95
DCDC_EFFICIENCY = 0.95
ETHERNET_COST = 450.0  # 100GbE
SERVER_LIFE_YEARS = 1.5

# Server bill-of-materials assumptions (documented; ASIC Clouds-style).
PCB_COST = 400.0
CONTROLLER_COST = 150.0  # FPGA/uC dispatcher
PSU_COST_PER_W = 0.12
HEATSINK_COST_PER_CHIP = 6.0
FAN_COST = 18.0  # per lane
PACKAGE_BASE_COST = 3.0  # organic substrate, per chip
PACKAGE_COST_PER_MM2 = 0.01


@dataclass(frozen=True)
class ChipConfig:
    """One chiplet design point."""

    die_mm2: float
    sram_mb: float
    tflops: float
    bw_ratio: float = 1.0  # CC-MEM bank-group ratio knob (x base bw/MB)

    # -- derived ------------------------------------------------------------
    @property
    def mem_bw(self) -> float:
        """CC-MEM aggregate bandwidth, bytes/s."""
        return self.sram_mb * CCMEM_BW_PER_MB_BASE * self.bw_ratio

    @property
    def compute_area(self) -> float:
        return self.tflops * COMPUTE_MM2_PER_TFLOP

    @property
    def mem_area(self) -> float:
        # Higher bank ratios cost decoder/control area (crossbar routing is
        # absorbed above the arrays — NoC symbiosis [36]).
        overhead = CCMEM_AREA_OVERHEAD_BASE * self.bw_ratio
        return self.sram_mb / SRAM_MB_PER_MM2 * (1.0 + overhead)

    @property
    def used_area(self) -> float:
        return self.compute_area + self.mem_area + AUX_AREA_MM2

    @property
    def tdp(self) -> float:
        compute = self.tflops * POWER_W_PER_TFLOP
        mem = (self.sram_mb * SRAM_LEAKAGE_W_PER_MB
               + self.mem_bw * SRAM_PJ_PER_BYTE * 1e-12)
        return compute + mem

    def feasible(self) -> bool:
        return (
            20.0 <= self.die_mm2 <= 800.0
            and self.used_area <= self.die_mm2
            and self.tdp / self.die_mm2 <= MAX_POWER_DENSITY_W_MM2
            and self.tflops > 0
            and self.sram_mb > 0
        )

    # -- fabrication cost ----------------------------------------------------
    def dies_per_wafer(self) -> int:
        d = WAFER_DIAMETER_MM
        a = self.die_mm2
        return max(1, int(math.pi * (d / 2) ** 2 / a
                          - math.pi * d / math.sqrt(2 * a)))

    def die_yield(self) -> float:
        return (1.0 + self.die_mm2 * DEFECT_DENSITY_MM2 / YIELD_ALPHA) ** (
            -YIELD_ALPHA)

    def die_cost(self) -> float:
        return (WAFER_COST / self.dies_per_wafer() + DIE_TEST_COST) \
            / self.die_yield()

    def packaged_cost(self) -> float:
        return self.die_cost() + PACKAGE_BASE_COST \
            + PACKAGE_COST_PER_MM2 * self.die_mm2


@dataclass(frozen=True)
class ServerConfig:
    """A 1U Chiplet Cloud server: lanes of chiplets on a 2D torus PCB."""

    chip: ChipConfig
    chips_per_lane: int
    lanes: int = LANES_PER_SERVER

    @property
    def num_chips(self) -> int:
        return self.chips_per_lane * self.lanes

    @property
    def silicon_per_lane(self) -> float:
        return self.chip.die_mm2 * self.chips_per_lane

    @property
    def power_per_lane(self) -> float:
        return self.chip.tdp * self.chips_per_lane

    @property
    def tdp(self) -> float:
        chips = self.chip.tdp * self.num_chips
        # controller+fans ~30W; PSU/DCDC losses on top.
        return (chips + 30.0) / (PSU_EFFICIENCY * DCDC_EFFICIENCY)

    @property
    def sram_mb(self) -> float:
        return self.chip.sram_mb * self.num_chips

    @property
    def tflops(self) -> float:
        return self.chip.tflops * self.num_chips

    def feasible(self) -> bool:
        return (
            self.chip.feasible()
            and 1 <= self.chips_per_lane <= MAX_CHIPS_PER_LANE
            and self.silicon_per_lane <= MAX_SILICON_PER_LANE_MM2
            and self.power_per_lane <= MAX_POWER_PER_LANE_W
        )

    def capex(self) -> float:
        chips = self.chip.packaged_cost() * self.num_chips
        psu = PSU_COST_PER_W * self.tdp
        heatsinks = HEATSINK_COST_PER_CHIP * self.num_chips
        fans = FAN_COST * self.lanes
        return (chips + psu + heatsinks + fans + PCB_COST
                + CONTROLLER_COST + ETHERNET_COST)


def sweep_chips(
    die_sizes=None, mem_fracs=None, bw_ratios=(0.5, 1.0, 2.0, 4.0),
) -> List[ChipConfig]:
    """Phase-1 chip enumeration: every (die, memory split, bank ratio)."""
    die_sizes = die_sizes or [20, 40, 60, 80, 100, 120, 140, 160, 200, 240,
                              280, 320, 400, 480, 560, 640, 720, 800]
    mem_fracs = mem_fracs or [0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
    out = []
    for die in die_sizes:
        budget = die - AUX_AREA_MM2
        for mf in mem_fracs:
            for r in bw_ratios:
                mem_area = budget * mf
                sram = mem_area * SRAM_MB_PER_MM2 / (
                    1.0 + CCMEM_AREA_OVERHEAD_BASE * r)
                tflops = (budget - mem_area) / COMPUTE_MM2_PER_TFLOP
                c = ChipConfig(die_mm2=die, sram_mb=sram, tflops=tflops,
                               bw_ratio=r)
                if c.feasible():
                    out.append(c)
    return out


def sweep_servers(chips: Optional[List[ChipConfig]] = None) -> List[ServerConfig]:
    """Phase-1 server enumeration with floorplan/power/thermal limits."""
    chips = chips or sweep_chips()
    out = []
    for c in chips:
        max_by_si = int(MAX_SILICON_PER_LANE_MM2 // c.die_mm2)
        max_by_pw = int(MAX_POWER_PER_LANE_W // max(c.tdp, 1e-9))
        top = min(MAX_CHIPS_PER_LANE, max_by_si, max_by_pw)
        # Enumerate a few packing densities, not just the max.
        for n in sorted({top, max(1, top // 2), max(1, top // 4)}):
            s = ServerConfig(chip=c, chips_per_lane=n)
            if s.feasible():
                out.append(s)
    return out
