"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation: everything here is abstract, so the dry-run can lower
and compile 235B-parameter training steps on a CPU host.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec
from repro.models import model as M


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs_for(cfg: ModelConfig, shape: ShapeSpec,
                    with_labels: bool) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.is_decode:
        S_tok = 1
    else:
        S_tok = S
    batch: Dict[str, Any] = {"tokens": sds((B, S_tok), jnp.int32)}
    if with_labels:
        batch["labels"] = sds((B, S_tok), jnp.int32)
    if cfg.family == "vlm" and not shape.is_decode:
        batch["patch_embeds"] = sds((B, cfg.num_patches, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.family == "audio" and not shape.is_decode:
        batch["frames"] = sds((B, cfg.encdec.encoder_seq_len, cfg.d_model),
                              jnp.bfloat16)
    return batch


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """All abstract inputs for the step this shape lowers.

    train_*   -> {params, opt_state, batch{tokens,labels}}
    prefill_* -> {params, batch{tokens,...}}
    decode_*  -> {params, cache, tokens, position}
    """
    shape = SHAPES[shape_name]
    params = M.param_specs(cfg)
    out: Dict[str, Any] = {"params": params}
    if shape.kind == "train":
        from repro.training import optimizer as opt
        out["opt_state"] = jax.eval_shape(lambda p: opt.init(p), params)
        out["batch"] = batch_specs_for(cfg, shape, with_labels=True)
    elif shape.kind == "prefill":
        out["batch"] = batch_specs_for(cfg, shape, with_labels=False)
    else:  # decode
        out["cache"] = M.cache_specs(cfg, shape.global_batch, shape.seq_len)
        out["tokens"] = sds((shape.global_batch, 1), jnp.int32)
        out["position"] = sds((), jnp.int32)
    return out
