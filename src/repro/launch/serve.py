"""Serving launcher: batched requests against a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --requests 16 --max-new 32

``--frontend async`` switches from the in-process closed loop to the
``AsyncFrontend`` service posture: requests arrive on an open-loop
Poisson clock (``--arrival-rate`` req/s) through admission control
(``--max-queue-depth`` backpressure, ``--breaker-*`` circuit-breaker
knobs) and the run reports client-side latency percentiles plus
goodput under ``--slo-ttft``:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --frontend async --arrival-rate 8 --max-queue-depth 8

With ``--replicas N`` the trace runs against a fault-tolerant fleet:
``--fault-crash-replica`` / ``--fault-seed`` inject deterministic
replica failures (the router fails in-flight requests over, bit-identical
under greedy sampling) and ``--drain-replica`` starts one replica
administratively drained — the run's ``fault_tolerance`` block reports
deaths, failovers, and failover TTFT percentiles.
"""
from __future__ import annotations

import argparse
import json
import warnings

import jax
import numpy as np

from repro.configs.base import get_config, list_archs
from repro.models import kv_quant
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultPlan, FaultyEngine
from repro.serving.frontend import CircuitBreaker
from repro.serving.openloop import poisson_trace, run_open_loop
from repro.serving.router import ROUTER_POLICIES, run_open_loop_router
from repro.serving.sampler import SamplerConfig
from repro.serving.spec import SPEC_DECODE_MODES
from repro.serving.warmup import trace_prompt_lens, warmup_prefill


def resolve_attn_kernel_arg(attn_kernel, decode_kernel) -> str:
    """Fold the deprecated ``--decode-kernel`` spelling into
    ``--attn-kernel`` (with a DeprecationWarning), defaulting to "auto"."""
    if decode_kernel is not None:
        warnings.warn(
            "--decode-kernel is deprecated; the knob now selects the "
            "prefill kernel too — use --attn-kernel",
            DeprecationWarning, stacklevel=2)
        if attn_kernel is not None and attn_kernel != decode_kernel:
            raise SystemExit(
                f"conflicting --attn-kernel {attn_kernel} and "
                f"--decode-kernel {decode_kernel}")
        return decode_kernel
    return attn_kernel or "auto"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "continuous", "wave"],
                    help="scheduler: continuous batching (attention "
                         "families) or the lockstep wave baseline")
    ap.add_argument("--block-size", type=int, default=8,
                    help="tokens per paged-KV block (>= max_len degenerates "
                         "to one stripe per request)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV block pool size (default: max_batch stripes' "
                         "worth)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="max prompt tokens prefilled per scheduler step "
                         "(0 = whole prompt in one call)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share KV blocks across requests with a common "
                         "prompt prefix (--no-prefix-cache disables; "
                         "retired blocks then free immediately instead of "
                         "lingering in the LRU pool)")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="decode iterations per jitted step / host sync "
                         "(masked early-exit on retirement; >1 amortizes "
                         "dispatch latency over several tokens)")
    ap.add_argument("--attn-kernel", default=None,
                    choices=["auto", "on", "off"],
                    help="attention-kernel implementation for BOTH paged "
                         "hot paths (flash-decode and flash-prefill — "
                         "each walks the block table straight out of the "
                         "shared KV pool; the prefill kernel also fuses "
                         "the new-token K/V scatter): Pallas kernels on "
                         "TPU with 'auto' (default), forced everywhere "
                         "with 'on' (interpret mode off-TPU), or the jnp "
                         "references with 'off'")
    ap.add_argument("--decode-kernel", default=None,
                    choices=["auto", "on", "off"],
                    help="DEPRECATED alias of --attn-kernel (the knob now "
                         "selects the prefill kernel too)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=list(kv_quant.KV_DTYPES),
                    help="paged KV pool representation: fp/bf16 (dense "
                         "compute-dtype blocks), f8 (dense float8 "
                         "stripes), or the SCLAD compressed encodings "
                         "int8/fp8 (payload + per-position fp32 scales; "
                         "~2x token context per device byte, dequantized "
                         "on the load path by references and kernels "
                         "alike).  Default: the config's setting")
    ap.add_argument("--spec-decode", default="off",
                    choices=list(SPEC_DECODE_MODES),
                    help="speculative multi-token decoding: 'ngram' drafts "
                         "continuations from each request's own history, "
                         "verifies them in one chunked-prefill pass and "
                         "rolls rejected K/V back — outputs stay "
                         "bit-identical to 'off'; wins on repetitive/"
                         "structured output, neutral on random text")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens proposed per lane per step "
                         "(with --spec-decode; up to spec_k+1 tokens emit "
                         "per verify pass)")
    ap.add_argument("--preempt-policy", default="youngest",
                    choices=["youngest", "largest", "deadline"],
                    help="which in-flight request pool pressure preempts: "
                         "most recently submitted, most KV blocks held, "
                         "or latest deadline")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens to "
                         "every request (exercises the prefix cache)")
    ap.add_argument("--frontend", default="sync",
                    choices=["sync", "async"],
                    help="'sync': submit everything up front and run the "
                         "engine closed-loop to drain; 'async': the "
                         "AsyncFrontend service posture — open-loop "
                         "Poisson arrivals through streaming admission "
                         "control, reporting client-side tail latency "
                         "and goodput-under-SLO")
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    help="[async] open-loop Poisson arrival rate, "
                         "requests/second (the clock does NOT wait for "
                         "the scheduler — saturate it and the breaker "
                         "sheds)")
    ap.add_argument("--max-queue-depth", type=int, default=32,
                    help="[async] max accepted-but-unfinished requests; "
                         "submits beyond it are rejected 503-style "
                         "(backpressure) instead of queueing unboundedly")
    ap.add_argument("--slo-ttft", type=float, default=2.0,
                    help="[async] client-side TTFT SLO (seconds) for the "
                         "goodput-under-SLO report")
    ap.add_argument("--breaker-window", type=int, default=16,
                    help="[async] circuit breaker: sliding window of "
                         "scheduler ticks scanned for pressure")
    ap.add_argument("--breaker-trip", type=int, default=4,
                    help="[async] pressure ticks (preemption or pool "
                         "saturation) within the window that trip the "
                         "breaker open")
    ap.add_argument("--breaker-sat", type=float, default=1.0,
                    help="[async] live-block pool saturation fraction "
                         "that counts a tick as pressure")
    ap.add_argument("--breaker-cooldown", type=int, default=8,
                    help="[async] ticks the breaker stays open before "
                         "half-opening to admit probes")
    ap.add_argument("--breaker-probes", type=int, default=1,
                    help="[async] probe requests admitted half-open; this "
                         "many clean completions close the breaker")
    ap.add_argument("--replicas", type=int, default=1,
                    help="[async] data-parallel scale-out: run this many "
                         "independent engine replicas behind a "
                         "prefix-affinity router (each replica is its own "
                         "controller — own scheduler, KV pool, breaker; "
                         "requests route to the replica already holding "
                         "their prefix blocks, else least-loaded)")
    ap.add_argument("--router-policy", default="affinity",
                    choices=list(ROUTER_POLICIES),
                    help="[async, --replicas > 1] placement policy: "
                         "'affinity' (prefix-cache match, then "
                         "least-loaded) or the 'round_robin' baseline")
    ap.add_argument("--fault-crash-replica", type=int, default=None,
                    help="[async, --replicas > 1] kill this replica "
                         "mid-run: its engine crashes at "
                         "--fault-crash-tick and the router fails its "
                         "in-flight requests over (outputs stay "
                         "bit-identical under greedy sampling)")
    ap.add_argument("--fault-crash-tick", type=int, default=24,
                    help="[async] engine-step index at which "
                         "--fault-crash-replica dies (deterministic: "
                         "idle pump ticks do not advance it)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="[async] wrap every replica in a seeded chaos "
                         "plan (transient hangs / step errors / "
                         "slowdowns, no crashes; replica i uses "
                         "seed + i) — same seed replays the same faults")
    ap.add_argument("--drain-replica", type=int, default=None,
                    help="[async, --replicas > 1] start with this "
                         "replica administratively drained: it takes no "
                         "placements while its peers serve the trace")
    ap.add_argument("--retry-budget", type=int, default=3,
                    help="[async] max failover re-homings per request "
                         "after replica deaths; exhaustion ends the "
                         "stream with a timeout-kind rejection")
    args = ap.parse_args()

    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.replicas > 1 and args.frontend != "async":
        raise SystemExit("--replicas requires --frontend async (the "
                         "router fronts AsyncFrontend replicas)")
    for flag, val in (("--fault-crash-replica", args.fault_crash_replica),
                      ("--drain-replica", args.drain_replica)):
        if val is not None:
            if args.replicas < 2:
                raise SystemExit(f"{flag} needs --replicas >= 2 (a peer "
                                 f"must absorb the traffic)")
            if not 0 <= val < args.replicas:
                raise SystemExit(f"{flag} {val} out of range for "
                                 f"--replicas {args.replicas}")
    if args.fault_seed is not None and args.frontend != "async":
        raise SystemExit("--fault-seed requires --frontend async")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))

    def make_engine():
        return ServingEngine(
            cfg, params, max_batch=args.max_batch,
            max_len=64 + args.shared_prefix + args.max_new, mode=args.mode,
            seed=args.seed,
            block_size=args.block_size, num_blocks=args.num_blocks,
            prefill_chunk=args.prefill_chunk or None,
            prefix_cache=args.prefix_cache, decode_steps=args.decode_steps,
            attn_kernel=resolve_attn_kernel_arg(args.attn_kernel,
                                                args.decode_kernel),
            preempt_policy=args.preempt_policy, kv_dtype=args.kv_dtype,
            spec_decode=args.spec_decode, spec_k=args.spec_k,
            sampler=SamplerConfig(temperature=args.temperature, top_k=50))

    engine = make_engine()
    rng = np.random.default_rng(args.seed)
    system = rng.integers(1, cfg.vocab_size, size=args.shared_prefix)

    if args.frontend == "async":
        if engine.mode != "continuous":
            raise SystemExit("--frontend async requires the continuous "
                             "scheduler (got mode=wave)")
        trace = poisson_trace(
            rng, args.requests, args.arrival_rate, cfg.vocab_size,
            prompt_len=(4, 16), budget=(args.max_new, args.max_new),
            shared_prefix=system if args.shared_prefix else None,
            prefix_fraction=0.5 if args.shared_prefix else 0.0)
        # Warm the jit caches closed-loop first so the open-loop clock
        # measures serving latency, not compilation — the SAME
        # (group-size, chunk-bucket) coverage rule the bench uses,
        # derived from the actual trace (see serving.warmup).
        engines = [engine] + [make_engine()
                              for _ in range(args.replicas - 1)]
        lens = trace_prompt_lens(trace, engine,
                                 extra=(16 + args.shared_prefix,))
        for e in engines:
            warmup_prefill(e, cfg.vocab_size, prompt_lens=lens)

        # Fault injection wraps AFTER warmup so the plan's step clock
        # starts at the trace, not at cache priming.
        plans = {}
        if args.fault_seed is not None:
            for i in range(len(engines)):
                plans[i] = FaultPlan.seeded(args.fault_seed + i)
        if args.fault_crash_replica is not None:
            i = args.fault_crash_replica
            plans[i] = plans.get(i, FaultPlan()) \
                + FaultPlan.crash_at(args.fault_crash_tick)
        if plans:
            engines = [FaultyEngine(e, plans[i]) if i in plans else e
                       for i, e in enumerate(engines)]
        engine = engines[0]

        def breaker():
            return CircuitBreaker(
                window=args.breaker_window,
                trip_pressure=args.breaker_trip,
                sat_threshold=args.breaker_sat,
                cooldown_ticks=args.breaker_cooldown,
                probes=args.breaker_probes)

        if args.replicas > 1:
            report, router = run_open_loop_router(
                engines, trace, policy=args.router_policy,
                max_queue_depth=args.max_queue_depth,
                breaker_factory=breaker,
                retry_budget=args.retry_budget,
                drain=() if args.drain_replica is None
                else (args.drain_replica,))
            out = report.summary(args.slo_ttft)
            out["routing"] = router.routing_report()
            if plans:
                out["fault_plans"] = {
                    str(i): p.describe() for i, p in sorted(plans.items())}
            print(json.dumps(out, indent=2))
            return
        report = run_open_loop(engine, trace,
                               max_queue_depth=args.max_queue_depth,
                               breaker=breaker())
        print(json.dumps(report.summary(args.slo_ttft), indent=2))
        return

    for i in range(args.requests):
        plen = int(rng.integers(4, 17))
        prompt = np.concatenate(
            [system, rng.integers(1, cfg.vocab_size, size=plen)])
        deadline = None
        if args.preempt_policy == "deadline":
            # Demo deadlines: arrival order + a work proxy, so requests
            # with more remaining work have more slack and are the ones
            # preempted under pool pressure (without this the policy
            # would see only deadline-less requests and degenerate to
            # youngest-first).
            deadline = float(i + len(prompt) + args.max_new)
        engine.submit(prompt, max_new_tokens=args.max_new,
                      deadline=deadline)
    results = engine.run()
    for uid, toks in sorted(results.items())[:4]:
        print(f"req {uid}: {toks[:16]}{'...' if len(toks) > 16 else ''}")
    s = engine.stats
    paged = (f" ({s.prefill_chunks} chunks, prefix hit-rate "
             f"{s.prefix_hit_rate:.0%})",
             f", KV utilization {s.block_utilization:.0%}, "
             f"{s.preemptions} preemptions") \
        if engine.mode == "continuous" else ("", "")
    print(f"prefill {s.prefill_tokens} tok in {s.prefill_s:.2f}s "
          f"({s.prefill_tokens_per_s:.1f} tok/s, mean TTFT "
          f"{s.mean_ttft_s * 1e3:.1f}ms){paged[0]}; "
          f"generated {s.generated_tokens} tok in {s.decode_s:.2f}s "
          f"({s.tokens_per_s:.1f} tok/s, mode={engine.mode}, "
          f"lane occupancy {s.slot_occupancy:.0%}{paged[1]})")
    if engine.spec_decode != "off":
        print(f"spec[{engine.spec_decode}] {s.spec_passes} verify passes, "
              f"draft acceptance {s.spec_acceptance_rate:.0%} "
              f"({s.spec_accepted}/{s.spec_proposed})")


if __name__ == "__main__":
    main()
