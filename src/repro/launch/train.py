"""Training launcher.

Examples:
  # CPU smoke (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 20 --batch 4 --seq 64

  # Production (on a real pod; mesh axes picked up from the runtime):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-235b-a22b \
      --steps 1000 --batch 256 --seq 4096 --ckpt /ckpts/qwen3
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import get_config, list_archs
from repro.training import optimizer as opt_lib
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params={cfg.param_count():,} "
          f"devices={jax.device_count()}")

    tcfg = TrainConfig(
        steps=args.steps, seq_len=args.seq, global_batch=args.batch,
        ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every, seed=args.seed,
        opt=opt_lib.AdamWConfig(lr=args.lr, total_steps=args.steps))
    state = train(cfg, tcfg)
    print(f"finished at step {state.step}")


if __name__ == "__main__":
    main()
