"""Production mesh factory.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* importing
jax; everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16x16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (used by tests and the mapping optimizer)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: Optional[int] = None):
    """A mesh over whatever devices exist (CPU smoke tests: 1 device)."""
    n = jax.device_count()
    mp = model_parallel or 1
    assert n % mp == 0
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes for this mesh ('pod' included when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
