import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  * ``compiled.memory_analysis()``  — proves the step fits per-device HBM
  * roofline FLOPs / bytes / collective wire bytes

XLA's ``cost_analysis()`` counts each ``while`` body ONCE, independent of the
trip count (verified empirically), so a scan-over-layers program would be
undercounted by ~L.  We therefore lower each cell at several static depths and
extrapolate linearly:

    total(L) = f(0) + L * (f(1) - f(0))            (single layer stack)
    hybrid:  f(0) + G*(f(6)-f(0)) + T*(f(1)-f(0))  (G groups, T tail layers)
    audio:   f(00) + Le*(f(10)-f(00)) + Ld*(f(01)-f(00))

The same extrapolation is applied to collective wire bytes (collectives live
inside the layer body).  The chunked-attention inner loops (flash-style
blockwise softmax) are also while loops, so their body is counted once per
layer; we add their cost analytically (exact MAC counts + KV re-reads) via
``_attention_correction``.

Results are written as JSON under ``experiments/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Dict, Tuple

import jax

from repro.configs.base import SHAPES, get_config, list_archs
from repro.core import roofline
from repro.launch import mesh as mesh_lib, steps as steps_lib
from repro.parallel import sharding
from repro.models import model as M
from repro.models import layers as layers_lib

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# ---------------------------------------------------------------------------
# Depth variants per family
# ---------------------------------------------------------------------------

def _variants(cfg) -> Dict[str, object]:
    """Map of label -> reduced-depth config used for extrapolation."""
    if cfg.family == "hybrid":
        per = cfg.hybrid.attn_every
        return {
            "f0": dataclasses.replace(cfg, num_layers=0),
            "f_tail": dataclasses.replace(cfg, num_layers=1),
            "f_group": dataclasses.replace(cfg, num_layers=per),
        }
    if cfg.family == "audio":
        ed = cfg.encdec
        return {
            "f0": dataclasses.replace(
                cfg, num_layers=0,
                encdec=dataclasses.replace(ed, num_encoder_layers=0)),
            "f_enc": dataclasses.replace(
                cfg, num_layers=0,
                encdec=dataclasses.replace(ed, num_encoder_layers=1)),
            "f_dec": dataclasses.replace(
                cfg, num_layers=1,
                encdec=dataclasses.replace(ed, num_encoder_layers=0)),
        }
    return {
        "f0": dataclasses.replace(cfg, num_layers=0),
        "f1": dataclasses.replace(cfg, num_layers=1),
    }


def _combine(cfg, meas: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Linear-extrapolate per-device measurements to full depth.

    Slopes are clamped at zero: the partitioner occasionally picks a
    *cheaper* strategy for the deeper variant (e.g. skipping an all-gather
    the empty-stack program needs), and a negative slope would extrapolate
    to negative cost.
    """
    def lc(*terms):  # base + sum of L_i * max(meas[label_i] - base, 0)
        keys = set(meas["f0"].keys())
        for _, l in terms:
            keys |= set(meas[l].keys())
        out = {}
        for k in keys:
            base = meas["f0"].get(k, 0.0)
            out[k] = base + sum(
                L * max(meas[l].get(k, 0.0) - base, 0.0) for L, l in terms)
        return out

    if cfg.family == "hybrid":
        G = cfg.num_layers // cfg.hybrid.attn_every
        T = cfg.num_layers - G * cfg.hybrid.attn_every
        return lc((G, "f_group"), (T, "f_tail"))
    if cfg.family == "audio":
        Le, Ld = cfg.encdec.num_encoder_layers, cfg.num_layers
        return lc((Le, "f_enc"), (Ld, "f_dec"))
    return lc((cfg.num_layers, "f1"))


# ---------------------------------------------------------------------------
# Analytic correction for chunked-attention inner loops
# ---------------------------------------------------------------------------

def _attention_correction(cfg, shape) -> Tuple[float, float]:
    """(flops, bytes) global, for all blockwise-attention applications.

    Only full-sequence shapes use the chunked path (decode attention has no
    inner loop).  Counts: QK^T and PV MACs (causal halves self-attention),
    plus KV re-reads (each query block re-streams the full K and V).
    """
    if shape.is_decode:
        return 0.0, 0.0
    B = shape.global_batch
    H, D, Hk = cfg.num_heads, cfg.head_dim, cfg.num_kv_heads
    bpe = 2  # bf16

    def self_attn(S, n_apps, causal=True):
        if S < layers_lib.CHUNKED_ATTN_THRESHOLD:
            return 0.0, 0.0  # dense path: fully counted by cost_analysis
        frac = 0.5 if causal else 1.0
        flops = n_apps * 4.0 * B * H * S * S * D * frac
        nq = S // layers_lib.Q_CHUNK
        bytes_ = n_apps * nq * (2.0 * B * S * Hk * D * bpe)
        return flops, bytes_

    S = shape.seq_len
    train_mult = 3.0 if shape.kind == "train" else 1.0  # fwd + remat + bwd

    fam = cfg.family
    if fam in ("dense", "moe"):
        f, b = self_attn(S, cfg.num_layers)
    elif fam == "vlm":
        f, b = self_attn(S + cfg.num_patches, cfg.num_layers)
    elif fam == "hybrid":
        f, b = self_attn(S, cfg.num_layers // cfg.hybrid.attn_every)
    elif fam == "audio":
        f1, b1 = self_attn(S, cfg.num_layers, causal=True)
        f2, b2 = self_attn(cfg.encdec.encoder_seq_len,
                           cfg.encdec.num_encoder_layers, causal=False)
        f, b = f1 + f2, b1 + b2
    else:  # ssm: no attention
        f, b = 0.0, 0.0
    return f * train_mult, b * train_mult


# ---------------------------------------------------------------------------
# Single-cell measurement
# ---------------------------------------------------------------------------

def _measure(cfg, shape_name: str, mesh, want_memory: bool):
    """Lower+compile one config; return per-device cost dict (+mem, hlo)."""
    step, args = steps_lib.step_and_args(cfg, shape_name)
    in_sh, out_sh = steps_lib.shardings_for(cfg, shape_name, mesh)
    # Decode: donate the KV/state cache so XLA aliases it in place instead
    # of copying the full multi-GB cache every token.
    donate = (1,) if SHAPES[shape_name].is_decode else ()
    with sharding.mesh_context(mesh):
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # jax < 0.5 returns [dict] per device
            cost = cost[0] if cost else {}
        cost = cost or {}
        hlo = compiled.as_text()
        mem = compiled.memory_analysis() if want_memory else None
    n_dev = mesh.devices.size
    coll = roofline.parse_collectives(hlo, n_dev)
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.wire_bytes / n_dev,  # per-device wire bytes
    }
    for op, v in coll.by_op.items():
        out[f"wire::{op}"] = v[2] / n_dev
        out[f"count::{op}"] = float(v[0])
    return out, mem, coll


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: Path,
             force: bool = False, kv_dtype: str = "bf16") -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "" if kv_dtype == "bf16" else f"__kv{kv_dtype}"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if kv_dtype != "bf16":
        cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    shape = SHAPES[shape_name]
    ok, why = cfg.shape_supported(shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    try:
        # Full-depth compile: the sharding/memory proof.
        full_cost, mem, coll_full = _measure(cfg, shape_name, mesh,
                                             want_memory=True)
        t_full = time.time() - t0

        # Depth variants for cost extrapolation.
        meas = {}
        for label, vcfg in _variants(cfg).items():
            meas[label], _, _ = _measure(vcfg, shape_name, mesh,
                                         want_memory=False)
        total = _combine(cfg, meas)  # per-device
        af, ab = _attention_correction(cfg, shape)

        flops_g = total["flops"] * n_dev + af
        bytes_g = total["bytes"] * n_dev + ab
        wire_g = total["wire"] * n_dev

        n_params = M.param_count(cfg)
        n_active = M.param_count_active(cfg)
        tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
        mf = roofline.model_flops(n_active, tokens,
                                  train=(shape.kind == "train"))
        terms = roofline.RooflineTerms(
            flops=flops_g, bytes_hbm=bytes_g, wire_bytes=wire_g, chips=n_dev)

        mem_d = {}
        if mem is not None:
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                mem_d[attr] = getattr(mem, attr, None)

        coll_d = {}
        for key, val in sorted(total.items()):
            if key.startswith("wire::"):
                coll_d[key[6:]] = {
                    "wire_bytes_global": val * n_dev,
                    "count_per_layer_body": total.get(
                        "count::" + key[6:], 0.0),
                }

        rec.update(
            status="ok",
            devices=n_dev,
            compile_s=round(t_full, 1),
            total_s=round(time.time() - t0, 1),
            params=n_params,
            params_active=n_active,
            tokens=tokens,
            model_flops=mf,
            flops_hlo_global=flops_g,
            bytes_hlo_global=bytes_g,
            wire_bytes_global=wire_g,
            attention_correction={"flops": af, "bytes": ab},
            useful_flops_ratio=(mf / flops_g) if flops_g else None,
            memory_analysis=mem_d,
            collectives=coll_d,
            roofline=terms.as_dict(),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def run_serving_smoke(out_dir: Path, model_parallel: int = 2,
                      requests: int = 4, max_new: int = 8) -> dict:
    """Multi-host serving smoke: sharded-engine vs solo-engine parity.

    Uses the forced 512-device host platform this module already runs
    under, but builds a SMALL (1, model_parallel) submesh over the first
    few devices (compiling against all 512 would take minutes for a
    smoke).  A reduced engine with the paged pool sharded over ``model``
    must emit greedy tokens identical to the meshless engine — float32
    params so TP psum reduction-order noise cannot flip an argmax — with
    bitwise-identical scheduler stats.  Writes serving_smoke.json.
    """
    import numpy as np

    from repro.serving.engine import ServingEngine

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              num_heads=4, num_kv_heads=4)
    params = jax.tree.map(lambda x: x.astype(jax.numpy.float32),
                          M.init_params(cfg, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(n)).tolist()
               for n in rng.integers(4, 14, size=requests)]

    def run(mesh):
        eng = ServingEngine(cfg, params, max_batch=4, max_len=48,
                            mode="continuous", mesh=mesh, block_size=8,
                            prefill_chunk=8, seed=7)
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        t0 = time.time()
        out = eng.run()
        return out, time.time() - t0, eng.stats

    solo, solo_s, s0 = run(None)
    devs = np.array(jax.devices()[:model_parallel]).reshape(
        1, model_parallel)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    shard, shard_s, s1 = run(mesh)
    rec = {
        "status": "ok" if solo == shard else "error",
        "devices": model_parallel,
        "model_parallel": model_parallel,
        "requests": requests,
        "greedy_identical": solo == shard,
        "stats_identical": (s0.preemptions, s0.admissions,
                            s0.cached_prompt_tokens)
        == (s1.preemptions, s1.admissions, s1.cached_prompt_tokens),
        "solo_wall_s": round(solo_s, 3),
        "sharded_wall_s": round(shard_s, 3),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"serving_smoke__mp{model_parallel}.json"
    path.write_text(json.dumps(rec, indent=2))
    return rec


def cells(mesh_sel: str):
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[mesh_sel]
    for arch in list_archs():
        for shape_name in SHAPES:
            for m in meshes:
                yield arch, shape_name, m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "f8"])
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--serving-smoke", action="store_true",
                    help="run the multi-host serving parity smoke (a "
                         "sharded reduced engine vs the meshless one) "
                         "instead of the compile sweep")
    ap.add_argument("--model-parallel", type=int, default=2,
                    help="[--serving-smoke] model-axis width of the "
                         "submesh the sharded engine runs on")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.serving_smoke:
        rec = run_serving_smoke(out_dir,
                                model_parallel=args.model_parallel)
        print(json.dumps(rec, indent=2))
        return 0 if rec["status"] == "ok" else 1

    if args.all:
        todo = list(cells(args.mesh))
    else:
        assert args.arch and args.shape
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        todo = [(args.arch, args.shape, m) for m in meshes]

    n_ok = n_skip = n_err = 0
    for arch, shape_name, mesh_name in todo:
        rec = run_cell(arch, shape_name, mesh_name, out_dir,
                       force=args.force, kv_dtype=args.kv_dtype)
        st = rec["status"]
        n_ok += st == "ok"
        n_skip += st == "skipped"
        n_err += st == "error"
        extra = ""
        if st == "ok":
            r = rec["roofline"]
            extra = (f"total={rec['total_s']}s bottleneck={r['bottleneck']} "
                     f"t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},"
                     f"{r['t_collective_s']:.2e})s "
                     f"useful={rec['useful_flops_ratio']:.2f}"
                     if rec.get("useful_flops_ratio") else "")
        elif st == "error":
            extra = rec["error"][:160]
        print(f"[{st:7s}] {arch:24s} {shape_name:12s} {mesh_name:6s} {extra}",
              flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
