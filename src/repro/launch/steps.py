"""Jit-able step functions: train_step / prefill_step / serve_step.

These are the functions the dry-run lowers and the launchers execute.  All
distribution is expressed through in/out shardings assembled in
``shardings_for`` — the step bodies are mesh-agnostic.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.parallel import sharding
from repro.training import optimizer as opt_lib


def make_train_step(cfg: ModelConfig,
                    opt_cfg: Optional[opt_lib.AdamWConfig] = None):
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        params, opt_state, metrics = opt_lib.update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, max_len)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, position):
        return M.decode_step(cfg, params, cache, tokens, position)

    return serve_step


# ---------------------------------------------------------------------------
# Sharding assembly
# ---------------------------------------------------------------------------

def shardings_for(cfg: ModelConfig, shape_name: str, mesh):
    """(in_shardings, out_shardings) pytrees for the step of this shape.

    Axis state is scoped to this call (``sharding.use_axes``), not set
    process-globally: callers that later trace the step (e.g. dryrun's
    ``jit(...).lower``) do so under ``sharding.mesh_context(mesh)``, which
    the constrain_* anchors fall back to."""
    from repro.launch import specs as specs_lib

    with sharding.use_axes(mesh):
        return _shardings_for(cfg, shape_name, mesh, specs_lib)


def _shardings_for(cfg: ModelConfig, shape_name: str, mesh, specs_lib):
    shape = SHAPES[shape_name]
    dp = mesh_lib.data_axes(mesh)
    ins = specs_lib.input_specs(cfg, shape_name)

    mode = "train" if shape.kind == "train" else "serve"
    pspec = sharding.param_specs(cfg, ins["params"], mode=mode)
    pspec = sharding.sanitize_specs(pspec, ins["params"])
    san = sharding.sanitize_specs
    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        ospec = opt_lib.AdamWState(
            step=P(), m=san(pspec, ins["opt_state"].m),
            v=san(pspec, ins["opt_state"].v))
        bspec = san(sharding.batch_specs(cfg, ins["batch"], dp,
                                         shape.global_batch), ins["batch"])
        metrics_spec = {"grad_norm": P(), "lr": P(), "loss": P()}
        in_sh = (ns(pspec), ns(ospec), ns(bspec))
        out_sh = (ns(pspec), ns(ospec), ns(metrics_spec))
        return in_sh, out_sh
    if shape.kind == "prefill":
        bspec = san(sharding.batch_specs(cfg, ins["batch"], dp,
                                         shape.global_batch), ins["batch"])
        cshape = M.cache_specs(cfg, shape.global_batch, shape.seq_len)
        cspec = san(sharding.cache_specs(cfg, cshape, dp, shape.global_batch),
                    cshape)
        dpa = dp if shape.global_batch % sharding._axes_size_hint(dp) == 0 else None
        lshape = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.vocab_size), jnp.bfloat16)
        logits_spec = san(P(dpa, "model"), lshape)
        in_sh = (ns(pspec), ns(bspec))
        out_sh = (ns(logits_spec), ns(cspec))
        return in_sh, out_sh
    # decode
    cspec = san(sharding.cache_specs(cfg, ins["cache"], dp,
                                     shape.global_batch), ins["cache"])
    dpa = dp if shape.global_batch % sharding._axes_size_hint(dp) == 0 else None
    tok_spec = san(P(dpa, None), ins["tokens"])
    lshape = jax.ShapeDtypeStruct(
        (shape.global_batch, 1, cfg.vocab_size), jnp.bfloat16)
    logits_spec = san(P(dpa, None, "model"), lshape)
    in_sh = (ns(pspec), ns(cspec), ns(tok_spec), NamedSharding(mesh, P()))
    out_sh = (ns(logits_spec), ns(cspec))
    return in_sh, out_sh


def step_and_args(cfg: ModelConfig, shape_name: str):
    """(step_fn, abstract_args tuple) for lowering this cell."""
    from repro.launch import specs as specs_lib

    shape = SHAPES[shape_name]
    ins = specs_lib.input_specs(cfg, shape_name)
    if shape.kind == "train":
        return make_train_step(cfg), (ins["params"], ins["opt_state"],
                                      ins["batch"])
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape.seq_len), (ins["params"],
                                                       ins["batch"])
    return make_serve_step(cfg), (ins["params"], ins["cache"], ins["tokens"],
                                  ins["position"])
