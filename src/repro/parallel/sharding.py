"""Sharding rules: parameter, cache, batch and optimizer-state PartitionSpecs.

Baseline layout (the "paper-faithful" mapping — tensor parallelism over
``model``, fully-sharded (FSDP/ZeRO-3 style) parameter+optimizer storage over
``data``, replication over ``pod``):

  * attention/MLP weights: 2-D sharded (fan-in over one axis, fan-out over the
    other) — this is the 2-D weight-stationary layout of Pope et al. [37] that
    the paper adopts for the feed-forward network;
  * MoE expert tensors: expert dim over ``model``, expert hidden dim over
    ``data`` (expert parallelism × tensor parallelism);
  * KV caches: batch over data axes, sequence over ``model`` (split-KV decode:
    each model shard owns a contiguous stripe of the context);
  * SSM states: batch over data axes, heads over ``model``.

Rules are path-based so they apply to scan-stacked parameters (leading layer
dims map to None).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec


# ---------------------------------------------------------------------------
# Mesh-axis state (context-scoped, not process-global)
#
# The spec rules and the constrain_* anchors below need to know the active
# mesh's axis sizes at TRACE time.  This used to be a trio of module globals
# mutated by ``set_mesh_axis_sizes`` — which meant one serving mesh per
# process and stale state leaking between components.  The state now lives in
# a ``ContextVar``:
#
#   * ``use_axes(mesh)`` scopes it to a ``with`` block — the serving engine
#     wraps its jitted-function bodies in this, so every engine traces under
#     its OWN mesh regardless of what the rest of the process is doing;
#   * ``set_mesh_axis_sizes(mesh)`` sets it for the current context
#     (scripts / tests that want ambient state);
#   * when nothing was set explicitly, readers fall back to the mesh active
#     in the enclosing jax context (``jax.set_mesh`` / ``with mesh:``), so
#     ``jit(...).lower()`` under ``mesh_context`` sees the right axes without
#     any global hand-off.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AxisState:
    """Immutable snapshot of a mesh's (axis name, size) pairs."""
    sizes: Tuple[Tuple[str, int], ...] = ()

    @classmethod
    def from_mesh(cls, mesh) -> "AxisState":
        if mesh is None:
            return cls()
        try:
            names, shape = tuple(mesh.axis_names), tuple(mesh.devices.shape)
        except AttributeError:  # AbstractMesh: no .devices
            names, shape = tuple(mesh.axis_names), \
                tuple(mesh.shape[a] for a in mesh.axis_names)
        return cls(tuple(zip(names, shape)))

    def size(self, name: Optional[str]) -> int:
        return dict(self.sizes).get(name, 1) if name else 1

    @property
    def dp(self) -> Tuple[str, ...]:
        names = [a for a, _ in self.sizes]
        return tuple(a for a in ("pod", "data") if a in names)

    @property
    def tp(self) -> Optional[str]:
        return "model" if any(a == "model" for a, _ in self.sizes) else None


#: None = nothing explicitly set in this context -> fall back to the ambient
#: jax mesh; an explicit (possibly empty) AxisState always wins.
_AXIS_STATE: "contextvars.ContextVar[Optional[AxisState]]" = \
    contextvars.ContextVar("mesh_axis_state", default=None)


def axis_state() -> AxisState:
    """The axis state readers resolve: explicit context state, else the
    enclosing jax mesh context, else empty (no sharding anchors)."""
    st = _AXIS_STATE.get()
    if st is not None:
        return st
    m = current_mesh()
    return AxisState.from_mesh(m) if m is not None else AxisState()


def set_mesh_axis_sizes(mesh) -> None:
    """Set the axis state for the CURRENT context (script/test ambient use;
    pass an empty-axes mesh to clear).  Engine code should prefer the scoped
    ``use_axes``."""
    _AXIS_STATE.set(AxisState.from_mesh(mesh))


@contextlib.contextmanager
def use_axes(state) -> Iterator[AxisState]:
    """Scope the axis state to a ``with`` block.  ``state`` is an AxisState
    or a mesh (None = explicitly no axes, shadowing any ambient state)."""
    if not isinstance(state, AxisState):
        state = AxisState.from_mesh(state)
    token = _AXIS_STATE.set(state)
    try:
        yield state
    finally:
        _AXIS_STATE.reset(token)


def axis_size(name: Optional[str]) -> int:
    return axis_state().size(name)


def data_axes() -> Tuple[str, ...]:
    """Batch-sharding axes ("pod"/"data") present in the active mesh."""
    return axis_state().dp


def tp_axis() -> Optional[str]:
    """The tensor-parallel axis ("model") if the active mesh has one."""
    return axis_state().tp


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _param_rule(cfg: ModelConfig, path: str, ndim: int, mode: str,
                fsdp: str = "data", tp: str = "model") -> P:
    """Spec for one parameter given its path and rank.

    mode="train": FSDP (ZeRO-3) over ``data`` x TP over ``model`` — weights
    are 2-D sharded and all-gathered per layer inside the step; optimizer
    state stays fully sharded.

    mode="serve": weights live resident (no per-token regather): TP over
    ``model`` only, except MoE expert tensors which use expert parallelism
    over ``data`` x TP over ``model`` — the expert dim is batch-like in the
    expert einsum so no gather is induced.

    The rank-suffix convention: rules name the trailing dims; leading stacked
    layer/group dims are padded with None.
    """
    serve = mode == "serve"
    fs = None if serve else fsdp

    def pad(*spec):
        return P(*([None] * (ndim - len(spec)) + list(spec)))

    leaf = path.rsplit("/", 1)[-1]
    tp_n = axis_size(tp)
    vocab_ok = cfg.vocab_size % tp_n == 0

    # Embedding / unembedding. When the vocab doesn't divide the model axis
    # (e.g. mamba2's 50280), shard the d_model dim instead.
    if leaf == "embed":
        if vocab_ok:
            return P(tp, fs)
        return P(None, tp if serve else fsdp)
    if leaf == "lm_head":
        if vocab_ok:
            return P(fs, tp)
        return P(tp if serve else fsdp, None)
    if leaf == "patch_proj":
        return P(fs, tp)

    # Norm scales/biases: replicated (small).
    if leaf in ("scale", "bias", "conv_b", "A_log", "D", "dt_bias"):
        return pad(None)
    if leaf == "norm_scale":
        return pad(tp)

    # Attention projections.
    if leaf in ("wq", "wk", "wv"):
        return pad(fs, tp)
    if leaf == "wo":
        return pad(tp, fs)
    if leaf in ("bq", "bk", "bv"):
        return pad(tp)

    # Dense / shared-expert MLP.
    if leaf in ("w_gate", "w_up", "w_down"):
        if "moe" in path and "shared" not in path:
            # Expert-stacked: (..., E, d, f) or (..., E, f, d).
            # Expert parallelism over ``data`` x TP, in BOTH modes: the
            # expert dim is batch-like (never gathered) and storage is
            # 256-way sharded.  When the manual-collective path applies
            # (E divides the data axis), TP splits *d_model* so the MoE
            # all-to-alls carry d/tp-sliced payloads and the up-projection
            # psum runs at h-volume (see moe.apply_moe_manual); otherwise
            # TP splits the hidden dim (plain Megatron-in-expert).
            ep_n = axis_size(fsdp)
            d_layout = cfg.moe is not None and ep_n > 1 \
                and cfg.moe.num_experts % ep_n == 0
            if d_layout:
                if leaf == "w_down":
                    return pad(fsdp, None, tp)
                return pad(fsdp, tp, None)
            if leaf == "w_down":
                return pad(fsdp, tp, None)
            return pad(fsdp, None, tp)
        if leaf == "w_down":
            return pad(tp, fs)
        return pad(fs, tp)
    if leaf == "router":
        return pad(fs, None)

    # Mamba2.
    if leaf == "in_proj":
        return pad(fs, tp)
    if leaf == "conv_w":
        return pad(None, tp)
    if leaf == "out_proj":
        return pad(tp, fs)

    return pad(None)


def param_specs(cfg: ModelConfig, params_shape, mode: str = "train") -> Any:
    """PartitionSpec pytree matching params (or their ShapeDtypeStructs)."""
    def rule(path, leaf):
        return _param_rule(cfg, _path_str(path), len(leaf.shape), mode)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, cache_shape, dp: Optional[Tuple[str, ...]],
                batch: int, tp: str = "model", paged: bool = False) -> Any:
    """dp = batch axes (None to replicate small batches).

    paged: the k/v leaves are block pools (L, N, bs, Hk, hd) rather than
    dense (L, B, S, Hk, hd) stripes — any request's block table may point
    anywhere in the pool, so the pool is NOT batch-shardable; shard the KV
    heads over ``model`` instead (matches the decode attention TP layout).
    """
    dpa = dp if (dp and batch % _axes_size_hint(dp) == 0) else None

    def rule(path, leaf):
        nd = len(leaf.shape)
        path_s = _path_str(path)
        name = path_s.rsplit("/", 1)[-1]
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            if paged:
                return P(None, None, None, tp, None)
            # (L, B, S, Hk, hd): batch over dp, sequence over model.
            return P(None, dpa, tp, None, None)
        if name in ("k_scale", "v_scale") and paged:
            # SCLAD scale metadata (L, N, bs, Hk): co-sharded with the
            # payload's KV-head axis so each shard dequantizes locally.
            return P(None, None, None, tp)
        if name == "state":
            # (..., B, H, P, N): heads over model.
            return P(*([None] * (nd - 4)), dpa, tp, None, None)
        if name == "conv":
            # (..., B, k-1, conv_dim): channels over model.
            return P(*([None] * (nd - 3)), dpa, None, tp)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def _axes_size_hint(axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= axis_size(a)
    return n


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, batch_shape, dp: Optional[Tuple[str, ...]],
                batch: int) -> Any:
    dpa = dp if (dp and batch % _axes_size_hint(dp) == 0) else None

    def rule(path, leaf):
        nd = len(leaf.shape)
        return P(dpa, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def sanitize_specs(spec_tree, shape_tree) -> Any:
    """Drop sharding on any dim the mesh axis doesn't divide evenly.

    jax.jit argument shardings require exact divisibility; internal
    with_sharding_constraint does not.  This keeps rules simple and fixes up
    the stragglers (60 experts, 50280 vocab, batch 1, seq 1500, ...).
    """
    def fix(spec, leaf):
        dims = leaf.shape
        out = []
        for i, axes in enumerate(tuple(spec) + (None,) * (len(dims) - len(spec))):
            if axes is None:
                out.append(None)
                continue
            axes_t = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for a in axes_t:
                size *= axis_size(a)
            out.append(axes if dims[i] % size == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Serving-side shard_map helpers (the paged-attention dispatch wrappers in
# kernels/flash_decode/ops.py and kernels/flash_prefill/ops.py)
# ---------------------------------------------------------------------------

#: The tensor-parallel mesh axis every serving-side rule shards over.
TP_AXIS = "model"


def attn_shard_size(mesh, num_kv_heads: int, axis: str = TP_AXIS) -> int:
    """How many ways the paged attention dispatch can shard the KV-head axis.

    The shard_map wrappers split the (N, bs, Hk, D) pool — payload AND
    SCLAD scale leaves — plus the query head groups over ``axis``, with
    everything host-derived (block tables, length/start vectors — the
    kernels' scalar-prefetch operands) broadcast.  Returns 1 (single-device
    dispatch, no wrapper) when there is no mesh, the mesh has no ``axis``
    (or it is trivial), or ``num_kv_heads`` does not divide it evenly —
    exactly the cases ``sanitize_specs`` drops the pool's head sharding
    for, so cache placement and kernel dispatch always agree.
    """
    if mesh is None:
        return 1
    m = AxisState.from_mesh(mesh).size(axis)
    return m if m > 1 and num_kv_heads % m == 0 else 1


def paged_attn_specs(axis: str = TP_AXIS) -> Dict[str, P]:
    """PartitionSpecs for the paged-attention shard_map wrappers.

    Head-axis sharding is contiguous, so a shard's Hk/m KV heads arrive
    with ALL of their ``rep = H // Hk`` query heads (queries are laid out
    head-major) — the per-shard kernel body is the unchanged single-device
    kernel on a contiguous head slice.  ``out_chunk`` is the prefill
    output AFTER its (B, S, H, D) -> (B, S, H*D) head-major flatten, so
    concatenating shards on the last axis restores the full head order.
    """
    return {
        "q_decode": P(None, axis, None),        # (B, H, D) head groups
        "q_chunk": P(None, None, axis, None),   # (B, S, H, D)
        "new_kv": P(None, None, axis, None),    # (B, S, Hk, D) chunk K/V
        "pool": P(None, None, axis, None),      # (N, bs, Hk, D)
        "scale": P(None, None, axis),           # (N, bs, Hk) SCLAD scales
        "host": P(),                            # tables/lengths/starts
        "out_decode": P(None, axis, None),      # (B, H, D)
        "out_chunk": P(None, None, axis),       # (B, S, H*D) head-major
    }


# ---------------------------------------------------------------------------
# jax version compatibility (shard_map moved out of experimental in ~0.6;
# the replication check was renamed check_rep -> check_vma, and the active
# mesh accessor became jax.sharding.get_abstract_mesh)
# ---------------------------------------------------------------------------

def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def current_mesh():
    """The mesh active in the enclosing context (``jax.set_mesh`` /
    ``with mesh:``), or None."""
    try:
        from jax.sharding import get_abstract_mesh
        m = get_abstract_mesh()
        return None if m is None or not m.axis_names else m
    except ImportError:  # jax < 0.5: the `with mesh:` thread resource
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m


def mesh_context(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh on new jax, the
    Mesh context manager on old)."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, KeyError, TypeError):
        return x


SEQUENCE_PARALLEL = True


def _seq_shardable(x, st: AxisState) -> bool:
    """Sequence-parallel residuals (Korthikanti et al.): between blocks the
    (B, S, d) stream is sharded over `model` along S, so saved-for-backward
    activations cost 1/tp the HBM and the TP all-reduce becomes a
    reduce-scatter + all-gather pair (half the wire bytes)."""
    if not SEQUENCE_PARALLEL or st.tp is None or x.ndim < 3:
        return False
    tp_n = st.size(st.tp)
    return tp_n > 1 and x.shape[1] % tp_n == 0 and x.shape[1] > 1


def constrain_tokens(x):
    """Anchor a (B, S, d) activation: batch over data axes; S over model
    when sequence parallelism applies (never for single-token decode)."""
    st = axis_state()
    if not st.dp:
        return x
    seq = st.tp if _seq_shardable(x, st) else None
    return constrain(x, P(st.dp, seq, *([None] * (x.ndim - 2))))


def constrain_logits(x):
    """Anchor (B, S, V) logits: batch over data; S over model when
    sequence-parallel (keeps the fp32 loss buffer sharded), else vocab."""
    st = axis_state()
    if not st.dp:
        return x
    if _seq_shardable(x, st):
        return constrain(x, P(st.dp, st.tp,
                              *([None] * (x.ndim - 2))))
    return constrain(x, P(st.dp, *([None] * (x.ndim - 2)), st.tp))


def constrain_heads(x):
    """Anchor a (B, S, H, D) attention tensor: batch over data, heads TP."""
    st = axis_state()
    if not st.dp:
        return x
    return constrain(x, P(st.dp, None, st.tp, None))
