"""GPipe-style pipeline parallelism with micro-batching (paper §4.2, Fig 6).

The paper's inference schedule overlaps `n` micro-batches over `p` pipeline
stages so per-token latency is max(l_mb, n * l_s).  This module implements
that schedule as a real jax program: a ``shard_map`` over a ``stage`` mesh
axis, with ``lax.ppermute`` moving activations stage->stage each tick.

The layer stack is stacked as (n_stages, layers_per_stage, ...) and each
stage device owns one slice — the same weight-stationary placement the
analytic engine assumes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any, microbatches: jnp.ndarray,
                   mesh, axis: str = "stage") -> jnp.ndarray:
    """Run microbatches (n_mb, mb, ...) through p pipeline stages.

    stage_fn(params_for_stage, x) -> x, applied by every stage.
    stage_params has leading dim n_stages (sharded over `axis`).
    Returns outputs with the same shape as `microbatches`.
    """
    n_stages = mesh.shape[axis]
    n_mb = microbatches.shape[0]

    def body(params, mbs):
        # params: (1, ...) local slice; mbs: (n_mb, mb, ...) replicated.
        stage = jax.lax.axis_index(axis)
        local = jax.tree.map(lambda x: x[0], params)
        mb_shape = mbs.shape[1:]
        # The carry becomes device-varying after ppermute; mark the initial
        # values as varying over the stage axis to satisfy shard_map typing.
        def _vary(x):
            try:
                return jax.lax.pvary(x, (axis,))
            except AttributeError:  # older jax
                return x

        carry = _vary(jnp.zeros(mb_shape, mbs.dtype))
        out = _vary(jnp.zeros_like(mbs))

        def tick(t, state):
            carry, out = state
            # Stage 0 injects microbatch t (while available); other stages
            # consume what arrived from the previous stage.
            inject = jnp.where(t < n_mb, t, n_mb - 1)
            x = jnp.where(stage == 0, mbs[inject], carry)
            y = stage_fn(local, x)
            # Last stage commits its result for microbatch (t - p + 1).
            commit = t - (n_stages - 1)
            commit_c = jnp.clip(commit, 0, n_mb - 1)
            do_commit = (stage == n_stages - 1) & (commit >= 0) & (commit < n_mb)
            starts = (commit_c,) + (0,) * y.ndim
            cur = jax.lax.dynamic_slice(out, starts, (1,) + y.shape)
            new = jnp.where(do_commit, y[None], cur)
            out = jax.lax.dynamic_update_slice(out, new, starts)
            # Shift activations to the next stage.
            carry = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return carry, out

        ticks = n_mb + n_stages - 1
        _, out = jax.lax.fori_loop(0, ticks, tick, (carry, out))
        # Every stage holds zeros except the last: reduce to broadcast.
        return jax.lax.psum(out, axis)

    from repro.parallel import sharding as _sh
    fn = _sh.shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P())
    return fn(stage_params, microbatches)


def split_microbatches(batch: jnp.ndarray, n: int) -> jnp.ndarray:
    """(B, ...) -> (n, B/n, ...)."""
    B = batch.shape[0]
    assert B % n == 0
    return batch.reshape((n, B // n) + batch.shape[1:])
