"""Serving engine: continuous batching over a slot-based KV cache.

The engine prices exactly what the paper's TCO/token metric prices: the
generate stage under heavy multi-tenant load.  The seed's wave batcher
(lockstep waves, bucketed by exact prompt length, host sync per token)
modeled exactly the utilization losses the paper's batching/pipelining
analysis (§4.2, Fig 6/8) says to avoid; this engine replaces it with
Orca/vLLM-style iteration-level scheduling:

  * the KV cache is allocated ONCE as (L, max_batch, ctx, Hk, hd); each
    batch row is a *slot* owned by at most one in-flight request, with a
    per-row ``pos`` pointer so rows decode at different sequence offsets;
  * admission: queued requests (any mix of prompt lengths) are LEFT-padded
    to a power-of-two bucket and prefilled together through a masked
    prefill (``model.prefill_slots``) that writes each prompt's K/V into a
    freed slot at its own offset — no bucket-by-exact-length restriction;
  * decode: one fully jitted masked step carries
    ``(cache, last_logits, pos[B], active[B], budget[B], rng)`` with donated
    buffers; sampling runs inside the jit (``serving.sampler.sample`` with a
    per-row active mask, so finished slots are no-ops) and EOS/budget
    retirement is computed on-device — the hot loop is one dispatch plus one
    token-sized device->host read per generated token;
  * scheduling: slots freed by EOS or ``max_new_tokens`` are refilled from
    the queue between decode iterations (stale K/V needs no zeroing — it is
    dead under the per-row mask and admission overwrites the whole slot
    row; ``model.reset_slot`` exists for callers that want a clean cache).

Families with attention KV caches (dense, moe, vlm) run this continuous
path.  SSM/hybrid/audio recurrent state cannot be left-pad-masked without
polluting the scan state, so those families fall back to the seed's wave
batching; ``mode="wave"`` forces that path for any family (it is the
baseline in ``benchmarks/serving_bench.py``).

On a multi-device mesh, pass ``mesh=``: parameters and the cache are placed
with the serve shardings from ``parallel.sharding`` (mode="serve": resident
TP weights, batch-sharded / sequence-split KV) and the jitted functions
inherit that placement.  Caveat: this sets the sharding module's
process-global axis sizes (they must be visible when the jits trace), so
one serving mesh per process — restore via ``set_mesh_axis_sizes`` if the
process later runs un-meshed work.  On CPU smoke runs the same code
executes on one device.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.parallel import sharding
from repro.serving.sampler import SamplerConfig, sample

# Families whose KV cache supports slot-level admission (see module doc).
CONTINUOUS_FAMILIES = ("dense", "moe", "vlm")


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    generated_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    admissions: int = 0
    # Occupancy: active slots summed over decode steps vs. capacity.
    occupied_slot_steps: int = 0
    slot_steps: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.decode_s, 1e-9)

    @property
    def slot_occupancy(self) -> float:
        return self.occupied_slot_steps / max(self.slot_steps, 1)


def _bucket(n: int, cap: int) -> int:
    """Smallest power-of-two >= n (min 8), capped at the cache capacity."""
    p = 8
    while p < n:
        p *= 2
    return min(p, cap)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0,
                 sampler: Optional[SamplerConfig] = None,
                 mode: str = "auto", pad_id: int = 0, seed: int = 0,
                 mesh=None):
        """mode: "auto" (continuous where the family supports it),
        "continuous" (error if unsupported) or "wave" (force the legacy
        lockstep baseline)."""
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.sampler = sampler or SamplerConfig()
        self.stats = EngineStats()
        self._queue: List[Request] = []
        self._uid = 0

        if mode == "auto":
            mode = "continuous" if cfg.family in CONTINUOUS_FAMILIES \
                else "wave"
        if mode == "continuous" and cfg.family not in CONTINUOUS_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} has no slot-addressable KV cache; "
                f"use mode='wave'")
        self.mode = mode

        self.params = params
        self._mesh = mesh
        if mesh is not None:
            self.params = self._place_serve(mesh, params)

        # CPU backend has no buffer donation; skip it to avoid warnings.
        donate = jax.default_backend() != "cpu"

        # Legacy wave path (also the fallback for recurrent-state families).
        self._prefill = jax.jit(
            lambda p, b: M.prefill(cfg, p, b, max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))

        if self.mode == "continuous":
            self._init_continuous(donate, seed)

    # -- public API ----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        prompt = np.asarray(prompt, np.int32)
        if max_new_tokens < 1:
            # The wave path would silently emit nothing while the slot
            # scheduler always decodes once: reject uniformly instead.
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) >= self.max_len:
            # Same bound in both modes: wave prefill would otherwise fail
            # deep in cache padding (or silently emit nothing at exactly
            # max_len).
            raise ValueError(
                f"prompt length {len(prompt)} leaves no decode room in a "
                f"{self.max_len}-token cache")
        self._uid += 1
        self._queue.append(Request(self._uid, prompt, max_new_tokens))
        return self._uid

    def step(self) -> List[Tuple[int, List[int]]]:
        """One scheduler iteration: admit queued requests into free slots,
        then run one jitted masked decode step across all slots.

        Returns the requests finished this iteration as (uid, tokens).
        """
        if self.mode != "continuous":
            raise RuntimeError(
                f"step() requires mode='continuous' (engine is in "
                f"{self.mode!r} mode); use run()")
        self._admit()
        if not self._host_active.any():
            return []

        t0 = time.perf_counter()
        (self._cache, self._logits, self._pos, self._active, self._budget,
         host_out, self._key) = self._decode_fn(
            self.params, self._cache, self._logits, self._pos, self._active,
            self._budget, self._key)
        host = np.asarray(host_out)  # the per-token host sync point
        tok_h, active_h = host[0], host[1].astype(bool)
        self.stats.decode_s += time.perf_counter() - t0

        was = self._host_active
        self.stats.decode_steps += 1
        self.stats.occupied_slot_steps += int(was.sum())
        self.stats.slot_steps += self.max_batch

        finished: List[Tuple[int, List[int]]] = []
        for i in np.nonzero(was)[0]:
            r = self._slot_req[i]
            r.output.append(int(tok_h[i]))
            self.stats.generated_tokens += 1
            if not active_h[i]:
                r.done = True
                finished.append((r.uid, r.output))
                self._slot_req[i] = None
        # Freed slots are NOT zeroed here: stale K/V is dead under the
        # per-row mask and admission overwrites the full slot row, while a
        # reset would copy the whole cache on donation-less backends.
        # model.reset_slot exists for callers that need a clean cache.
        self._host_active = active_h
        return finished

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue; returns uid -> generated tokens."""
        if self.mode != "continuous":
            return self._run_waves()
        results: Dict[int, List[int]] = {}
        while self._queue or self._host_active.any():
            for uid, toks in self.step():
                results[uid] = toks
        return results

    # -- continuous internals ------------------------------------------------
    def _init_continuous(self, donate: bool, seed: int) -> None:
        cfg, B = self.cfg, self.max_batch
        self._cache = M.init_cache(cfg, B, self.max_len)
        if self._mesh is not None:
            self._cache = self._place_cache(self._mesh, self._cache)
        ldtype = self.params["embed"].dtype
        self._logits = jnp.zeros((B, cfg.vocab_size), ldtype)
        self._pos = jnp.zeros((B,), jnp.int32)
        self._active = jnp.zeros((B,), bool)
        self._budget = jnp.zeros((B,), jnp.int32)
        self._key = jax.random.PRNGKey(seed)
        self._slot_req: List[Optional[Request]] = [None] * B
        self._host_active = np.zeros(B, bool)

        sampler, eos_id, pad_id = self.sampler, self.eos_id, self.pad_id

        def decode_step(params, cache, last_logits, pos, active, budget,
                        key):
            key, sub = jax.random.split(key)
            tok = sample(sampler, last_logits, sub, active=active,
                         pad_id=pad_id)
            budget = budget - active.astype(jnp.int32)
            retire = active & ((tok == eos_id) | (budget <= 0))
            # All slots run the model (a retired/free slot is a masked
            # no-op lane — the occupancy loss the stats report); the
            # active mask keeps dead lanes out of MoE expert capacity.
            logits, cache = M.decode_step(cfg, params, cache, tok[:, None],
                                          pos, active=active)
            pos = pos + active.astype(jnp.int32)
            new_active = active & ~retire
            # One packed (2, B) buffer -> a single device->host read per
            # token in the scheduler loop.
            host_out = jnp.stack([tok, new_active.astype(jnp.int32)])
            return (cache, logits[:, 0], pos, new_active, budget, host_out,
                    key)

        self._decode_fn = jax.jit(
            decode_step,
            donate_argnums=(1, 2, 3, 4, 5, 6) if donate else ())
        # One jit handles every (group size, bucket) shape combination;
        # power-of-two buckets keep the number of retraces small.
        self._prefill_slots = jax.jit(
            lambda p, c, t, ln, s: M.prefill_slots(cfg, p, c, t, ln, s),
            donate_argnums=(1,) if donate else ())

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self._slot_req) if r is None]
        if not self._queue or not free:
            return
        take = self._queue[:len(free)]
        del self._queue[:len(take)]
        slots = np.asarray(free[:len(take)], np.int32)
        P = _bucket(max(len(r.prompt) for r in take), self.max_len)
        tokens = np.full((len(take), P), self.pad_id, np.int32)
        lengths = np.empty(len(take), np.int32)
        budgets = np.empty(len(take), np.int32)
        for j, r in enumerate(take):
            S = len(r.prompt)
            tokens[j, P - S:] = r.prompt  # left-pad
            lengths[j] = S
            budgets[j] = min(r.max_new_tokens, self.max_len - S)

        t0 = time.perf_counter()
        logits_new, self._cache = self._prefill_slots(
            self.params, self._cache, jnp.asarray(tokens),
            jnp.asarray(lengths), jnp.asarray(slots))
        self._logits = self._logits.at[slots].set(logits_new)
        self._pos = self._pos.at[slots].set(lengths)
        self._active = self._active.at[slots].set(True)
        self._budget = self._budget.at[slots].set(budgets)
        jax.block_until_ready(self._logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += int(lengths.sum())
        self.stats.admissions += len(take)
        for i, r in zip(slots, take):
            self._slot_req[int(i)] = r
        self._host_active[slots] = True

    # -- mesh placement ------------------------------------------------------
    def _place_serve(self, mesh, params):
        sharding.set_mesh_axis_sizes(mesh)
        specs = sharding.param_specs(self.cfg, params, mode="serve")
        specs = sharding.sanitize_specs(specs, params)
        return jax.device_put(params, sharding.to_shardings(mesh, specs))

    def _place_cache(self, mesh, cache):
        specs = sharding.cache_specs(
            self.cfg, cache, sharding._DP_AXES or None, self.max_batch)
        specs = sharding.sanitize_specs(specs, cache)
        return jax.device_put(cache, sharding.to_shardings(mesh, specs))

    # -- legacy wave path ----------------------------------------------------
    def _run_waves(self) -> Dict[int, List[int]]:
        """Lockstep wave batching, bucketed by exact prompt length (padding
        would let real tokens attend to pads without the masked-prefill
        machinery of the continuous path)."""
        results: Dict[int, List[int]] = {}
        by_len: Dict[int, List[Request]] = {}
        for r in self._queue:
            by_len.setdefault(len(r.prompt), []).append(r)
        self._queue = []
        for _, reqs in sorted(by_len.items()):
            for i in range(0, len(reqs), self.max_batch):
                wave = reqs[i: i + self.max_batch]
                self._run_wave(wave)
                for r in wave:
                    results[r.uid] = r.output
        return results

    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        S = len(wave[0].prompt)  # waves are same-length by construction
        toks = np.stack([r.prompt for r in wave]).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, self.cfg.num_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encdec.encoder_seq_len, self.cfg.d_model),
                jnp.bfloat16)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += B * S
        self.stats.admissions += B

        max_new = min(max(r.max_new_tokens for r in wave),
                      self.max_len - S)
        key = jax.random.PRNGKey(self._uid)
        done = np.zeros(B, bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            self.stats.decode_steps += 1
            self.stats.occupied_slot_steps += int((~done).sum())
            self.stats.slot_steps += self.max_batch
            key, sub = jax.random.split(key)
            next_tok = sample(self.sampler, logits.reshape(B, -1), sub)
            nt = np.asarray(next_tok)
            for i, r in enumerate(wave):
                if not done[i] and len(r.output) < r.max_new_tokens:
                    r.output.append(int(nt[i]))
                    self.stats.generated_tokens += 1
                    if nt[i] == self.eos_id:
                        done[i] = True
                if len(r.output) >= r.max_new_tokens:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode(
                self.params, cache, next_tok[:, None], jnp.int32(S + step))
            logits = logits[:, 0]
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t0
