"""Serving engine: continuous batching over a ref-counted paged KV cache.

The engine prices exactly what the paper's TCO/token metric prices: the
generate stage under heavy multi-tenant load.  PR 1 replaced the seed's
lockstep wave batcher with Orca-style iteration-level scheduling; PR 2 made
KV memory block-granular (paged allocation + chunked prefill).  This version
makes the block pool a **shared, content-addressed store** and drops the
worst-case reservation:

  * the KV cache is ONE pool of fixed-size token blocks
    (``model.init_paged_cache``) addressed through per-lane block tables in
    the jitted decode/prefill steps — unchanged from PR 2;
  * **prefix caching**: full blocks are registered in a hash-chained prefix
    index (``serving.paged.BlockStore``).  ``admit`` matches the longest
    cached prefix of the prompt and the lane STARTS with those blocks —
    prefill runs only the uncached tail, entering the existing chunked
    continuation path with ``start = cached_len``.  Requests sharing a
    system prompt or few-shot header therefore share its KV bytes and skip
    its prefill compute.  At least one prompt token is always recomputed
    (decode needs the last-token logits);
  * retired requests' full blocks linger in an **LRU pool** (still
    matchable) until allocation pressure evicts them, so a request admitted
    after its prefix donor finished still hits;
  * **copy-on-write**: before any write the engine runs a write barrier
    (``ensure_writable``) — a block another lane can read is swapped for a
    fresh block and its device payload copied, so sharing is never
    observable through the attention gather.  (With full-block-only sharing
    writes land past the shared prefix by construction; the barrier makes
    that an enforced invariant rather than an accident.)
  * **optimistic admission + preemption**: nothing is reserved.  A request
    is admitted when the store can cover its *uncached prompt* plus one
    decode block; decode growth may then run the pool dry
    (``OutOfBlocks``), and the engine **preempts the youngest request** —
    release its blocks, re-queue it at the head with its generated tokens
    appended to the prompt, recompute on re-admission.  Its full blocks
    usually survive in the LRU pool, so the recompute is mostly prefix-cache
    hits.  Sampling keys are POSITIONAL — token p of request uid samples
    with ``fold_in(fold_in(seed, uid), p)`` — so stochastic outputs are
    independent of co-tenants AND unchanged by preemption, with O(1)
    resume;
  * **multi-step decode** (``decode_steps=k``): the jitted step runs k
    decode iterations per host sync (``lax.scan`` with masked early-exit on
    EOS/budget retirement), amortizing dispatch + device->host latency over
    k tokens.  Defaults to 1 (bit-identical to the single-step engine);
  * **fused paged attention, decode AND prefill**: the jitted decode
    step's attention reads go through
    ``kernels.flash_decode.ops.decode_attention`` and every prefill
    chunk's through ``kernels.flash_prefill.ops.prefill_attention`` — on
    TPU the Pallas kernels walk each lane's blocks through its table
    straight out of the shared pool (KV bytes streamed exactly once, the
    CC-MEM contract), instead of first gathering a dense O(B*T*bs*Hk*D)
    per-lane copy of the pool; the prefill kernel additionally derives
    the causal/left-pad mask from scalars (no dense (B, S, S) mask) and
    scatters the chunk's new K/V into the pool INSIDE the same kernel
    invocation (``input_output_aliases`` — no separate HBM round-trip).
    ``attn_kernel`` selects the implementation for both paths
    ("auto"/"on"/"off"; "on" uses Pallas interpret mode off-TPU — the CI
    parity path); ``decode_kernel=`` is accepted as a deprecated alias.

Correctness contract (pinned by tests/test_continuous_batching.py): greedy
outputs are bit-identical with prefix caching on or off, across concurrent
prefix sharers, LRU revivals and preemption-recompute.

Knobs (see also examples/quickstart.py):
  * ``block_size`` — tokens per KV block.  Small blocks (8-16) minimize
    fragmentation AND maximize prefix-sharing granularity (only FULL blocks
    are shared); ``block_size >= max_len`` degenerates to one stripe per
    request.
  * ``num_blocks`` — pool size; defaults to ``max_batch`` full-length
    stripes' worth.
  * ``prefill_chunk`` — max prompt tokens prefilled per scheduler
    iteration (None = whole prompt in one call).
  * ``prefix_cache`` — block sharing on/off (off: every block exclusive,
    released blocks return straight to the free list).
  * ``decode_steps`` — decode iterations per jitted step / host sync.
  * ``attn_kernel`` — attention-kernel implementation for the paged
    decode AND chunked-prefill hot paths ("auto" = kernels on TPU /
    references elsewhere; "on" forces the kernels, interpret mode
    off-TPU; "off" forces the jnp references — the pre-kernel gather
    paths).  ``decode_kernel`` is the deprecated PR-4 spelling.
  * ``preempt_policy`` — pool-pressure victim selection: "youngest"
    (default), "largest" (most blocks held) or "deadline".  Under
    "deadline" eviction order is STRICT on ``submit(deadline=...)``:
    the latest deadline (most slack) is evicted first, and a request
    with ``deadline=None`` is treated as infinitely late — evicted
    before ANY request that named a deadline (ties broken youngest-
    first).  This makes ``deadline=`` the admission-priority surface:
    the async frontend (``serving.frontend``) maps request priorities
    onto it, so deadline-less best-effort traffic is always shed ahead
    of SLO-carrying traffic.  Pinned by
    tests/test_decode_dispatch.py::test_preempt_policy_deadline_strict_order.
  * ``kv_dtype`` — on-device KV pool representation.  "fp"/"bf16" store
    dense compute-dtype blocks; "int8"/"fp8" store the SCLAD compressed
    pool (``models.kv_quant``: int8 / float8_e4m3fn payload + per-
    position-per-head fp32 scales) — every reader dequantizes on load,
    so a fixed device byte budget holds ~2x the token context.  The
    prefix-cache hash chain is namespaced per encoding
    (``paged.chain_root_for``), so pools with different kv_dtype
    settings can never false-share blocks.  Composes with
    ``attn_kernel``: both the jnp references and the Pallas kernels
    fuse the dequant into their block-streaming loops.

vlm note: the patch prefix is part of each lane's cache, so its positions
enter the hash chain as sentinel ids and the PATCH-EMBEDDING DIGEST seeds
the lane's chain root: requests submitted with the same image (or both
with the zero stub, the default) share the prefix; identical token ids
with different images can never false-share.

Families with attention KV caches (dense, moe, vlm) run this continuous
path.  SSM/hybrid/audio recurrent state cannot be left-pad-masked without
polluting the scan state, so those families fall back to the seed's wave
batching; ``mode="wave"`` forces that path for any family.

On a multi-device mesh, pass ``mesh=``: parameters and the cache are placed
with the serve shardings from ``parallel.sharding`` (mode="serve").  Axis
state is ENGINE-SCOPED (``sharding.use_axes`` wraps every jitted-function
body), so several engines with different meshes can coexist in one process
and nothing leaks into ambient sharding state.
"""
from __future__ import annotations

import functools
import hashlib
import math
import time
import warnings
from dataclasses import dataclass, field, replace as dc_replace
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.flash_prefill.ops import ATTN_KERNEL_MODES
from repro.models import kv_quant
from repro.models import model as M
from repro.parallel import sharding
from repro.serving.paged import (BlockStore, CHAIN_ROOT, OutOfBlocks,
                                 TRASH_BLOCK, chain_hashes, chain_root_for)
from repro.serving.sampler import SamplerConfig, positional_keys, sample
from repro.serving.spec import SPEC_DECODE_MODES, make_proposer

# Families whose KV cache supports block-level admission (see module doc).
CONTINUOUS_FAMILIES = ("dense", "moe", "vlm")

#: Victim-selection policies for pool-pressure preemption.
PREEMPT_POLICIES = ("youngest", "largest", "deadline")


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32 — the ORIGINAL prompt
    max_new_tokens: int
    output: List[int] = field(default_factory=list)
    done: bool = False
    #: Soft completion deadline (any monotone unit; only ORDER matters) —
    #: consumed by preempt_policy="deadline".  None = no deadline.
    deadline: Optional[float] = None
    #: vlm only: per-request patch embeddings (num_patches, d_model); None
    #: = the engine-constant zero stub.
    patch_embeds: Optional[np.ndarray] = None
    #: sha256 chain-root seed derived from patch_embeds (vlm) or the
    #: global CHAIN_ROOT — two requests may share prefix blocks only if
    #: their seeds agree, so identical token ids with different images
    #: never false-share.
    chain_seed: bytes = CHAIN_ROOT


@dataclass
class _Prefilling:
    """A request mid-admission: its prompt is entering the cache in chunks.

    ``tokens`` is the EFFECTIVE prompt (original prompt plus any tokens
    generated before a preemption — recompute replays them as prompt).
    ``consumed`` counts effective-prompt tokens already in the cache; it
    starts at the prefix-cache hit length, so prefill begins at the
    uncached tail.  ``cached_len`` is the cache-position hit length
    (including any vlm patch prefix) — nonzero means the first chunk uses
    the continuation path (the cached context is gathered, patches are NOT
    re-embedded)."""
    req: Request
    lane: int
    budget: int  # decode budget remaining (clamped; minus pre-preemption output)
    tokens: np.ndarray
    consumed: int = 0
    cached_len: int = 0
    counted_cached: int = 0  # cached tokens credited to stats at admission


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    prefill_chunks: int = 0
    cached_prompt_tokens: int = 0  # prompt tokens skipped via prefix cache
    generated_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    admissions: int = 0
    preemptions: int = 0
    # Concurrency capacity (continuous mode): peak simultaneously DECODING
    # lanes — requests that finished prefill and hold every block they
    # need.  Admission is optimistic (lanes fill before blocks are
    # consumed), so under pool pressure this — not admissions — is what
    # the pool caps: preemption evicts the overflow during the prefill
    # storm and the survivors decode together.  The SCLAD capacity claim
    # is exactly this number at a fixed pool byte budget — a compressed
    # pool affords more blocks, so more lanes sustain concurrently.
    peak_decode_lanes: int = 0
    # Time-to-first-token (submit -> first generated token observed at a
    # host sync), summed over finished-first-token requests.  The paged
    # flash-prefill work prices exactly this: TTFT is the prefill-side
    # latency metric the decode-side tokens_per_s cannot see.
    ttft_s_sum: float = 0.0
    ttft_count: int = 0
    # Per-request latency DISTRIBUTIONS (open-loop serving prices tails,
    # not means — a p99 TTFT SLO is what admission control defends):
    #   ttft_history — one submit->first-token sample per request;
    #   itl_history  — inter-token latency samples at OBSERVATION
    #     granularity: tokens are released to the host at decode-window
    #     syncs, so each token after a request's first records the gap
    #     since that request's previous observation, divided evenly over
    #     the tokens released in the same window (with decode_steps=1
    #     every sample is a real host-sync gap; a preemption recompute
    #     shows up as one honest, long gap — exactly the client's stall).
    ttft_history: List[float] = field(default_factory=list)
    itl_history: List[float] = field(default_factory=list)
    # Requests aborted by the caller mid-flight (async frontend
    # cancellation); their blocks are released like a retirement.
    cancellations: int = 0
    # Peak PHYSICAL pool occupancy: blocks referenced by >= 1 lane at the
    # worst moment (retired-but-resident LRU blocks do NOT count — they
    # are reclaimable).  This is the number CC-MEM capacity planning
    # prices.  kv_block_bytes is device bytes per block across all
    # layers, K+V (filled in by the engine).
    peak_live_blocks: int = 0
    kv_block_bytes: int = 0
    # Occupancy: active lanes summed over decode steps vs. lane capacity.
    occupied_slot_steps: int = 0
    slot_steps: int = 0
    # KV memory: live LOGICAL tokens summed over decode steps vs. pool
    # tokens.  With prefix sharing the ratio can exceed 1.0 — lanes are
    # serving more token-context than the pool physically stores.
    used_token_steps: int = 0
    pool_token_steps: int = 0
    # Speculative decoding (spec_decode != "off"): verify passes run, draft
    # tokens proposed, and draft tokens accepted (the emitted-ahead-of-
    # plain-decode tokens; the per-pass anchor token is not a draft and
    # counts in neither).  acceptance = accepted / proposed is the knob
    # benchmarks watch: every accepted draft amortizes one full-pool KV
    # sweep, every rejected one cost a wasted optimistic write + rollback.
    spec_passes: int = 0
    spec_proposed: int = 0
    spec_accepted: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.decode_s, 1e-9)

    @property
    def prefill_tokens_per_s(self) -> float:
        """Prompt tokens prefilled per second of prefill wall time (cached
        prefix tokens are skipped work — they do not count)."""
        return self.prefill_tokens / max(self.prefill_s, 1e-9)

    @property
    def mean_ttft_s(self) -> float:
        """Mean submit->first-token latency over requests that produced at
        least one token."""
        return self.ttft_s_sum / max(self.ttft_count, 1)

    @staticmethod
    def percentile(history: List[float], q: float) -> float:
        """Nearest-rank percentile: the ceil(q/100 * n)-th order statistic
        (q in (0, 100]).  Always an OBSERVED sample — no interpolation —
        so unit pins on hand-built histories are exact.  Empty history
        returns 0.0 (no traffic, no tail)."""
        if not history:
            return 0.0
        if not 0.0 < q <= 100.0:
            raise ValueError(f"percentile q={q} outside (0, 100]")
        xs = sorted(history)
        return xs[max(0, math.ceil(q / 100.0 * len(xs)) - 1)]

    @property
    def p50_ttft_s(self) -> float:
        return self.percentile(self.ttft_history, 50.0)

    @property
    def p99_ttft_s(self) -> float:
        return self.percentile(self.ttft_history, 99.0)

    @property
    def p50_itl_s(self) -> float:
        return self.percentile(self.itl_history, 50.0)

    @property
    def p99_itl_s(self) -> float:
        return self.percentile(self.itl_history, 99.0)

    @property
    def slot_occupancy(self) -> float:
        return self.occupied_slot_steps / max(self.slot_steps, 1)

    @property
    def mean_active_requests(self) -> float:
        """Concurrent in-decode requests averaged over decode steps."""
        return self.occupied_slot_steps / max(self.decode_steps, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens served from the prefix cache instead
        of being prefilled (recompute after preemption counts as prefill,
        so thrash shows up here too)."""
        seen = self.cached_prompt_tokens + self.prefill_tokens
        return self.cached_prompt_tokens / max(seen, 1)

    @property
    def block_utilization(self) -> float:
        """Live logical tokens vs. pool token capacity, averaged over
        decode steps.  >1.0 means prefix sharing is serving more context
        than the pool stores — the capacity win §4.2 prices into
        TCO/token."""
        return self.used_token_steps / max(self.pool_token_steps, 1)

    @property
    def peak_pool_bytes(self) -> int:
        """Peak device bytes held by live KV blocks."""
        return self.peak_live_blocks * self.kv_block_bytes

    @property
    def spec_acceptance_rate(self) -> float:
        """Accepted draft tokens over proposed draft tokens (0.0 when
        speculation is off or never proposed anything)."""
        return self.spec_accepted / max(self.spec_proposed, 1)


def _bucket(n: int, cap: int) -> int:
    """Smallest power-of-two >= n (min 8), capped at cap."""
    p = 8
    while p < n:
        p *= 2
    return min(p, cap)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0,
                 sampler: Optional[SamplerConfig] = None,
                 mode: str = "auto", pad_id: int = 0, seed: int = 0,
                 mesh=None, block_size: int = 8,
                 num_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = 32,
                 prefix_cache: bool = True,
                 decode_steps: int = 1,
                 attn_kernel: Optional[str] = None,
                 decode_kernel: Optional[str] = None,
                 preempt_policy: str = "youngest",
                 kv_dtype: Optional[str] = None,
                 spec_decode: str = "off", spec_k: int = 4):
        """mode: "auto" (continuous where the family supports it),
        "continuous" (error if unsupported) or "wave" (force the legacy
        lockstep baseline).

        mesh: optional device mesh for tensor scale-up.  One engine is ONE
        controller over one mesh — the single-controller-per-replica model:
        the host-side scheduler (queue, BlockStore, block tables, preempt/
        admit decisions) runs unreplicated on this process, and the mesh
        only widens the jitted device work.  What shards over the mesh's
        ``model`` axis: the weights (``param_specs(mode="serve")``), the
        paged KV pool's KV-head axis — payload AND SCLAD scale leaves,
        co-placed by ``cache_specs(paged=True)`` — and, through the
        ``shard_map`` wrappers in ``kernels.*.ops``, the attention heads
        of both paged hot paths.  What broadcasts: block tables, lengths/
        start vectors and every other scalar-prefetch operand, sampled
        tokens, logits, and all scheduler state.  Replica scale-OUT (many
        engines, each with its own mesh or none) lives one level up in
        ``serving.router.ReplicaRouter``.

        block_size / num_blocks / prefill_chunk / prefix_cache /
        decode_steps: paged-KV and scheduler knobs, see the module
        docstring.

        attn_kernel: overrides ``cfg.attn_kernel`` — the implementation of
        BOTH paged attention hot paths (flash-decode and flash-prefill):
        "auto" (Pallas kernels on TPU, jnp references elsewhere), "on"
        (always the kernels; interpret mode off-TPU) or "off" (always the
        references).  None keeps the config's setting.  ``decode_kernel=``
        is the deprecated PR-4 spelling and maps onto ``attn_kernel`` with
        a DeprecationWarning.

        preempt_policy: which in-flight request pool pressure evicts —
        "youngest" (highest uid; the default, matches prior behavior),
        "largest" (most KV blocks held: frees the most memory per
        eviction) or "deadline" (latest ``submit(deadline=...)`` first;
        requests without a deadline are evicted before any with one).

        kv_dtype: overrides ``cfg.kv_dtype`` — the paged pool's on-device
        representation: "fp"/"bf16" (dense compute-dtype blocks, the
        default), "f8" (dense float8 stripes, legacy), or the SCLAD
        compressed encodings "int8"/"fp8" (payload + per-position fp32
        scales; ~2x token context per device byte, dequantized on load
        by references and kernels alike).  None keeps the config's
        setting.  See the module docstring.

        spec_decode / spec_k: speculative multi-token decoding ("off" or
        "ngram"; continuous mode only).  Per scheduler step each lane
        samples its next token as usual, then a draft proposer
        (``serving.spec``) proposes up to ``spec_k`` continuation tokens
        from the request's own history; the (anchor + drafts) chunk is
        scored in ONE pass through the chunked-prefill path (drafted K/V
        written into the pool optimistically) and the engine keeps the
        longest draft prefix matching what plain decode would have
        sampled, rolling the rejected tail back via
        ``BlockStore.truncate``.  Correctness contract: emitted tokens
        are BIT-IDENTICAL to ``spec_decode="off"`` for greedy AND
        stochastic sampling — the verify pass re-samples each drafted
        position with the SAME positional PRNG key plain decode would
        have used (``sampler.positional_keys``: the token at position p
        of request uid draws from ``fold_in(fold_in(seed, uid), p)``).
        The PRNG "fast-forward" rule falls out of that: positions only
        advance by ACCEPTED tokens, so the stochastic stream never skips
        ahead over rejected drafts — speculation changes throughput,
        never outputs.  With speculation on, each ``step()`` runs one
        verify pass (up to ``spec_k + 1`` tokens per lane) and
        ``decode_steps`` window batching is not used.

        Scope of the bit-identity contract: it is EXACT on the jnp
        reference path (``attn_kernel="off"``, or "auto" off-TPU).
        Under ``attn_kernel="on"`` speculation moves decode-position
        scoring from the flash-decode kernel into the flash-prefill
        kernel, whose online-softmax accumulation tiles keys differently
        (context blocks split at ``start`` plus one in-chunk tile vs
        block-aligned tiles) — the same cross-implementation situation
        as kernel-vs-reference, and the same contract applies: logits
        agree to dtype tolerance, a near-tie greedy argmax can flip, and
        all scheduling invariants (prefix sharing, preemption recompute,
        chunked prefill) still hold bit-identically WITHIN the
        speculative configuration.
        """
        if decode_steps < 1:
            raise ValueError("decode_steps must be >= 1")
        if spec_decode not in SPEC_DECODE_MODES:
            raise ValueError(
                f"spec_decode {spec_decode!r} not in {SPEC_DECODE_MODES}")
        if spec_k < 1:
            raise ValueError("spec_k must be >= 1")
        if preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(
                f"preempt_policy {preempt_policy!r} not in "
                f"{PREEMPT_POLICIES}")
        if decode_kernel is not None:
            warnings.warn(
                "ServingEngine(decode_kernel=...) is deprecated; the knob "
                "now selects the prefill kernel too — use attn_kernel=",
                DeprecationWarning, stacklevel=2)
            if attn_kernel is not None and attn_kernel != decode_kernel:
                raise ValueError(
                    f"conflicting attn_kernel={attn_kernel!r} and "
                    f"decode_kernel={decode_kernel!r}")
            attn_kernel = decode_kernel
        if attn_kernel is not None:
            if attn_kernel not in ATTN_KERNEL_MODES:
                raise ValueError(
                    f"attn_kernel (nee decode_kernel) {attn_kernel!r} not "
                    f"in {ATTN_KERNEL_MODES}")
            cfg = dc_replace(cfg, attn_kernel=attn_kernel)
        if kv_dtype is not None:
            if kv_dtype not in kv_quant.KV_DTYPES:
                raise ValueError(
                    f"kv_dtype {kv_dtype!r} not in {kv_quant.KV_DTYPES}")
            cfg = dc_replace(cfg, kv_dtype=kv_dtype)
        #: Prefix-cache chain root, namespaced by the pool encoding so an
        #: int8 pool can never revive/share blocks hashed for an fp pool.
        self._chain_root = chain_root_for(cfg.kv_dtype)
        self.preempt_policy = preempt_policy
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.sampler = sampler or SamplerConfig()
        self.stats = EngineStats()
        #: Poisoned-engine flag (see ``step()``): True once a failing
        #: step left the ``BlockStore`` inconsistent.  A poisoned engine
        #: refuses step()/submit() — its pool may hold half-applied
        #: state — and its replica must be failed over, not retried.
        self.poisoned = False
        self._queue: List[Request] = []
        self._instant: List[Tuple[int, List[int]]] = []  # zero-budget retires
        #: uid -> submit wall time, consumed when its first token lands.
        self._submit_t: Dict[int, float] = {}
        #: uid -> host time of the request's latest observed token (feeds
        #: the inter-token-latency history).
        self._last_obs_t: Dict[int, float] = {}
        #: Optional per-token hook ``on_token(uid, token)`` — called on
        #: the engine's (caller's) thread for EVERY generated token as it
        #: is observed at a host sync, before the owning request
        #: finishes.  This is the streaming surface the async frontend
        #: rides (``serving.frontend``); leave None to skip the calls.
        #: Preemption recompute replays tokens as PROMPT, so no token is
        #: ever re-announced.
        self.on_token: Optional[Callable[[int, int], None]] = None
        #: uid -> (content length, chain digests): a queue head waiting
        #: for capacity is re-matched every scheduler step — hash its
        #: prompt once, not once per step.
        self._digest_cache: Dict[int, Tuple[int, List[bytes]]] = {}
        self._uid = 0

        if mode == "auto":
            mode = "continuous" if cfg.family in CONTINUOUS_FAMILIES \
                else "wave"
        if mode == "continuous" and cfg.family not in CONTINUOUS_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} has no block-addressable KV cache; "
                f"use mode='wave'")
        if spec_decode != "off" and mode != "continuous":
            raise ValueError(
                "spec_decode requires the continuous (paged) engine: the "
                "verifier is the paged chunked-prefill path and rollback "
                "is a BlockStore operation")
        self.mode = mode
        self.spec_decode = spec_decode
        self.spec_k = spec_k
        self._proposer = make_proposer(spec_decode)
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.prefill_chunk = prefill_chunk
        self.prefix_cache = prefix_cache
        self.decode_steps = decode_steps

        self.params = params
        self._mesh = mesh
        self._axes = sharding.AxisState.from_mesh(mesh)
        if mesh is not None:
            self.params = self._place_serve(mesh, params)

        # CPU backend has no buffer donation; skip it to avoid warnings.
        donate = jax.default_backend() != "cpu"

        # Legacy wave path (also the fallback for recurrent-state families).
        self._prefill = jax.jit(
            self._scoped(lambda p, b: M.prefill(cfg, p, b, max_len)))
        self._decode = jax.jit(
            self._scoped(lambda p, c, t, pos: M.decode_step(cfg, p, c, t,
                                                            pos)))

        if self.mode == "continuous":
            self._init_continuous(donate, seed)

    def _scoped(self, fn):
        """Run ``fn`` (a to-be-jitted body) under THIS engine's axis state,
        so trace-time sharding anchors see the engine's mesh — not whatever
        ambient state the process happens to carry."""
        axes = self._axes

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with sharding.use_axes(axes):
                return fn(*args, **kwargs)
        return wrapped

    # -- public API ----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               deadline: Optional[float] = None,
               patch_embeds: Optional[np.ndarray] = None) -> int:
        """Queue a request.  ``deadline`` feeds preempt_policy="deadline";
        ``patch_embeds`` (vlm only, (num_patches, d_model)) is the
        request's image frontend — its digest seeds the prefix-cache hash
        chain, so only requests with the SAME image (or both the zero
        stub) can share prefix blocks."""
        if self.poisoned:
            raise RuntimeError(
                "engine is poisoned: an earlier step() failure left the "
                "block store inconsistent; build a fresh engine")
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) >= self.max_len:
            # Same bound in both modes (and regardless of budget): wave
            # prefill would otherwise fail deep in cache padding (or
            # silently emit nothing at exactly max_len).
            raise ValueError(
                f"prompt length {len(prompt)} leaves no decode room in a "
                f"{self.max_len}-token cache")
        if patch_embeds is not None:
            if self.cfg.family != "vlm":
                raise ValueError(
                    f"patch_embeds is vlm-only (family is "
                    f"{self.cfg.family!r})")
            patch_embeds = np.asarray(patch_embeds, np.float32)
            want = (self.cfg.num_patches, self.cfg.d_model)
            if patch_embeds.shape != want:
                raise ValueError(
                    f"patch_embeds shape {patch_embeds.shape} != {want}")
        self._uid += 1
        uid = self._uid
        if max_new_tokens < 1:
            # A zero-budget request retires immediately with an empty
            # output: it never touches the scheduler or the block pool.
            self._instant.append((uid, []))
            return uid
        if self.mode == "continuous":
            worst = self._worst_case_tokens(prompt, max_new_tokens)
            need = self._alloc.blocks_for(worst)
            cap = min(self._alloc.num_blocks, self._alloc.max_blocks_per_slot)
            if need > cap:
                raise ValueError(
                    f"request needs {need} KV blocks but the pool/block "
                    f"table caps at {cap}; it can never be admitted "
                    f"(raise num_blocks or shorten the prompt/budget)")
        self._submit_t[uid] = time.perf_counter()
        self._queue.append(Request(
            uid, prompt, max_new_tokens, deadline=deadline,
            patch_embeds=patch_embeds,
            chain_seed=self._chain_seed(patch_embeds)))
        return uid

    def _note_tokens(self, uid: int, m: int, now: float) -> None:
        """Record latency samples for ``m`` tokens of request ``uid``
        observed at host time ``now``: the request's first token ever is a
        TTFT sample; every later token an inter-token-latency sample at
        observation granularity (see ``EngineStats.itl_history``) — the
        host-sync gap spread evenly over the ``m`` tokens of the window.

        Every multi-token emission path shares this one rule: a
        ``decode_steps > 1`` window passes the tokens the window released,
        and a speculative verify pass passes the ACCEPTED count (anchor +
        accepted drafts) — never the proposed count, so rejected drafts
        cannot dilute the distribution with tokens the client never
        received.  Pinned in tests/test_latency_stats.py."""
        if m <= 0:
            return
        prev = self._last_obs_t.get(uid)
        if prev is None:
            t0 = self._submit_t.pop(uid, None)
            if t0 is not None:
                self.stats.ttft_s_sum += now - t0
                self.stats.ttft_count += 1
                self.stats.ttft_history.append(now - t0)
            # Any further tokens in this first window left the same host
            # sync as the first token: there is no measurable gap, so
            # they contribute no ITL samples (they are part of TTFT).
        else:
            self.stats.itl_history.extend([(now - prev) / m] * m)
        self._last_obs_t[uid] = now

    def _chain_seed(self, patch_embeds: Optional[np.ndarray]) -> bytes:
        """Per-request prefix-cache chain root.  Non-vlm content is fully
        determined by token ids -> the global root; vlm K/V additionally
        depends on the image, so the patch embeddings' digest is folded in
        (the None zero-stub gets its own constant seed, preserving
        stub-to-stub sharing).  All digests grow from the engine's
        kv_dtype-namespaced chain root: quantized pools store DIFFERENT
        bytes for the same token ids, so their content addresses must
        never collide with an fp pool's."""
        if self.cfg.family != "vlm":
            return self._chain_root
        if patch_embeds is None:
            return hashlib.sha256(
                self._chain_root + b"|vlm-zero-stub").digest()
        return hashlib.sha256(
            self._chain_root + patch_embeds.tobytes()).digest()

    def step(self) -> List[Tuple[int, List[int]]]:
        """One scheduler iteration: admit queued requests onto free lanes
        (prefix-cache matched), run ONE prefill chunk for admitting
        prompts, then ``decode_steps`` jitted masked decode iterations
        across all lanes.  Block-pool pressure anywhere in here preempts
        the youngest request (see module docstring).

        Returns the requests finished this iteration as (uid, tokens).

        Exception safety — the POISONED-ENGINE contract: when the step
        body raises, the engine re-checks the ``BlockStore`` invariants
        before re-raising.  If they hold, the failure was transient and
        the engine stays usable (every request keeps its lane/blocks; the
        next ``step()`` resumes where this one stopped).  If they do NOT
        hold, the engine marks itself ``poisoned`` and every later
        ``step()``/``submit()`` raises immediately — a half-applied
        scheduler iteration must never be stepped again (it could serve
        corrupt KV), and the caller (the replica router's health layer)
        must fail its requests over to a healthy replica instead.
        """
        if self.mode != "continuous":
            raise RuntimeError(
                f"step() requires mode='continuous' (engine is in "
                f"{self.mode!r} mode); use run()")
        if self.poisoned:
            raise RuntimeError(
                "engine is poisoned: an earlier step() failure left the "
                "block store inconsistent; build a fresh engine")
        try:
            return self._step()
        except Exception:
            try:
                self._alloc.check_invariants()
            except Exception:
                self.poisoned = True
            raise

    def _step(self) -> List[Tuple[int, List[int]]]:
        finished: List[Tuple[int, List[int]]] = list(self._instant)
        self._instant = []
        self._admit()
        self._prefill_step()
        if not self._host_active.any():
            return finished
        if self._proposer is not None:
            return self._spec_step(finished)

        K = self.decode_steps
        # Hand each about-to-decode lane the blocks its next (up to K)
        # tokens land in.  Growth is optimistic: OutOfBlocks preempts the
        # youngest request and retries — possibly preempting the growing
        # lane itself.
        for i in np.nonzero(self._host_active)[0]:
            i = int(i)
            if not self._host_active[i]:
                continue  # preempted while an earlier lane grew
            steps_i = min(K, int(self._host_rem[i]))
            lo = self._prefix + int(self._host_pos[i])
            self._grow_for_writes(
                i, lo, lo + steps_i,
                alive=lambda i=i: bool(self._host_active[i]))
        if not self._host_active.any():
            return finished
        self._note_peak()
        tables = jnp.asarray(self._alloc.block_table())

        t0 = time.perf_counter()
        (self._cache, self._logits, self._pos, self._active, self._budget,
         host_out) = self._decode_fn(
            self.params, self._cache, self._logits, self._pos, self._active,
            self._budget, self._keys, tables)
        host = np.asarray(host_out)  # (2, K, B): the per-window host sync
        tok_h, active_h = host[0], host[1].astype(bool)
        self.stats.decode_s += time.perf_counter() - t0

        was = self._host_active.copy()
        self.stats.peak_decode_lanes = max(self.stats.peak_decode_lanes,
                                           int(was.sum()))
        self.stats.decode_steps += K
        self.stats.slot_steps += self.max_batch * K
        self.stats.used_token_steps += self._alloc.live_tokens * K
        self.stats.pool_token_steps += self._alloc.num_blocks \
            * self._alloc.block_size * K

        bs = self._alloc.block_size
        now = time.perf_counter()
        for i in np.nonzero(was)[0]:
            i = int(i)
            r = self._slot_req[i]
            pos_before = self._prefix + int(self._host_pos[i])
            alive, emitted = True, 0
            for j in range(K):
                if not alive:
                    break
                tok = int(tok_h[j, i])
                r.output.append(tok)
                emitted += 1
                if self.on_token is not None:
                    self.on_token(r.uid, tok)
                self._host_pos[i] += 1
                self._host_rem[i] -= 1
                self.stats.generated_tokens += 1
                self.stats.occupied_slot_steps += 1
                alive = bool(active_h[j, i])
            self._note_tokens(r.uid, emitted, now)
            if self.prefix_cache and \
                    (self._prefix + int(self._host_pos[i])) // bs \
                    != pos_before // bs:
                # A block boundary was crossed: the freshly-filled full
                # block(s) become matchable for future admissions.  (The
                # store's chain cache makes this O(new blocks), and the
                # boundary check keeps the common no-new-block window from
                # paying even the content-array concat.)
                self._alloc.commit_full(i, self._content_ids(r))
            if not alive:
                r.done = True
                finished.append((r.uid, r.output))
                self._slot_req[i] = None
                self._host_active[i] = False
                self._last_obs_t.pop(r.uid, None)
                # References drop; exclusive full blocks retire into the
                # LRU pool (still matchable), partial ones go blank.
                self._alloc.release(i)
        return finished

    def _spec_step(self, finished: List[Tuple[int, List[int]]]
                   ) -> List[Tuple[int, List[int]]]:
        """One speculative decode pass across all decoding lanes.

        1. Obtain each lane's ANCHOR token — exactly what plain decode
           would sample.  In steady state it was already computed by the
           PREVIOUS verify pass (``anchor_next``, cached per lane), so no
           extra dispatch runs; only lanes whose logits were never scored
           by a verify pass (fresh prefill, preemption recompute) fall
           back to the ``_spec_anchor_fn`` dispatch.
        2. Host: the proposer drafts up to ``spec_k`` continuations from
           the request's own history (none past EOS or the budget).
        3. Grow + write-barrier each lane's blocks for the whole chunk
           (optimistic: pool pressure preempts, exactly like decode).
        4. ONE fixed-shape verify pass (``_spec_verify_fn``) scores every
           lane's [anchor | drafts] chunk through chunked prefill,
           writing drafted K/V through to the pool, and returns how many
           drafts plain decode would have emitted.
        5. Emit the accepted prefix through ``on_token`` (stopping at
           EOS/budget exactly like decode), rewind ``_host_pos`` past
           nothing — positions only ever advanced by accepted tokens —
           and ``BlockStore.truncate`` the rejected tail's K/V.

        The anchor's NEXT sample is not emitted here: the verify pass
        hands back the last accepted position's logits, so the next
        pass's anchor IS that token — engine logits state stays exactly
        plain decode's, which is what makes the bit-identity contract
        compositional across passes.
        """
        B = self.max_batch
        t0 = time.perf_counter()
        live = [int(i) for i in np.nonzero(self._host_active)[0]]
        # Anchors are popped (not read): a lane that doesn't survive to
        # the end of this pass re-derives its anchor from replayed logits
        # next time, so a stale cache entry can never outlive its request.
        cached = {i: self._spec_next.pop(i) for i in list(self._spec_next)}
        if any(i not in cached for i in live):
            anchors = np.asarray(self._spec_anchor_fn(
                self._logits, self._keys,
                jnp.asarray(self._host_pos, jnp.int32),
                jnp.asarray(self._host_active)))
        chunks: Dict[int, List[int]] = {}
        for i in live:
            r = self._slot_req[i]
            chunk = [cached[i] if i in cached else int(anchors[i])]
            rem_after = int(self._host_rem[i]) - 1
            if chunk[0] != self.eos_id and rem_after > 0:
                k = min(self.spec_k, rem_after)
                hist = [int(t) for t in r.prompt] + r.output + chunk
                chunk += [int(d) for d in
                          self._proposer.propose(hist, k)[:k]]
            chunks[i] = chunk
        for i in chunks:
            if not self._host_active[i]:
                continue  # preempted while an earlier lane grew
            lo = self._prefix + int(self._host_pos[i])
            self._grow_for_writes(
                i, lo, lo + len(chunks[i]),
                alive=lambda i=i: bool(self._host_active[i]))
        if not self._host_active.any():
            self.stats.decode_s += time.perf_counter() - t0
            return finished
        self._note_peak()

        P = self.spec_k + 1
        tokens = np.full((B, P), self.pad_id, np.int32)
        lengths = np.zeros(B, np.int32)
        starts = np.zeros(B, np.int32)
        for i, chunk in chunks.items():
            if not self._host_active[i]:
                continue  # its anchor is discarded; recompute replays it
            lengths[i] = len(chunk)
            tokens[i, P - len(chunk):] = chunk
            starts[i] = self._prefix + int(self._host_pos[i])
            self.stats.spec_proposed += len(chunk) - 1
        tables = jnp.asarray(self._alloc.block_table())
        self._cache, self._logits, v_dev, anext_dev = self._spec_verify_fn(
            self.params, self._cache, jnp.asarray(tokens),
            jnp.asarray(lengths), tables, jnp.asarray(starts),
            self._keys, self._logits)
        v = np.asarray(v_dev)
        anext = np.asarray(anext_dev)
        self.stats.decode_s += time.perf_counter() - t0

        self.stats.peak_decode_lanes = max(self.stats.peak_decode_lanes,
                                           int((lengths > 0).sum()))
        self.stats.spec_passes += 1
        self.stats.decode_steps += 1
        self.stats.slot_steps += B
        self.stats.used_token_steps += self._alloc.live_tokens
        self.stats.pool_token_steps += self._alloc.num_blocks \
            * self._alloc.block_size

        bs = self._alloc.block_size
        now = time.perf_counter()
        for i in np.nonzero(lengths > 0)[0]:
            i = int(i)
            r = self._slot_req[i]
            chunk = chunks[i]
            lo = self._prefix + int(self._host_pos[i])
            emitted, alive = 0, True
            # Accepted tokens are EXACTLY what plain decode would emit, so
            # the retirement walk is the same: stop at EOS or budget zero.
            for j in range(int(v[i]) + 1):
                tok = chunk[j]
                r.output.append(tok)
                emitted += 1
                if self.on_token is not None:
                    self.on_token(r.uid, tok)
                self._host_pos[i] += 1
                self._host_rem[i] -= 1
                self.stats.generated_tokens += 1
                if tok == self.eos_id or self._host_rem[i] <= 0:
                    alive = False
                    break
            self.stats.spec_accepted += emitted - 1
            self.stats.occupied_slot_steps += 1
            # One host sync released `emitted` tokens: the ITL window gap
            # spreads over ACCEPTED tokens (rejected drafts never reached
            # the client, so they must not dilute the distribution).
            self._note_tokens(r.uid, emitted, now)
            if emitted < len(chunk):
                # Rejected-tail rollback: the optimistic writes past the
                # accepted prefix are un-committed (refcount/chain-safe).
                self._alloc.truncate(i, lo + emitted)
            if self.prefix_cache and (lo + emitted) // bs != lo // bs:
                self._alloc.commit_full(i, self._content_ids(r))
            if not alive:
                r.done = True
                finished.append((r.uid, r.output))
                self._slot_req[i] = None
                self._host_active[i] = False
                self._last_obs_t.pop(r.uid, None)
                self._alloc.release(i)
            else:
                # The lane consumed its whole accepted prefix (emitted ==
                # v + 1), so its position is exactly where `anchor_next`
                # was sampled — carry it as next pass's anchor.
                self._spec_next[i] = int(anext[i])
        return finished

    def has_pending_work(self) -> bool:
        """True while any request is queued, prefilling, decoding or
        waiting to be retired — i.e. while ``step()`` can make progress."""
        if self.mode != "continuous":
            return bool(self._queue or self._instant)
        return bool(self._queue or self._prefilling or self._instant
                    or self._host_active.any())

    @property
    def pool_saturation(self) -> float:
        """Live (ref-counted) blocks over pool capacity, right now — the
        saturation signal the frontend's circuit breaker watches."""
        if self.mode != "continuous":
            return 0.0
        return self._alloc.live_blocks / max(self._alloc.num_blocks, 1)

    @property
    def live_blocks(self) -> int:
        """Blocks currently referenced by some in-flight lane (the load
        half of the replica router's least-loaded fallback)."""
        if self.mode != "continuous":
            return 0
        return self._alloc.live_blocks

    def match_cached_blocks(self, prompt, patch_embeds=None) -> int:
        """How many leading blocks of ``prompt`` this engine's prefix cache
        could serve RIGHT NOW, without admitting or touching any state.

        The replica router's affinity probe: it hashes the prompt with the
        SAME chain (vlm patch sentinels + per-request chain seed +
        kv_dtype-namespaced root) admission uses, so a nonzero answer here
        is exactly a nonzero ``cached_len`` if the request were admitted
        here next.  0 when the engine is not continuous or prefix caching
        is off."""
        if self.mode != "continuous" or not self.prefix_cache:
            return 0
        content = np.concatenate([
            np.full(self._prefix, -1, np.int64),
            np.asarray(prompt, np.int64)])
        digests = chain_hashes(content, self._alloc.block_size,
                               seed=self._chain_seed(patch_embeds))
        return self._alloc.match_digests(
            digests,
            max_cached_tokens=self._prefix + len(prompt) - 1,
            min_cached_tokens=self._prefix)[0]

    def cancel(self, uid: int) -> bool:
        """Abort a request wherever it currently is — queued, mid-prefill
        or decoding — releasing its KV blocks exactly like a retirement
        (non-shared blocks free, full exclusive blocks retire into the LRU
        pool).  Returns True if the request was found in flight; False if
        it already finished (or was never submitted).  Tokens generated
        before the cancel are simply dropped — the caller streamed them
        already.  Continuous mode only (the wave path has no per-request
        scheduler state to unwind)."""
        if self.mode != "continuous":
            raise RuntimeError("cancel() requires mode='continuous'")
        self._submit_t.pop(uid, None)
        self._last_obs_t.pop(uid, None)
        for i, (u, _) in enumerate(self._instant):
            if u == uid:
                self._instant.pop(i)
                self.stats.cancellations += 1
                return True
        for i, r in enumerate(self._queue):
            if r.uid == uid:
                self._queue.pop(i)
                self._digest_cache.pop(uid, None)
                self.stats.cancellations += 1
                return True
        for s in self._prefilling:
            if s.req.uid == uid:
                self._prefilling.remove(s)
                self._alloc.release(s.lane)
                # The abandoned admission's prefix-cache credit never
                # served anything (same rollback as a preemption).
                self.stats.cached_prompt_tokens -= s.counted_cached
                self.stats.cancellations += 1
                return True
        for i, r in enumerate(self._slot_req):
            if r is not None and r.uid == uid:
                self._slot_req[i] = None
                self._host_active[i] = False
                self._host_rem[i] = 0
                self._active = self._active.at[i].set(False)
                if self._proposer is not None:
                    self._spec_next.pop(i, None)
                self._alloc.release(i)
                self.stats.cancellations += 1
                return True
        return False

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue; returns uid -> generated tokens."""
        if self.mode != "continuous":
            return self._run_waves()
        results: Dict[int, List[int]] = {}
        while (self._queue or self._prefilling or self._instant
               or self._host_active.any()):
            for uid, toks in self.step():
                results[uid] = toks
        return results

    # -- continuous internals ------------------------------------------------
    def _init_continuous(self, donate: bool, seed: int) -> None:
        cfg, B = self.cfg, self.max_batch
        self._prefix = cfg.num_patches if cfg.family == "vlm" else 0
        ctx = self.max_len + self._prefix
        bs = self.block_size
        table_width = -(-ctx // bs)
        if self.num_blocks is None:
            self.num_blocks = B * table_width
        self._alloc = BlockStore(self.num_blocks, bs, B, table_width,
                                 prefix_cache=self.prefix_cache,
                                 kv_dtype=cfg.kv_dtype)
        # +1 device block: id 0 is the dead-lane trash sink.  With a mesh
        # the pool lands pre-sharded on its KV-head axis (payload + scale
        # leaves co-placed) so the shard_map'd kernels read it in place.
        self._cache = M.init_paged_cache(cfg, self.num_blocks + 1, bs,
                                         mesh=self._mesh)
        # Device bytes per pool block, all layers, K+V, summed over EVERY
        # cache leaf (axis 1 is blocks for payload and scale leaves
        # alike) — so a quantized pool's number is the true compressed
        # footprint: int8/fp8 payload bytes PLUS the fp32 scale metadata,
        # not a dense-equivalent estimate.
        self.kv_block_bytes = sum(
            int(np.prod(x.shape)) // x.shape[1] * x.dtype.itemsize
            for x in self._cache.values())
        ldtype = self.params["embed"].dtype
        self._logits = jnp.zeros((B, cfg.vocab_size), ldtype)
        self._pos = jnp.zeros((B,), jnp.int32)
        self._active = jnp.zeros((B,), bool)
        self._budget = jnp.zeros((B,), jnp.int32)
        self._base_key = jax.random.PRNGKey(seed)
        self._keys = jnp.zeros((B,) + self._base_key.shape,
                               self._base_key.dtype)
        self._slot_req: List[Optional[Request]] = [None] * B
        self._prefilling: List[_Prefilling] = []
        self._host_active = np.zeros(B, bool)
        self._host_pos = np.zeros(B, np.int64)
        self._host_rem = np.zeros(B, np.int64)  # decode budget remaining

        sampler, eos_id, pad_id = self.sampler, self.eos_id, self.pad_id
        K = self.decode_steps

        def decode_window(params, cache, last_logits, pos, active, budget,
                          keys, tables):
            def one_step(carry, _):
                cache, logits, pos, active, budget = carry
                # Inactive lanes (retired mid-window, mid-chunked-prefill,
                # preempted) run as masked no-op rows with their tables
                # pointed at the trash block, so their writes cannot
                # clobber a live or partially prefilled block.
                tbl = jnp.where(active[:, None], tables, TRASH_BLOCK)
                # Positional per-lane keys: the token at position p of
                # request uid samples with fold_in(fold_in(seed, uid), p)
                # — reproducible per request regardless of co-tenants, and
                # preemption/speculation-invariant by construction (a
                # recompute resamples position p with the same key; no
                # stream fast-forwarding needed).
                sub = positional_keys(keys, pos)
                tok = sample(sampler, logits, sub, active=active,
                             pad_id=pad_id)
                budget = budget - active.astype(jnp.int32)
                retire = active & ((tok == eos_id) | (budget <= 0))
                # All lanes run the model (a retired/free lane is a masked
                # no-op — the occupancy loss the stats report); the active
                # mask keeps dead lanes out of MoE expert capacity.
                logits2, cache = M.decode_step(cfg, params, cache,
                                               tok[:, None], pos,
                                               active=active,
                                               block_tables=tbl,
                                               mesh=self._mesh)
                pos = pos + active.astype(jnp.int32)
                new_active = active & ~retire
                return ((cache, logits2[:, 0], pos, new_active, budget),
                        (tok, new_active.astype(jnp.int32)))

            carry = (cache, last_logits, pos, active, budget)
            carry, (toks, actives) = jax.lax.scan(one_step, carry, None,
                                                  length=K)
            cache, logits, pos, active, budget = carry
            # One packed (2, K, B) buffer -> a single device->host read per
            # decode window in the scheduler loop.
            host_out = jnp.stack([toks, actives])
            return cache, logits, pos, active, budget, host_out

        self._decode_fn = jax.jit(
            self._scoped(decode_window),
            donate_argnums=(1, 2, 3, 4, 5) if donate else ())
        # One jit per (first/continuation) handles every (group size,
        # bucket) shape combination; power-of-two buckets keep the number
        # of retraces small.  vlm first chunks take the cohort's (possibly
        # per-request) patch embeddings explicitly.
        mesh = self._mesh
        if cfg.family == "vlm":
            self._prefill_first = jax.jit(
                self._scoped(
                    lambda p, c, t, ln, bt, pe: M.prefill_slots(
                        cfg, p, c, t, ln, bt, patch_embeds=pe, mesh=mesh)),
                donate_argnums=(1,) if donate else ())
        else:
            self._prefill_first = jax.jit(
                self._scoped(
                    lambda p, c, t, ln, bt: M.prefill_slots(
                        cfg, p, c, t, ln, bt, mesh=mesh)),
                donate_argnums=(1,) if donate else ())
        self._prefill_cont = jax.jit(
            self._scoped(
                lambda p, c, t, ln, bt, st: M.prefill_slots(
                    cfg, p, c, t, ln, bt, start=st, mesh=mesh)),
            donate_argnums=(1,) if donate else ())

        if self._proposer is not None:
            pfx = self._prefix
            # lane -> anchor token carried from the previous verify pass
            # (see _spec_step); invalidated whenever a request leaves its
            # lane (retire, preempt, cancel).
            self._spec_next: Dict[int, int] = {}

            def spec_anchor(logits, keys, pos, active):
                """The pass's first token — EXACTLY decode's sampling rule
                (same positional key, same active masking)."""
                return sample(sampler, logits, positional_keys(keys, pos),
                              active=active, pad_id=pad_id)

            self._spec_anchor_fn = jax.jit(self._scoped(spec_anchor))

            def spec_verify(params, cache, tokens, lengths, tables, starts,
                            keys, last_logits):
                """Score each lane's [anchor | drafts] chunk in ONE
                chunked-prefill continuation pass (all B lanes, fixed
                (B, spec_k + 1) shape -> one trace for the whole run;
                rows with length 0 read junk and write nothing) and
                compute in-jit how many drafts plain decode would have
                emitted.  The chunk's K/V lands in the pool through the
                prefill write-through — optimistically; the host rolls
                back the rejected tail with ``BlockStore.truncate``."""
                logits_all, cache = M.prefill_slots(
                    cfg, params, cache, tokens, lengths, tables,
                    start=starts, all_logits=True, mesh=mesh)
                Bn, P = tokens.shape
                pad = (P - lengths).astype(jnp.int32)
                # Column c of row b holds the token AT token-position
                # (starts[b] - pfx) + (c - pad[b]); what plain decode
                # emits AFTER it samples logits_all[b, c] with the key of
                # the NEXT position.
                col = jnp.arange(P)[None]
                nxt = (starts - pfx)[:, None] \
                    + jnp.maximum(col - pad[:, None], 0) + 1
                flat_keys = positional_keys(
                    jnp.repeat(keys, P, axis=0), nxt.reshape(-1))
                expected = sample(
                    sampler, logits_all.reshape(Bn * P, -1),
                    flat_keys).reshape(Bn, P)
                # Longest accepted draft prefix: draft at column c+1 is
                # accepted iff it equals what decode emits after column c.
                ok = tokens[:, 1:] == expected[:, :-1]
                idx = jnp.arange(P - 1)[None]
                lead = jnp.where(idx < pad[:, None], True, ok)
                run = jnp.cumprod(lead.astype(jnp.int32), axis=1).sum(1)
                v = jnp.clip(run - pad, 0, jnp.maximum(lengths - 1, 0))
                # Next-step logits: the last ACCEPTED token's column (the
                # anchor for the next pass — its sample is next pass's
                # first token, so no logits state diverges from plain
                # decode).  Idle rows keep their previous logits.
                sel = jnp.minimum(pad + v, P - 1)
                new_logits = jnp.take_along_axis(
                    logits_all, sel[:, None, None], axis=1)[:, 0]
                new_logits = jnp.where((lengths > 0)[:, None], new_logits,
                                       last_logits)
                # `expected` at the selected column IS the next pass's
                # anchor (same logits, same positional key the anchor fn
                # would use after the accepted tokens advance the
                # position) — returning it here makes the steady-state
                # pass a SINGLE dispatch: the host caches it per lane and
                # only falls back to the anchor fn for lanes fresh out of
                # prefill/preemption, whose logits it has never scored.
                anchor_next = jnp.take_along_axis(
                    expected, sel[:, None], axis=1)[:, 0]
                return cache, new_logits, v, anchor_next

            self._spec_verify_fn = jax.jit(
                self._scoped(spec_verify),
                donate_argnums=(1,) if donate else ())

    def _clamped_budget(self, prompt, max_new_tokens: int) -> int:
        """Decode budget clamped so the sequence fits the per-request
        context — the ONE definition admission, the device budget and the
        submit guard all share."""
        return min(max_new_tokens, self.max_len - len(prompt))

    def _worst_case_tokens(self, prompt, max_new_tokens: int) -> int:
        """Total cache tokens a request can ever hold."""
        return self._prefix + len(prompt) \
            + self._clamped_budget(prompt, max_new_tokens)

    def _effective_prompt(self, r: Request) -> np.ndarray:
        """Prompt to prefill: the original prompt plus any tokens generated
        before a preemption (recompute replays them)."""
        if not r.output:
            return r.prompt
        return np.concatenate(
            [r.prompt, np.asarray(r.output, np.int32)])

    def _content_ids(self, r: Request) -> np.ndarray:
        """Token ids at each cache position, for the prefix-cache hash
        chain: sentinel -1 per vlm patch position, then prompt, then
        generated tokens.  The sentinel alone does NOT identify the patch
        content — the request's ``chain_seed`` (patch-embedding digest)
        commits the whole chain to the image, which is what makes the
        sentinel sound; do not drop the seed as redundant."""
        return np.concatenate([
            np.full(self._prefix, -1, np.int64),
            np.asarray(r.prompt, np.int64),
            np.asarray(r.output, np.int64)])

    def _remaining_budget(self, r: Request) -> int:
        return self._clamped_budget(r.prompt, r.max_new_tokens) \
            - len(r.output)

    def _prompt_digests(self, r: Request) -> List[bytes]:
        """Chain digests of the request's full content, cached by length
        (the content only ever grows — on preemption requeue — which
        naturally invalidates the entry)."""
        n = self._prefix + len(r.prompt) + len(r.output)
        hit = self._digest_cache.get(r.uid)
        if hit is not None and hit[0] == n:
            return hit[1]
        digests = chain_hashes(self._content_ids(r), self._alloc.block_size,
                               seed=r.chain_seed)
        self._digest_cache[r.uid] = (n, digests)
        return digests

    # -- preemption ----------------------------------------------------------
    def _victim_key(self, r: Request, lane: int):
        """Sort key for victim selection — the MAX key is preempted.
        Re-queued preempted requests keep their uid, so under "youngest"
        they age back into protection once re-admitted."""
        if self.preempt_policy == "largest":
            return (self._alloc.owned_blocks(lane), r.uid)
        if self.preempt_policy == "deadline":
            # Latest deadline has the most slack to absorb a recompute;
            # deadline-less requests are evicted before any with one.
            d = float("inf") if r.deadline is None else float(r.deadline)
            return (d, r.uid)
        return (r.uid,)  # youngest

    def _select_victim(self):
        """The in-flight request ``preempt_policy`` evicts under pool
        pressure: ("lane", i) or ("prefill", s), or None if nothing is in
        flight."""
        best, best_key = None, None
        for i in np.nonzero(self._host_active)[0]:
            r = self._slot_req[int(i)]
            if r is None:
                continue
            key = self._victim_key(r, int(i))
            if best_key is None or key > best_key:
                best, best_key = ("lane", int(i)), key
        for s in self._prefilling:
            key = self._victim_key(s.req, s.lane)
            if best_key is None or key > best_key:
                best, best_key = ("prefill", s), key
        return best

    def _preempt(self, victim) -> None:
        """Release the victim's blocks and re-queue it at the head for
        recompute.  Only its NON-SHARED blocks actually free (shared prefix
        blocks keep their other references); its full blocks retire into
        the LRU pool, so the recompute is usually prefix-cache hits."""
        kind, v = victim
        self.stats.preemptions += 1
        if kind == "lane":
            r = self._slot_req[v]
            self._slot_req[v] = None
            self._host_active[v] = False
            self._host_rem[v] = 0
            self._active = self._active.at[v].set(False)
            if self._proposer is not None:
                # The carried anchor belongs to the evicted request; the
                # recompute replays its logits and re-derives it.
                self._spec_next.pop(v, None)
            self._alloc.release(v)
            self._queue.insert(0, r)
        else:
            self._prefilling.remove(v)
            self._alloc.release(v.lane)
            self._queue.insert(0, v.req)
            # The abandoned admission's cache credit never served anything;
            # roll it back so prefix_hit_rate reflects thrash instead of
            # being inflated by it (re-admission re-counts its real hits).
            self.stats.cached_prompt_tokens -= v.counted_cached

    def _under_pressure(self, alive: Callable[[], bool],
                        op: Callable[[], None]) -> bool:
        """Run an allocator op that may raise OutOfBlocks, preempting the
        youngest request and retrying until it succeeds.  Returns False if
        the op's own request was preempted (op abandoned)."""
        while True:
            if not alive():
                return False
            try:
                op()
                return True
            except OutOfBlocks:
                victim = self._select_victim()
                # The growing request is itself in flight, so a victim
                # always exists (possibly the grower).
                assert victim is not None, "OutOfBlocks with no live request"
                self._preempt(victim)

    def _grow_for_writes(self, lane: int, lo: int, hi: int,
                         alive: Callable[[], bool]) -> bool:
        """Grow ``lane`` to ``hi`` tokens and run the copy-on-write barrier
        over the blocks covering cache positions [lo, hi).  Returns False
        if the lane was preempted along the way."""
        if not self._under_pressure(
                alive, lambda: self._alloc.grow(lane, hi)):
            return False
        bs = self._alloc.block_size
        for idx in range(lo // bs, (hi - 1) // bs + 1):
            moved: List[Tuple[int, int]] = []

            def cow(idx=idx, moved=moved):
                mv = self._alloc.ensure_writable(lane, idx * bs)
                if mv is not None:
                    moved.append(mv)

            if not self._under_pressure(alive, cow):
                return False
            for src, dst in moved:
                self._copy_block(src, dst)
        return True

    def _note_peak(self) -> None:
        self.stats.kv_block_bytes = self.kv_block_bytes
        self.stats.peak_live_blocks = max(self.stats.peak_live_blocks,
                                          self._alloc.live_blocks)

    def _copy_block(self, src: int, dst: int) -> None:
        """Device-side copy-on-write payload copy (all layers of one
        block).  Rare: only a write into a still-shared block triggers
        it."""
        self._cache = M.copy_cache_block(self._cache, src, dst)

    # -- admission / prefill -------------------------------------------------
    def _admit(self) -> None:
        """Move queued requests onto free lanes.  Admission is OPTIMISTIC:
        a request enters when the store can cover its uncached prompt tail
        plus one decode block RIGHT NOW — the decode budget is not
        reserved; preemption recovers from over-commitment.  Prefix-cache
        hits shrink the tail, so shared-prompt traffic admits far deeper
        than the pool's raw capacity."""
        owned = {s.lane for s in self._prefilling}
        free = [i for i, r in enumerate(self._slot_req)
                if r is None and i not in owned]
        while self._queue and free:
            r = self._queue[0]
            eff_len = len(r.prompt) + len(r.output)
            digests = self._prompt_digests(r) if self.prefix_cache else []
            cached_blocks, pooled = self._alloc.match_digests(
                digests,
                max_cached_tokens=self._prefix + eff_len - 1,
                min_cached_tokens=self._prefix)
            need_now = self._alloc.blocks_for(
                self._prefix + eff_len + 1) - cached_blocks
            # Matched-but-pooled blocks will be revived out of `available`
            # by admit, so they cannot double as allocatable headroom.
            if need_now > self._alloc.available - pooled:
                break  # FIFO: wait for blocks rather than starve the head
            lane = free.pop(0)
            eff = self._effective_prompt(r)
            cached_len = self._alloc.admit(
                lane, digests=digests if self.prefix_cache else None,
                max_cached_tokens=self._prefix + eff_len - 1,
                min_cached_tokens=self._prefix, seed=r.chain_seed)
            self._digest_cache.pop(r.uid, None)
            consumed = max(0, cached_len - self._prefix)
            self.stats.cached_prompt_tokens += consumed
            self._prefilling.append(_Prefilling(
                r, lane, self._remaining_budget(r), eff,
                consumed=consumed, cached_len=cached_len,
                counted_cached=consumed))
            self._queue.pop(0)
            self.stats.admissions += 1

    def _prefill_step(self) -> None:
        """Run ONE prefill chunk for the current admission cohort."""
        if not self._prefilling:
            return

        # From-scratch first chunks embed the vlm patch prefix (a different
        # traced shape); cached or continuation chunks gather their context
        # through the block table.  Group the two separately.
        def _first(s: _Prefilling) -> bool:
            return s.consumed == 0 and s.cached_len == 0

        first = _first(self._prefilling[0])
        cohort = [s for s in self._prefilling if _first(s) == first]
        cap = self.prefill_chunk or self.max_len

        # Grow every member's blocks (write-barriered) BEFORE assembling
        # the batch: growth can preempt cohort members (including the one
        # being grown), which drops them from this chunk.
        ready: List[Tuple[_Prefilling, int]] = []
        for s in cohort:
            if s not in self._prefilling:
                continue  # preempted as a victim of an earlier member
            take = min(cap, len(s.tokens) - s.consumed)
            lo = self._prefix + s.consumed
            if self._grow_for_writes(
                    s.lane, lo, lo + take,
                    alive=lambda s=s: s in self._prefilling):
                ready.append((s, take))
        # A LATER member's growth may have preempted an earlier one that
        # had already grown — drop it, or its chunk would be written into
        # released blocks and the preempted request wrongly activated.
        ready = [(s, t) for (s, t) in ready if s in self._prefilling]
        self._note_peak()
        if not ready:
            return
        cohort, takes = [s for s, _ in ready], [t for _, t in ready]
        P = _bucket(max(takes), cap)
        n = len(cohort)
        tokens = np.full((n, P), self.pad_id, np.int32)
        lengths = np.empty(n, np.int32)
        starts = np.empty(n, np.int32)
        for j, (s, take) in enumerate(zip(cohort, takes)):
            tokens[j, P - take:] = s.tokens[s.consumed:s.consumed + take]
            lengths[j] = take
            starts[j] = self._prefix + s.consumed
        tables = jnp.asarray(
            self._alloc.block_table()[[s.lane for s in cohort]])

        t0 = time.perf_counter()
        if first:
            if self.cfg.family == "vlm":
                # Per-request images; the zero stub for requests without.
                pe = np.zeros((n, self.cfg.num_patches, self.cfg.d_model),
                              np.float32)
                for j, (s, _) in enumerate(zip(cohort, takes)):
                    if s.req.patch_embeds is not None:
                        pe[j] = s.req.patch_embeds
                logits_new, self._cache = self._prefill_first(
                    self.params, self._cache, jnp.asarray(tokens),
                    jnp.asarray(lengths), tables,
                    jnp.asarray(pe).astype(jnp.bfloat16))
            else:
                logits_new, self._cache = self._prefill_first(
                    self.params, self._cache, jnp.asarray(tokens),
                    jnp.asarray(lengths), tables)
        else:
            logits_new, self._cache = self._prefill_cont(
                self.params, self._cache, jnp.asarray(tokens),
                jnp.asarray(lengths), tables, jnp.asarray(starts))

        done_rows, done = [], []
        for j, (s, take) in enumerate(zip(cohort, takes)):
            s.consumed += take
            if self.prefix_cache:
                self._alloc.commit_full(s.lane, self._content_ids(s.req))
            if s.consumed == len(s.tokens):
                done_rows.append(j)
                done.append(s)
                self._slot_req[s.lane] = s.req
                self._prefilling.remove(s)
        if done:
            rows = jnp.asarray(done_rows)
            lanes = jnp.asarray([s.lane for s in done])
            plens = jnp.asarray([len(s.tokens) for s in done], jnp.int32)
            budgets = jnp.asarray([s.budget for s in done], jnp.int32)
            self._logits = self._logits.at[lanes].set(logits_new[rows])
            self._pos = self._pos.at[lanes].set(plens)
            self._active = self._active.at[lanes].set(True)
            self._budget = self._budget.at[lanes].set(budgets)
            self._keys = self._keys.at[lanes].set(jnp.stack(
                [self._request_key(s.req) for s in done]))
            for s in done:
                self._host_active[s.lane] = True
                self._host_pos[s.lane] = len(s.tokens)
                self._host_rem[s.lane] = s.budget
        jax.block_until_ready(self._logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += int(sum(takes))
        self.stats.prefill_chunks += 1

    def _request_key(self, r: Request):
        """The request's base PRNG key: fold_in(seed, uid).  The decode
        step folds the sampling POSITION in on top, so a preemption
        recompute resumes the same stochastic stream with no
        fast-forwarding (O(1) re-admission)."""
        return jax.random.fold_in(self._base_key, r.uid)

    # -- mesh placement ------------------------------------------------------
    def _place_serve(self, mesh, params):
        with sharding.use_axes(self._axes):
            specs = sharding.param_specs(self.cfg, params, mode="serve")
            specs = sharding.sanitize_specs(specs, params)
            return jax.device_put(params,
                                  sharding.to_shardings(mesh, specs))

    # -- legacy wave path ----------------------------------------------------
    def _run_waves(self) -> Dict[int, List[int]]:
        """Lockstep wave batching, bucketed by exact prompt length (padding
        would let real tokens attend to pads without the masked-prefill
        machinery of the continuous path)."""
        results: Dict[int, List[int]] = {uid: toks
                                         for uid, toks in self._instant}
        self._instant = []
        by_len: Dict[int, List[Request]] = {}
        for r in self._queue:
            by_len.setdefault(len(r.prompt), []).append(r)
        self._queue = []
        for _, reqs in sorted(by_len.items()):
            for i in range(0, len(reqs), self.max_batch):
                wave = reqs[i: i + self.max_batch]
                self._run_wave(wave)
                for r in wave:
                    results[r.uid] = r.output
        return results

    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        S = len(wave[0].prompt)  # waves are same-length by construction
        toks = np.stack([r.prompt for r in wave]).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            pe = np.zeros((B, self.cfg.num_patches, self.cfg.d_model),
                          np.float32)
            for i, r in enumerate(wave):
                if r.patch_embeds is not None:
                    pe[i] = r.patch_embeds
            batch["patch_embeds"] = jnp.asarray(pe).astype(jnp.bfloat16)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encdec.encoder_seq_len, self.cfg.d_model),
                jnp.bfloat16)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += B * S
        self.stats.admissions += B

        max_new = min(max(r.max_new_tokens for r in wave),
                      self.max_len - S)
        key = jax.random.PRNGKey(self._uid)
        done = np.zeros(B, bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            self.stats.decode_steps += 1
            self.stats.occupied_slot_steps += int((~done).sum())
            self.stats.slot_steps += self.max_batch
            key, sub = jax.random.split(key)
            next_tok = sample(self.sampler, logits.reshape(B, -1), sub)
            nt = np.asarray(next_tok)
            now = time.perf_counter()
            for i, r in enumerate(wave):
                if not done[i] and len(r.output) < r.max_new_tokens:
                    r.output.append(int(nt[i]))
                    if self.on_token is not None:
                        self.on_token(r.uid, int(nt[i]))
                    self._note_tokens(r.uid, 1, now)
                    self.stats.generated_tokens += 1
                    if nt[i] == self.eos_id:
                        done[i] = True
                if len(r.output) >= r.max_new_tokens:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode(
                self.params, cache, next_tok[:, None], jnp.int32(S + step))
            logits = logits[:, 0]
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t0
        for r in wave:
            self._last_obs_t.pop(r.uid, None)
