"""Serving engine: prefill + autoregressive decode with wave batching.

The engine prices exactly what the paper's TCO/token metric prices: the
generate stage.  Requests are grouped into fixed-size waves (the analytic
engine's chosen batch size); each wave shares a KV cache allocation and
decodes in lockstep, with per-row early-exit masking on EOS.

On a real mesh the engine jits ``prefill`` / ``decode_step`` with the serve
shardings from ``parallel.sharding``; on CPU smoke runs it executes the same
code on one device.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.sampler import SamplerConfig, sample


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    generated_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.decode_s, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0,
                 sampler: Optional[SamplerConfig] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.sampler = sampler or SamplerConfig()
        self.stats = EngineStats()
        self._prefill = jax.jit(
            lambda p, b: M.prefill(cfg, p, b, max_len),
        )
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
        self._queue: List[Request] = []
        self._uid = 0

    # -- public API ----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        self._uid += 1
        self._queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                   max_new_tokens))
        return self._uid

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue in waves; returns uid -> generated tokens.

        Requests are bucketed by prompt length so waves need no padding
        (padding would let real tokens attend to pads).
        """
        results: Dict[int, List[int]] = {}
        by_len: Dict[int, List[Request]] = {}
        for r in self._queue:
            by_len.setdefault(len(r.prompt), []).append(r)
        self._queue = []
        for _, reqs in sorted(by_len.items()):
            for i in range(0, len(reqs), self.max_batch):
                wave = reqs[i: i + self.max_batch]
                self._run_wave(wave)
                for r in wave:
                    results[r.uid] = r.output
        return results

    # -- internals -----------------------------------------------------------
    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        S = len(wave[0].prompt)  # waves are same-length by construction
        toks = np.stack([r.prompt for r in wave]).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, self.cfg.num_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encdec.encoder_seq_len, self.cfg.d_model),
                jnp.bfloat16)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += B * S

        max_new = min(max(r.max_new_tokens for r in wave),
                      self.max_len - S)
        key = jax.random.PRNGKey(self._uid)
        done = np.zeros(B, bool)
        t0 = time.perf_counter()
        next_tok = None
        for step in range(max_new):
            key, sub = jax.random.split(key)
            next_tok = sample(self.sampler, logits.reshape(B, -1), sub)
            nt = np.asarray(next_tok)
            for i, r in enumerate(wave):
                if not done[i] and len(r.output) < r.max_new_tokens:
                    r.output.append(int(nt[i]))
                    self.stats.generated_tokens += 1
                    if nt[i] == self.eos_id:
                        done[i] = True
                if len(r.output) >= r.max_new_tokens:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode(
                self.params, cache, next_tok[:, None], jnp.int32(S + step))
            logits = logits[:, 0]
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t0
