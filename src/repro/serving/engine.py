"""Serving engine: continuous batching over a PAGED (block) KV cache.

The engine prices exactly what the paper's TCO/token metric prices: the
generate stage under heavy multi-tenant load.  The seed's wave batcher
(lockstep waves, bucketed by exact prompt length, host sync per token)
modeled exactly the utilization losses the paper's batching/pipelining
analysis (§4.2, Fig 6/8) says to avoid.  PR 1 replaced it with Orca-style
iteration-level scheduling over per-slot ``max_len`` KV stripes; this
version replaces the stripes with vLLM-style paged allocation plus chunked
prefill:

  * the KV cache is ONE pool of fixed-size token blocks
    (``model.init_paged_cache``, (L, num_blocks, block_size, Hk, hd))
    shared by every request; a host-side free-list allocator
    (``serving.paged.BlockAllocator``) hands blocks to decode lanes as
    their sequences grow and reclaims them at retirement, so a long prompt
    no longer strands a full ``max_len`` stripe that short requests could
    use — admission is **block-granular**;
  * each lane addresses the pool through a per-row block table threaded
    into the jitted decode step: ``layers.attention_decode`` scatters the
    new K/V through the table and gathers the context back block-by-block;
  * admission: queued requests reserve their worst-case block count
    (prompt + decode budget — no mid-flight preemption needed), then the
    prompt is prefilled through ``model.prefill_slots`` in left-padded
    buckets.  Prompts longer than ``prefill_chunk`` are processed in
    **chunks interleaved with decode iterations**, so admitting a long
    prompt no longer stalls in-flight decodes for its whole prefill;
  * decode: one fully jitted masked step carries
    ``(cache, last_logits, pos[B], active[B], budget[B], keys[B])`` with
    donated buffers; sampling runs inside the jit with a PER-REQUEST key
    (``fold_in(seed, uid)``, so stochastic outputs are reproducible no
    matter which co-tenants share the batch) and EOS/budget retirement is
    computed on-device — the hot loop is one dispatch plus one token-sized
    device->host read per generated token;
  * scheduling: lanes freed by EOS or ``max_new_tokens`` return their
    blocks to the pool and are refilled from the queue between decode
    iterations.  Freed blocks are NOT zeroed — a retired lane's block
    table is pointed at the trash block, so its masked no-op writes cannot
    touch a re-assigned block.

Knobs (see also examples/quickstart.py):
  * ``block_size`` — tokens per KV block.  Small blocks (8-16) minimize
    fragmentation (waste is < one block per request); ``block_size >=
    max_len`` degenerates to PR 1's slot-per-request reservation and is
    the baseline in ``benchmarks/serving_bench.py``.
  * ``num_blocks`` — pool size; defaults to ``max_batch`` full-length
    stripes' worth.  Admission is limited by blocks (memory), lanes
    (``max_batch``) and per-request context (``max_len``) independently.
  * ``prefill_chunk`` — max prompt tokens prefilled per scheduler
    iteration (None = whole prompt in one call).

Families with attention KV caches (dense, moe, vlm) run this continuous
path.  SSM/hybrid/audio recurrent state cannot be left-pad-masked without
polluting the scan state, so those families fall back to the seed's wave
batching; ``mode="wave"`` forces that path for any family.

On a multi-device mesh, pass ``mesh=``: parameters and the cache are placed
with the serve shardings from ``parallel.sharding`` (mode="serve": resident
TP weights; the paged pool shards KV heads over ``model`` — block tables
are request-local, so the pool itself is not batch-shardable) and the
jitted functions inherit that placement.  Caveat: this sets the sharding
module's process-global axis sizes (they must be visible when the jits
trace), so one serving mesh per process — restore via
``set_mesh_axis_sizes`` if the process later runs un-meshed work.  On CPU
smoke runs the same code executes on one device.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.parallel import sharding
from repro.serving.paged import TRASH_BLOCK, BlockAllocator
from repro.serving.sampler import SamplerConfig, sample

# Families whose KV cache supports block-level admission (see module doc).
CONTINUOUS_FAMILIES = ("dense", "moe", "vlm")


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class _Prefilling:
    """A request mid-admission: its prompt is entering the cache in chunks."""
    req: Request
    lane: int
    budget: int  # decode budget clamped to the cache (fixed at admission)
    consumed: int = 0  # prompt tokens already prefilled


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    prefill_chunks: int = 0
    generated_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    admissions: int = 0
    # Occupancy: active lanes summed over decode steps vs. lane capacity.
    occupied_slot_steps: int = 0
    slot_steps: int = 0
    # KV memory: live TOKENS summed over decode steps vs. pool tokens.
    used_token_steps: int = 0
    pool_token_steps: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.decode_s, 1e-9)

    @property
    def slot_occupancy(self) -> float:
        return self.occupied_slot_steps / max(self.slot_steps, 1)

    @property
    def mean_active_requests(self) -> float:
        """Concurrent in-decode requests averaged over decode steps."""
        return self.occupied_slot_steps / max(self.decode_steps, 1)

    @property
    def block_utilization(self) -> float:
        """Fraction of the KV pool's TOKEN capacity holding live tokens,
        averaged over decode steps — the capacity-fragmentation metric
        paged allocation improves (a stripe engine counts a whole stripe
        against the pool per request; paging wastes at most one partial
        block per request)."""
        return self.used_token_steps / max(self.pool_token_steps, 1)


def _bucket(n: int, cap: int) -> int:
    """Smallest power-of-two >= n (min 8), capped at cap."""
    p = 8
    while p < n:
        p *= 2
    return min(p, cap)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0,
                 sampler: Optional[SamplerConfig] = None,
                 mode: str = "auto", pad_id: int = 0, seed: int = 0,
                 mesh=None, block_size: int = 8,
                 num_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = 32):
        """mode: "auto" (continuous where the family supports it),
        "continuous" (error if unsupported) or "wave" (force the legacy
        lockstep baseline).

        block_size / num_blocks / prefill_chunk: paged-KV knobs, see the
        module docstring.  Defaults give ``max_batch`` stripes' worth of
        blocks and chunk prompts longer than 32 tokens.
        """
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.sampler = sampler or SamplerConfig()
        self.stats = EngineStats()
        self._queue: List[Request] = []
        self._uid = 0

        if mode == "auto":
            mode = "continuous" if cfg.family in CONTINUOUS_FAMILIES \
                else "wave"
        if mode == "continuous" and cfg.family not in CONTINUOUS_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} has no block-addressable KV cache; "
                f"use mode='wave'")
        self.mode = mode
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.prefill_chunk = prefill_chunk

        self.params = params
        self._mesh = mesh
        if mesh is not None:
            self.params = self._place_serve(mesh, params)

        # CPU backend has no buffer donation; skip it to avoid warnings.
        donate = jax.default_backend() != "cpu"

        # Legacy wave path (also the fallback for recurrent-state families).
        self._prefill = jax.jit(
            lambda p, b: M.prefill(cfg, p, b, max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))

        if self.mode == "continuous":
            self._init_continuous(donate, seed)

    # -- public API ----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        prompt = np.asarray(prompt, np.int32)
        if max_new_tokens < 1:
            # The wave path would silently emit nothing while the slot
            # scheduler always decodes once: reject uniformly instead.
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) >= self.max_len:
            # Same bound in both modes: wave prefill would otherwise fail
            # deep in cache padding (or silently emit nothing at exactly
            # max_len).
            raise ValueError(
                f"prompt length {len(prompt)} leaves no decode room in a "
                f"{self.max_len}-token cache")
        if self.mode == "continuous":
            worst = self._worst_case_tokens(prompt, max_new_tokens)
            if self._alloc.blocks_for(worst) > min(
                    self._alloc.num_blocks, self._alloc.max_blocks_per_slot):
                raise ValueError(
                    f"request needs {self._alloc.blocks_for(worst)} KV "
                    f"blocks; the pool can never satisfy it")
        self._uid += 1
        self._queue.append(Request(self._uid, prompt, max_new_tokens))
        return self._uid

    def step(self) -> List[Tuple[int, List[int]]]:
        """One scheduler iteration: admit queued requests onto free lanes,
        run ONE prefill chunk for admitting prompts, then one jitted masked
        decode step across all lanes — chunked prefill and decode interleave
        at this granularity, so a long prompt's admission cannot stall
        in-flight decodes for its whole prefill.

        Returns the requests finished this iteration as (uid, tokens).
        """
        if self.mode != "continuous":
            raise RuntimeError(
                f"step() requires mode='continuous' (engine is in "
                f"{self.mode!r} mode); use run()")
        self._admit()
        self._prefill_step()
        if not self._host_active.any():
            return []

        # Hand each about-to-decode lane the block its next token lands in
        # (always within the admission reservation, so this cannot fail).
        for i in np.nonzero(self._host_active)[0]:
            self._alloc.grow(int(i), self._prefix + int(self._host_pos[i]) + 1)
        tables = jnp.asarray(self._alloc.block_table())

        t0 = time.perf_counter()
        (self._cache, self._logits, self._pos, self._active, self._budget,
         host_out, self._keys) = self._decode_fn(
            self.params, self._cache, self._logits, self._pos, self._active,
            self._budget, self._keys, tables)
        host = np.asarray(host_out)  # the per-token host sync point
        tok_h, active_h = host[0], host[1].astype(bool)
        self.stats.decode_s += time.perf_counter() - t0

        was = self._host_active
        self.stats.decode_steps += 1
        self.stats.occupied_slot_steps += int(was.sum())
        self.stats.slot_steps += self.max_batch
        self.stats.used_token_steps += self._alloc.live_tokens
        self.stats.pool_token_steps += self._alloc.num_blocks \
            * self._alloc.block_size

        finished: List[Tuple[int, List[int]]] = []
        for i in np.nonzero(was)[0]:
            r = self._slot_req[i]
            r.output.append(int(tok_h[i]))
            self._host_pos[i] += 1
            self.stats.generated_tokens += 1
            if not active_h[i]:
                r.done = True
                finished.append((r.uid, r.output))
                self._slot_req[i] = None
                # Blocks return to the pool; the lane's table rows become
                # trash so its dead-lane writes cannot touch them again.
                self._alloc.release(int(i))
        self._host_active = active_h
        return finished

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue; returns uid -> generated tokens."""
        if self.mode != "continuous":
            return self._run_waves()
        results: Dict[int, List[int]] = {}
        while self._queue or self._prefilling or self._host_active.any():
            for uid, toks in self.step():
                results[uid] = toks
        return results

    # -- continuous internals ------------------------------------------------
    def _init_continuous(self, donate: bool, seed: int) -> None:
        cfg, B = self.cfg, self.max_batch
        self._prefix = cfg.num_patches if cfg.family == "vlm" else 0
        ctx = self.max_len + self._prefix
        bs = self.block_size
        table_width = -(-ctx // bs)
        if self.num_blocks is None:
            self.num_blocks = B * table_width
        self._alloc = BlockAllocator(self.num_blocks, bs, B, table_width)
        # +1 device block: id 0 is the dead-lane trash sink.
        self._cache = M.init_paged_cache(cfg, self.num_blocks + 1, bs)
        if self._mesh is not None:
            self._cache = self._place_cache(self._mesh, self._cache)
        ldtype = self.params["embed"].dtype
        self._logits = jnp.zeros((B, cfg.vocab_size), ldtype)
        self._pos = jnp.zeros((B,), jnp.int32)
        self._active = jnp.zeros((B,), bool)
        self._budget = jnp.zeros((B,), jnp.int32)
        self._base_key = jax.random.PRNGKey(seed)
        self._keys = jnp.zeros((B,) + self._base_key.shape,
                               self._base_key.dtype)
        self._slot_req: List[Optional[Request]] = [None] * B
        self._prefilling: List[_Prefilling] = []
        self._host_active = np.zeros(B, bool)
        self._host_pos = np.zeros(B, np.int64)

        sampler, eos_id, pad_id = self.sampler, self.eos_id, self.pad_id

        def decode_step(params, cache, last_logits, pos, active, budget,
                        keys, tables):
            # Inactive lanes still run as masked no-op rows, but a lane
            # mid-chunked-prefill already OWNS blocks — point dead lanes'
            # tables at the trash block so their no-op writes cannot clobber
            # a partially prefilled prompt (or a re-assigned block).
            tables = jnp.where(active[:, None], tables, TRASH_BLOCK)
            # Per-lane keys: each request's stream was seeded by fold_in at
            # admission, so sampling is reproducible per request regardless
            # of which co-tenants share the batch.
            splits = jax.vmap(jax.random.split)(keys)  # (B, 2, key)
            keys, sub = splits[:, 0], splits[:, 1]
            tok = sample(sampler, last_logits, sub, active=active,
                         pad_id=pad_id)
            budget = budget - active.astype(jnp.int32)
            retire = active & ((tok == eos_id) | (budget <= 0))
            # All lanes run the model (a retired/free lane is a masked
            # no-op — the occupancy loss the stats report); the active
            # mask keeps dead lanes out of MoE expert capacity.
            logits, cache = M.decode_step(cfg, params, cache, tok[:, None],
                                          pos, active=active,
                                          block_tables=tables)
            pos = pos + active.astype(jnp.int32)
            new_active = active & ~retire
            # One packed (2, B) buffer -> a single device->host read per
            # token in the scheduler loop.
            host_out = jnp.stack([tok, new_active.astype(jnp.int32)])
            return (cache, logits[:, 0], pos, new_active, budget, host_out,
                    keys)

        self._decode_fn = jax.jit(
            decode_step,
            donate_argnums=(1, 2, 3, 4, 5, 6) if donate else ())
        # One jit per (first/continuation) handles every (group size,
        # bucket) shape combination; power-of-two buckets keep the number
        # of retraces small.
        self._prefill_first = jax.jit(
            lambda p, c, t, ln, bt: M.prefill_slots(cfg, p, c, t, ln, bt),
            donate_argnums=(1,) if donate else ())
        self._prefill_cont = jax.jit(
            lambda p, c, t, ln, bt, st: M.prefill_slots(cfg, p, c, t, ln, bt,
                                                        start=st),
            donate_argnums=(1,) if donate else ())

    def _clamped_budget(self, prompt, max_new_tokens: int) -> int:
        """Decode budget clamped so the sequence fits the per-request
        context — the ONE definition the reservation, the device budget
        and the submit guard all share."""
        return min(max_new_tokens, self.max_len - len(prompt))

    def _worst_case_tokens(self, prompt, max_new_tokens: int) -> int:
        """Total cache tokens a request can ever hold (reservation size)."""
        return self._prefix + len(prompt) \
            + self._clamped_budget(prompt, max_new_tokens)

    def _admit(self) -> None:
        """Move queued requests onto free lanes, block-granularly: each
        reserves only its own worst case (prompt + budget), so many short
        requests can hold lanes alongside one long one."""
        owned = {s.lane for s in self._prefilling}
        free = [i for i, r in enumerate(self._slot_req)
                if r is None and i not in owned]
        while self._queue and free:
            r = self._queue[0]
            if not self._alloc.can_admit(
                    self._worst_case_tokens(r.prompt, r.max_new_tokens)):
                break  # FIFO: wait for blocks rather than starve the head
            lane = free.pop(0)
            self._alloc.admit(
                lane, self._worst_case_tokens(r.prompt, r.max_new_tokens))
            self._prefilling.append(_Prefilling(
                r, lane, self._clamped_budget(r.prompt, r.max_new_tokens)))
            self._queue.pop(0)
            self.stats.admissions += 1

    def _prefill_step(self) -> None:
        """Run ONE prefill chunk for the current admission cohort."""
        if not self._prefilling:
            return
        # First chunks embed the vlm patch prefix (a different traced
        # shape), so group first-timers and continuations separately.
        first = self._prefilling[0].consumed == 0
        cohort = [s for s in self._prefilling
                  if (s.consumed == 0) == first]
        cap = self.prefill_chunk or self.max_len
        takes = [min(cap, len(s.req.prompt) - s.consumed) for s in cohort]
        P = _bucket(max(takes), cap)
        n = len(cohort)
        tokens = np.full((n, P), self.pad_id, np.int32)
        lengths = np.empty(n, np.int32)
        starts = np.empty(n, np.int32)
        for j, (s, take) in enumerate(zip(cohort, takes)):
            tokens[j, P - take:] = s.req.prompt[s.consumed:s.consumed + take]
            lengths[j] = take
            starts[j] = self._prefix + s.consumed
            self._alloc.grow(s.lane, self._prefix + s.consumed + take)
        tables = jnp.asarray(
            self._alloc.block_table()[[s.lane for s in cohort]])

        t0 = time.perf_counter()
        if first:
            logits_new, self._cache = self._prefill_first(
                self.params, self._cache, jnp.asarray(tokens),
                jnp.asarray(lengths), tables)
        else:
            logits_new, self._cache = self._prefill_cont(
                self.params, self._cache, jnp.asarray(tokens),
                jnp.asarray(lengths), tables, jnp.asarray(starts))

        done_rows, done = [], []
        for j, (s, take) in enumerate(zip(cohort, takes)):
            s.consumed += take
            if s.consumed == len(s.req.prompt):
                done_rows.append(j)
                done.append(s)
                self._slot_req[s.lane] = s.req
                self._prefilling.remove(s)
        if done:
            rows = jnp.asarray(done_rows)
            lanes = jnp.asarray([s.lane for s in done])
            plens = jnp.asarray([len(s.req.prompt) for s in done], jnp.int32)
            budgets = jnp.asarray([s.budget for s in done], jnp.int32)
            self._logits = self._logits.at[lanes].set(logits_new[rows])
            self._pos = self._pos.at[lanes].set(plens)
            self._active = self._active.at[lanes].set(True)
            self._budget = self._budget.at[lanes].set(budgets)
            self._keys = self._keys.at[lanes].set(jnp.stack(
                [jax.random.fold_in(self._base_key, s.req.uid)
                 for s in done]))
            for s in done:
                self._host_active[s.lane] = True
                self._host_pos[s.lane] = len(s.req.prompt)
        jax.block_until_ready(self._logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += int(sum(takes))
        self.stats.prefill_chunks += 1

    # -- mesh placement ------------------------------------------------------
    def _place_serve(self, mesh, params):
        sharding.set_mesh_axis_sizes(mesh)
        specs = sharding.param_specs(self.cfg, params, mode="serve")
        specs = sharding.sanitize_specs(specs, params)
        return jax.device_put(params, sharding.to_shardings(mesh, specs))

    def _place_cache(self, mesh, cache):
        specs = sharding.cache_specs(
            self.cfg, cache, sharding._DP_AXES or None, self.max_batch,
            paged=True)
        specs = sharding.sanitize_specs(specs, cache)
        return jax.device_put(cache, sharding.to_shardings(mesh, specs))

    # -- legacy wave path ----------------------------------------------------
    def _run_waves(self) -> Dict[int, List[int]]:
        """Lockstep wave batching, bucketed by exact prompt length (padding
        would let real tokens attend to pads without the masked-prefill
        machinery of the continuous path)."""
        results: Dict[int, List[int]] = {}
        by_len: Dict[int, List[Request]] = {}
        for r in self._queue:
            by_len.setdefault(len(r.prompt), []).append(r)
        self._queue = []
        for _, reqs in sorted(by_len.items()):
            for i in range(0, len(reqs), self.max_batch):
                wave = reqs[i: i + self.max_batch]
                self._run_wave(wave)
                for r in wave:
                    results[r.uid] = r.output
        return results

    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        S = len(wave[0].prompt)  # waves are same-length by construction
        toks = np.stack([r.prompt for r in wave]).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, self.cfg.num_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.encdec.encoder_seq_len, self.cfg.d_model),
                jnp.bfloat16)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += B * S
        self.stats.admissions += B

        max_new = min(max(r.max_new_tokens for r in wave),
                      self.max_len - S)
        key = jax.random.PRNGKey(self._uid)
        done = np.zeros(B, bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            self.stats.decode_steps += 1
            self.stats.occupied_slot_steps += int((~done).sum())
            self.stats.slot_steps += self.max_batch
            key, sub = jax.random.split(key)
            next_tok = sample(self.sampler, logits.reshape(B, -1), sub)
            nt = np.asarray(next_tok)
            for i, r in enumerate(wave):
                if not done[i] and len(r.output) < r.max_new_tokens:
                    r.output.append(int(nt[i]))
                    self.stats.generated_tokens += 1
                    if nt[i] == self.eos_id:
                        done[i] = True
                if len(r.output) >= r.max_new_tokens:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode(
                self.params, cache, next_tok[:, None], jnp.int32(S + step))
            logits = logits[:, 0]
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t0
