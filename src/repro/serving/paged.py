"""Paged KV-cache block allocator (host side).

The PR 1 engine reserved one contiguous ``max_len`` stripe of KV cache per
slot, so a single long prompt stranded capacity that many short requests
could have used — exactly the fragmentation waste the paper's generate-stage
utilization argument (CC-MEM, §4.2, Fig 6/8) prices into TCO/token and that
vLLM's PagedAttention removes.  This module is the host half of the paged
replacement: a free list of fixed-size token *blocks* shared across all
decode lanes, with a per-lane block table mapping sequence positions to
blocks.  The device half (gather over the block table) lives in
``models.layers.attention_decode`` / ``models.model.prefill_slots``.

Two bookkeeping levels, deliberately separate:

  * **allocation** is lazy: a lane holds exactly
    ``ceil(seq_len / block_size)`` live blocks — blocks are handed out by
    ``grow`` as the sequence crosses block boundaries and returned by
    ``release`` when the request retires.  The property suite in
    ``tests/test_paged_kv.py`` pins this invariant (no double assignment,
    freed blocks return to the free list, live == sum of rounded lengths);
  * **reservation** is eager: ``admit`` reserves the request's worst-case
    block count (prompt + decode budget) up front, so a mid-decode ``grow``
    can never fail and the engine never has to preempt/swap a running
    request.  Reservation is a counter, not block ids — short requests
    reserve only what they can ever touch, which is what lets long and
    short requests share one pool.

Block id 0 (``TRASH_BLOCK``) is never handed out: the device scatter for
retired/padded lanes is redirected there, so a freed block can be re-assigned
to another lane without any risk of a stale lane clobbering it.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

#: Block id reserved as the write sink for dead lanes; never allocated.
TRASH_BLOCK = 0


class BlockAllocator:
    """Free-list allocator of fixed-size KV token blocks over ``num_slots``
    decode lanes.

    num_blocks:  usable pool size (ids ``1..num_blocks``; id 0 is trash).
    block_size:  tokens per block.
    num_slots:   decode lanes (rows of the block table).
    max_blocks_per_slot: width of the per-lane block table (the per-request
        context cap in blocks).
    """

    def __init__(self, num_blocks: int, block_size: int, num_slots: int,
                 max_blocks_per_slot: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("num_blocks and block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.num_slots = num_slots
        self.max_blocks_per_slot = max_blocks_per_slot
        # LIFO free list: recently-freed blocks are reused first, which keeps
        # the working set of device pages small.
        self._free: List[int] = list(range(num_blocks, 0, -1))
        self._blocks: Dict[int, List[int]] = {}  # slot -> owned block ids
        self._len: Dict[int, int] = {}  # slot -> current sequence length
        self._reserved: Dict[int, int] = {}  # slot -> worst-case block count
        self._table = np.zeros((num_slots, max_blocks_per_slot), np.int32)

    # -- queries -------------------------------------------------------------
    @property
    def num_free(self) -> int:
        """Blocks not currently assigned to any lane."""
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def live_tokens(self) -> int:
        """Tokens actually cached across all lanes (<= live_blocks * bs;
        the gap is the sub-block fragmentation paging cannot remove)."""
        return sum(self._len.values())

    @property
    def reserved_blocks(self) -> int:
        return sum(self._reserved.values())

    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def seq_len(self, slot: int) -> int:
        return self._len.get(slot, 0)

    def can_admit(self, tokens: int) -> bool:
        """True if a request that may grow to ``tokens`` total cache tokens
        fits: its worst-case blocks on top of every live lane's outstanding
        reservation."""
        need = self.blocks_for(tokens)
        return (need <= self.max_blocks_per_slot
                and self.reserved_blocks + need <= self.num_blocks)

    def block_table(self) -> np.ndarray:
        """(num_slots, max_blocks_per_slot) int32; unallocated entries are
        TRASH_BLOCK.  Returns the live array — callers must not mutate it."""
        return self._table

    # -- lifecycle -----------------------------------------------------------
    def admit(self, slot: int, tokens: int) -> None:
        """Reserve worst-case capacity for a request on a free lane."""
        if slot in self._reserved:
            raise ValueError(f"slot {slot} already admitted")
        if not self.can_admit(tokens):
            raise ValueError(
                f"cannot reserve {self.blocks_for(tokens)} blocks "
                f"({self.reserved_blocks}/{self.num_blocks} already reserved)")
        self._reserved[slot] = self.blocks_for(tokens)
        self._blocks[slot] = []
        self._len[slot] = 0

    def grow(self, slot: int, seq_len: int) -> List[int]:
        """Extend ``slot`` to hold ``seq_len`` tokens; returns the newly
        assigned block ids (possibly empty).  Never exceeds the admission
        reservation, so it can never run the pool dry."""
        if slot not in self._reserved:
            raise ValueError(f"slot {slot} not admitted")
        if seq_len < self._len[slot]:
            raise ValueError(
                f"slot {slot} cannot shrink ({self._len[slot]} -> {seq_len})")
        need = self.blocks_for(seq_len)
        if need > self._reserved[slot]:
            raise ValueError(
                f"slot {slot} would exceed its reservation "
                f"({need} > {self._reserved[slot]} blocks)")
        owned = self._blocks[slot]
        new: List[int] = []
        while len(owned) < need:
            b = self._free.pop()  # cannot fail: reservation bounds demand
            self._table[slot, len(owned)] = b
            owned.append(b)
            new.append(b)
        self._len[slot] = seq_len
        return new

    def release(self, slot: int) -> List[int]:
        """Retire a request: return its blocks to the free list and drop its
        reservation.  Returns the freed block ids."""
        if slot not in self._reserved:
            raise ValueError(f"slot {slot} not admitted")
        freed = self._blocks.pop(slot)
        self._free.extend(freed)
        self._table[slot] = TRASH_BLOCK
        del self._len[slot]
        del self._reserved[slot]
        return freed

    # -- invariants (exercised by tests/test_paged_kv.py) --------------------
    def check_invariants(self) -> None:
        owned = [b for blocks in self._blocks.values() for b in blocks]
        assert len(owned) == len(set(owned)), "block double-assigned"
        assert not set(owned) & set(self._free), "live block on free list"
        assert TRASH_BLOCK not in owned and TRASH_BLOCK not in self._free
        assert len(owned) + len(self._free) == self.num_blocks, "block leaked"
        expect = sum(self.blocks_for(n) for n in self._len.values())
        assert self.live_blocks == expect, (
            f"live blocks {self.live_blocks} != sum(ceil(len/bs)) {expect}")
        for slot, blocks in self._blocks.items():
            assert len(blocks) <= self._reserved[slot]
            row = self._table[slot]
            assert list(row[:len(blocks)]) == blocks
            assert (row[len(blocks):] == TRASH_BLOCK).all()
