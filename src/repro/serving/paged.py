"""Paged KV-cache block store (host side): ref-counts, prefix cache, LRU pool.

The PR 2 allocator was a plain free list with *worst-case reservation*: every
request reserved ``ceil((prompt + budget) / block_size)`` blocks at admission
so a mid-decode ``grow`` could never fail.  That is safe but doubly
conservative for the paper's SRAM-only CC-MEM design (§4.2, Fig 6/8), where
on-chip KV capacity is the scarcest resource priced into TCO/token:

  * requests that share a prompt prefix (system prompts, few-shot headers —
    the dominant traffic shape at "millions of users" scale) each paid for
    their own copy of identical KV blocks;
  * the decode budget was reserved up front even though most requests stop
    at EOS long before it, stranding capacity admission could have used.

This module replaces the free list with a **ref-counted block store**:

  * every live block carries a reference count — multiple lanes may map the
    same block through their block tables (read-only sharing);
  * full blocks are content-addressed by a **hash chain** over their token
    ids (sha256 of ``parent_digest || token_bytes``, so a block's identity
    commits to its entire prefix, not just its own tokens).  A prefix index
    maps chain digests to live blocks; ``admit`` walks a new request's chain
    and starts the lane with every already-resident prefix block, so prefill
    only runs the uncached tail;
  * blocks whose refcount drops to zero but whose content is registered are
    *retired into an LRU pool* instead of being blanked: a later request with
    the same prefix revives them (an "LRU hit"), and allocation evicts the
    oldest pooled block only when the true free list is empty;
  * a lane that must write into a block another lane can read goes
    **copy-on-write** via ``ensure_writable`` (the store swaps in a fresh
    block; the caller copies the device payload), so sharing is never
    observable through the attention gather;
  * there is **no reservation**: ``grow`` hands out blocks lazily and raises
    ``OutOfBlocks`` when both the free list and the pool are dry.  The
    serving engine reacts by *preempting* the youngest request (release its
    blocks, re-queue it for recompute) — vLLM-style optimistic admission.

Block id 0 (``TRASH_BLOCK``) is never handed out: the device scatter for
retired/padded lanes is redirected there, so a freed block can be re-assigned
to another lane without any risk of a stale lane clobbering it.

Invariants (pinned by ``tests/test_paged_kv.py``): refcounts never go
negative; a block reaches the free list iff its refcount is zero AND it is
not in (or has left) the LRU pool; the prefix index and per-block hash map
stay a bijection; copy-on-write never hands back a block any other lane can
reach.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Block id reserved as the write sink for dead lanes; never allocated.
TRASH_BLOCK = 0


class OutOfBlocks(RuntimeError):
    """The free list and the LRU pool are both empty.

    Raised by ``grow`` / ``ensure_writable`` under optimistic admission;
    the engine preempts a request and retries.
    """


CHAIN_ROOT = b"kv-chain-root"
_CHAIN_ROOT = CHAIN_ROOT  # back-compat alias


def chain_root_for(kv_dtype: str = "fp") -> bytes:
    """The store's chain-root seed for a given pool representation.

    A block's compressed payload is a pure function of (token ids, chain
    root, kv_dtype) — the SCLAD quantizers are path-independent — so the
    kv_dtype must be part of the content address: two stores serving the
    same tokens under different ``kv_dtype`` hold different pool bytes and
    must never hash-match each other's blocks (e.g. through a snapshot or
    a shared host-side index).  fp-family spellings ("fp"/"bf16"/"f8")
    keep the historic root so existing digests stay valid.
    """
    if kv_dtype in ("int8", "fp8"):
        return hashlib.sha256(
            CHAIN_ROOT + b"|kv:" + kv_dtype.encode()).digest()
    return CHAIN_ROOT


def chain_hashes(content: Sequence[int], block_size: int,
                 prefix: Sequence[bytes] = (),
                 seed: bytes = CHAIN_ROOT) -> List[bytes]:
    """Digest per FULL block of ``content``: sha256(parent || tokens).

    The chain makes a block's identity commit to its whole prefix — two
    requests share block ``i`` only if they agree on every token up to and
    including block ``i``, which is exactly the prefix-cache safety
    condition for causal attention.

    ``prefix``: already-computed digests for the leading blocks — they are
    reused verbatim and only the remaining blocks are hashed (the
    incremental path ``commit_full`` uses so per-token decode cost stays
    O(1) amortized instead of re-hashing the whole sequence).

    ``seed``: the chain root.  Token ids alone don't always determine the
    cached K/V — a vlm request's patch prefix depends on its IMAGE, which
    the engine folds in here as a per-request patch-embedding digest, so
    two requests with identical token ids but different images can never
    share blocks.
    """
    n_full = len(content) // block_size
    out: List[bytes] = list(prefix[:n_full])
    prev = out[-1] if out else seed
    for i in range(len(out), n_full):
        blk = np.asarray(content[i * block_size:(i + 1) * block_size],
                         np.int64)
        prev = hashlib.sha256(prev + blk.tobytes()).digest()
        out.append(prev)
    return out


class BlockStore:
    """Ref-counted store of fixed-size KV token blocks over ``num_slots``
    decode lanes, with content-hash prefix sharing and an LRU retired pool.

    num_blocks:  usable pool size (ids ``1..num_blocks``; id 0 is trash).
    block_size:  tokens per block.
    num_slots:   decode lanes (rows of the block table).
    max_blocks_per_slot: width of the per-lane block table (the per-request
        context cap in blocks).
    prefix_cache: when False, no hashing/registration happens — the store
        degenerates to the plain lazy allocator (every block exclusive,
        released blocks go straight back to the free list).
    kv_dtype: the device pool's representation ("fp" family or the SCLAD
        "int8"/"fp8" compressed layouts).  Only used to derive the store's
        default chain root (``chain_root_for``): quantized pools hold
        different bytes per token than fp pools, so their content hashes
        live in a disjoint namespace and can never cross-match.
    """

    def __init__(self, num_blocks: int, block_size: int, num_slots: int,
                 max_blocks_per_slot: int, prefix_cache: bool = True,
                 kv_dtype: str = "fp"):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("num_blocks and block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.num_slots = num_slots
        self.max_blocks_per_slot = max_blocks_per_slot
        self.prefix_cache = prefix_cache
        self.kv_dtype = kv_dtype
        self.chain_root = chain_root_for(kv_dtype)
        # LIFO free list: recently-freed blocks are reused first, which keeps
        # the working set of device pages small.
        self._free: List[int] = list(range(num_blocks, 0, -1))
        #: retired-but-reusable blocks, oldest first: block -> chain digest.
        self._pool: "OrderedDict[int, bytes]" = OrderedDict()
        self._ref: Dict[int, int] = {}  # live block -> number of owning lanes
        self._hash: Dict[int, bytes] = {}  # registered block -> chain digest
        self._index: Dict[bytes, int] = {}  # chain digest -> block
        self._blocks: Dict[int, List[int]] = {}  # slot -> block ids, in order
        self._len: Dict[int, int] = {}  # slot -> grown sequence length
        #: slot -> chain digests computed so far (cache for commit_full:
        #: decode extends the chain incrementally instead of re-hashing
        #: the sequence from position 0 every window).
        self._chain: Dict[int, List[bytes]] = {}
        #: slot -> chain-root seed (per-request for vlm patch digests).
        self._seed: Dict[int, bytes] = {}
        self._table = np.zeros((num_slots, max_blocks_per_slot), np.int32)
        # Counters for EngineStats / benchmarks.
        self.hit_blocks = 0    # blocks reused through the prefix index
        self.lru_hits = 0      # of those, revived from the retired pool
        self.evictions = 0     # pooled blocks blanked to satisfy allocation
        self.cow_copies = 0    # copy-on-write block swaps

    # -- queries -------------------------------------------------------------
    @property
    def num_free(self) -> int:
        """Blocks that are blank (hold no reusable content)."""
        return len(self._free)

    @property
    def available(self) -> int:
        """Blocks obtainable by allocation: blank + evictable (LRU pool)."""
        return len(self._free) + len(self._pool)

    @property
    def live_blocks(self) -> int:
        """Blocks referenced by at least one lane (shared blocks count
        once — this is device-memory occupancy, not logical tokens)."""
        return self.num_blocks - self.available

    @property
    def pooled_blocks(self) -> int:
        return len(self._pool)

    @property
    def live_tokens(self) -> int:
        """LOGICAL tokens cached across lanes (sum of per-lane lengths).
        With prefix sharing this can exceed ``live_blocks * block_size`` —
        the gap is exactly the memory sharing saves."""
        return sum(self._len.values())

    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def seq_len(self, slot: int) -> int:
        return self._len.get(slot, 0)

    def owned_blocks(self, slot: int) -> int:
        """Blocks currently referenced by the slot (shared ones included) —
        what a preemption of this slot can drop references to."""
        return len(self._blocks.get(slot, ()))

    def ref_count(self, block: int) -> int:
        return self._ref.get(block, 0)

    def block_table(self) -> np.ndarray:
        """(num_slots, max_blocks_per_slot) int32; unallocated entries are
        TRASH_BLOCK.  Returns the live array — callers must not mutate it."""
        return self._table

    def match_prefix(self, content: Sequence[int],
                     max_cached_tokens: Optional[int] = None,
                     min_cached_tokens: int = 0,
                     seed: Optional[bytes] = None) -> int:
        """Number of leading FULL blocks of ``content`` resident in the
        store (live or pooled), after the caps admission applies:

        max_cached_tokens: never match past this many tokens (the engine
            caps at ``len(content) - 1`` so at least one token is always
            recomputed — decode needs the last-token logits);
        min_cached_tokens: an all-or-nothing floor (the vlm patch prefix
            cannot be *partially* cached — its embedding is only computed
            on a from-scratch first chunk).
        """
        if not self.prefix_cache:
            return 0
        seed = self.chain_root if seed is None else seed
        return self._match(chain_hashes(content, self.block_size, seed=seed),
                           max_cached_tokens, min_cached_tokens)

    def match_digests(self, digests: Sequence[bytes],
                      max_cached_tokens: Optional[int] = None,
                      min_cached_tokens: int = 0) -> Tuple[int, int]:
        """Like ``match_prefix`` but over precomputed chain digests, and
        also reports how many of the matched blocks currently sit in the
        LRU pool.  Admission policy needs that split: pooled blocks count
        toward ``available`` until the match revives them, so a gate that
        credits them as cached must NOT also count them as allocatable."""
        if not self.prefix_cache:
            return 0, 0
        n = self._match(digests, max_cached_tokens, min_cached_tokens)
        pooled = sum(1 for h in digests[:n] if self._index[h] in self._pool)
        return n, pooled

    def _match(self, digests: Sequence[bytes],
               max_cached_tokens: Optional[int],
               min_cached_tokens: int) -> int:
        n = 0
        for h in digests:
            if h not in self._index:
                break
            n += 1
        if max_cached_tokens is not None:
            n = min(n, max_cached_tokens // self.block_size)
        n = min(n, self.max_blocks_per_slot)
        if n * self.block_size < min_cached_tokens:
            n = 0
        return n

    # -- lifecycle -----------------------------------------------------------
    def admit(self, slot: int, content: Optional[Sequence[int]] = None,
              max_cached_tokens: Optional[int] = None,
              min_cached_tokens: int = 0,
              digests: Optional[Sequence[bytes]] = None,
              seed: Optional[bytes] = None) -> int:
        """Open a lane; start it with every cached prefix block of
        ``content`` (token ids, from cache position 0).  Takes a reference
        on each matched block — pooled blocks are revived, live ones are
        shared.  Returns the cached length in tokens (0 when nothing
        matched, caching is off, or no content was given).

        ``digests``: precomputed ``chain_hashes`` of the content — pass it
        when the caller already hashed for its admission policy, so the
        prompt is hashed once per admission, not twice.

        ``seed``: the lane's chain-root seed (see ``chain_hashes``) —
        remembered for the lane's own ``commit_full`` registrations, so a
        request's blocks are only ever matchable by requests with the SAME
        seed (e.g. the same vlm patch-embedding digest).

        There is NO capacity reservation: admission policy (how much room
        must be available before admitting) is the caller's job.
        """
        if slot in self._blocks:
            raise ValueError(f"slot {slot} already admitted")
        seed = self.chain_root if seed is None else seed
        self._blocks[slot] = []
        self._len[slot] = 0
        self._chain[slot] = []
        self._seed[slot] = seed
        if (content is None and digests is None) or not self.prefix_cache:
            return 0
        if digests is None:
            digests = chain_hashes(content, self.block_size, seed=seed)
        else:
            digests = list(digests)
        n = self._match(digests, max_cached_tokens, min_cached_tokens)
        self._chain[slot] = digests[:n]  # seed the incremental chain cache
        owned = self._blocks[slot]
        for h in digests[:n]:
            b = self._index[h]
            if b in self._pool:  # revive: retired donor, same prefix
                del self._pool[b]
                self._ref[b] = 1
                self.lru_hits += 1
            else:
                self._ref[b] += 1
            self._table[slot, len(owned)] = b
            owned.append(b)
            self.hit_blocks += 1
        self._len[slot] = n * self.block_size
        return self._len[slot]

    def _take_block(self) -> int:
        """A writable blank block: free list first, else evict the LRU
        pooled block (its cached content is lost to the prefix index)."""
        if self._free:
            return self._free.pop()
        if self._pool:
            b, h = self._pool.popitem(last=False)  # oldest retiree
            del self._index[h]
            del self._hash[b]
            self.evictions += 1
            return b
        raise OutOfBlocks(
            f"all {self.num_blocks} blocks are referenced by live lanes")

    def grow(self, slot: int, seq_len: int) -> List[int]:
        """Extend ``slot`` to hold ``seq_len`` tokens; returns the newly
        assigned block ids (possibly empty).  With no reservation this MAY
        raise ``OutOfBlocks`` — the engine preempts and retries.  On a
        partial failure the blocks already assigned stay with the lane (and
        ``seq_len`` is rounded down to what they cover), so a retry after
        preemption continues where it left off."""
        if slot not in self._blocks:
            raise ValueError(f"slot {slot} not admitted")
        if seq_len < self._len[slot]:
            raise ValueError(
                f"slot {slot} cannot shrink ({self._len[slot]} -> {seq_len})")
        need = self.blocks_for(seq_len)
        if need > self.max_blocks_per_slot:
            raise ValueError(
                f"slot {slot} needs {need} blocks; the block table is "
                f"{self.max_blocks_per_slot} wide")
        owned = self._blocks[slot]
        new: List[int] = []
        while len(owned) < need:
            try:
                b = self._take_block()
            except OutOfBlocks:
                self._len[slot] = max(self._len[slot],
                                      min(seq_len,
                                          len(owned) * self.block_size))
                raise
            self._ref[b] = 1
            self._table[slot, len(owned)] = b
            owned.append(b)
            new.append(b)
        self._len[slot] = seq_len
        return new

    def ensure_writable(self, slot: int, pos: int) -> Optional[Tuple[int, int]]:
        """Write barrier for cache position ``pos`` of ``slot``.

        If the covering block is shared (refcount > 1) it is swapped for a
        fresh exclusive block — **copy-on-write**: returns ``(src, dst)``
        and the caller must copy the device payload ``src -> dst`` before
        writing.  May raise ``OutOfBlocks``.  If the block is exclusive,
        returns None; a registered exclusive block is unregistered first
        (its content is about to diverge from its digest)."""
        if slot not in self._blocks:
            raise ValueError(f"slot {slot} not admitted")
        idx = pos // self.block_size
        owned = self._blocks[slot]
        if idx >= len(owned):
            raise ValueError(
                f"slot {slot} position {pos} not grown (has "
                f"{len(owned)} blocks)")
        b = owned[idx]
        # The write may change content at positions >= pos, so any cached
        # chain digests from this block on are no longer trustworthy.
        # (Engine writes are append-only — logical content never changes —
        # but the store stays correct for arbitrary callers.)
        del self._chain[slot][idx:]
        if self._ref[b] > 1:
            nb = self._take_block()
            self._ref[b] -= 1
            self._ref[nb] = 1
            owned[idx] = nb
            self._table[slot, idx] = nb
            self.cow_copies += 1
            return (b, nb)
        self._unregister(b)
        return None

    def _unregister(self, block: int) -> None:
        """Drop ``block`` from the prefix index: its content no longer
        matches its digest (or is about to stop matching)."""
        h = self._hash.pop(block, None)
        if h is not None and self._index.get(h) == block:
            del self._index[h]

    def commit_full(self, slot: int, content: Sequence[int]) -> int:
        """Register the lane's full, written blocks in the prefix index.

        ``content`` are the token ids actually written (cache position
        order).  Only blocks both fully *grown into* and fully *covered by
        content* are eligible (a lane pre-grown for multi-step decode may
        own blocks beyond its written length).  Already-registered blocks
        and duplicate content (another block holds the same chain digest)
        are skipped.  Returns the number of newly registered blocks.
        """
        if not self.prefix_cache:
            return 0
        if slot not in self._blocks:
            raise ValueError(f"slot {slot} not admitted")
        owned = self._blocks[slot]
        n_full = min(self._len[slot], len(content)) // self.block_size
        # Incremental: digests before len(self._chain[slot]) are reused,
        # so a decode loop calling this every window hashes each block
        # once, not the whole sequence every token.
        chain = chain_hashes(content[:n_full * self.block_size],
                             self.block_size, prefix=self._chain[slot],
                             seed=self._seed[slot])
        self._chain[slot] = chain
        added = 0
        for i, h in enumerate(chain):
            b = owned[i]
            if b in self._hash or h in self._index:
                continue
            self._hash[b] = h
            self._index[h] = b
            added += 1
        return added

    def truncate(self, slot: int, new_len: int) -> List[int]:
        """Roll the lane back to ``new_len`` tokens — the speculative-decode
        rejection path: drafted K/V was written through the pool
        optimistically, the verifier rejected a suffix, and the lane's
        logical length rewinds.

        Safety rules (pinned in tests/test_paged_kv.py):

        * blocks past ``blocks_for(new_len)`` lose this lane's reference;
          at refcount zero they are unregistered and go to the FREE list,
          never the LRU pool — their tail bytes are untrusted, so a stale
          digest must not be able to revive them;
        * a now-partial boundary block that this lane owns exclusively is
          unregistered (its tail holds rolled-back bytes that a future
          write will replace, so its digest no longer binds);
        * a SHARED boundary block keeps its registration and is not
          touched: the lane can never have written it (the copy-on-write
          barrier in ``ensure_writable`` forbids it), so its content is
          still exactly its digest and every other owner stays intact;
        * cached chain digests from the first rolled-back block on are
          invalidated, so a later ``commit_full`` re-hashes the suffix the
          lane actually wrote instead of reviving the stale chain.

        Returns the block ids whose refcount reached zero (freed).
        """
        if slot not in self._blocks:
            raise ValueError(f"slot {slot} not admitted")
        if not 0 <= new_len <= self._len[slot]:
            raise ValueError(
                f"slot {slot} cannot truncate to {new_len} "
                f"(grown length {self._len[slot]})")
        keep = self.blocks_for(new_len)
        owned = self._blocks[slot]
        del self._chain[slot][new_len // self.block_size:]
        dropped: List[int] = []
        while len(owned) > keep:
            b = owned.pop()
            self._table[slot, len(owned)] = TRASH_BLOCK
            self._ref[b] -= 1
            assert self._ref[b] >= 0, f"block {b} refcount went negative"
            if self._ref[b] == 0:
                del self._ref[b]
                self._unregister(b)
                self._free.append(b)
                dropped.append(b)
        if new_len % self.block_size and keep:
            b = owned[keep - 1]
            if self._ref[b] == 1:
                self._unregister(b)
        self._len[slot] = new_len
        return dropped

    def release(self, slot: int) -> List[int]:
        """Retire a request: drop one reference from each of its blocks.
        Blocks that hit refcount zero either retire into the LRU pool
        (registered content stays matchable) or return to the free list
        (unregistered / partial blocks).  Shared blocks another lane still
        references stay live and are NOT returned.  Returns the block ids
        whose refcount reached zero."""
        if slot not in self._blocks:
            raise ValueError(f"slot {slot} not admitted")
        dropped: List[int] = []
        for b in self._blocks.pop(slot):
            self._ref[b] -= 1
            assert self._ref[b] >= 0, f"block {b} refcount went negative"
            if self._ref[b] == 0:
                del self._ref[b]
                h = self._hash.get(b)
                if h is not None:
                    self._pool[b] = h  # newest retiree at the MRU end
                else:
                    self._free.append(b)
                dropped.append(b)
        self._table[slot] = TRASH_BLOCK
        del self._len[slot]
        del self._chain[slot]
        del self._seed[slot]
        return dropped

    # -- invariants (exercised by tests/test_paged_kv.py) --------------------
    def check_invariants(self) -> None:
        counts: Dict[int, int] = {}
        for slot, blocks in self._blocks.items():
            assert len(blocks) == len(set(blocks)), \
                f"slot {slot} lists a block twice"
            for b in blocks:
                counts[b] = counts.get(b, 0) + 1
        live, free, pool = set(counts), set(self._free), set(self._pool)
        assert not live & free, "live block on the free list"
        assert not live & pool, "live block in the retired pool"
        assert not free & pool, "block both free and pooled"
        assert TRASH_BLOCK not in live | free | pool
        assert len(live) + len(free) + len(pool) == self.num_blocks, \
            "block leaked"
        assert set(self._ref) == live
        for b, n in counts.items():
            assert self._ref[b] == n, (
                f"block {b} refcount {self._ref[b]} != {n} owning lanes")
            assert n >= 1
        for b in pool:
            assert b in self._hash, "pooled block lost its registration"
            assert self._pool[b] == self._hash[b]
        for h, b in self._index.items():
            assert self._hash.get(b) == h, "index/hash maps diverged"
        for b, h in self._hash.items():
            assert self._index.get(h) == b, "hash map entry not indexed"
            assert b in live or b in pool
        assert set(self._chain) == set(self._blocks), "chain cache leaked"
        assert set(self._seed) == set(self._blocks), "seed map leaked"
        for slot, chain in self._chain.items():
            assert len(chain) <= len(self._blocks[slot])
        expect = sum(self.blocks_for(n) for n in self._len.values())
        total_owned = sum(len(b) for b in self._blocks.values())
        assert total_owned == expect, (
            f"owned blocks {total_owned} != sum(ceil(len/bs)) {expect}")
        for slot, blocks in self._blocks.items():
            row = self._table[slot]
            assert list(row[:len(blocks)]) == blocks
            assert (row[len(blocks):] == TRASH_BLOCK).all()


#: Back-compat alias (PR 2 name); the reservation API is gone, only the
#: class name survives.
BlockAllocator = BlockStore
