"""Closed-loop jit warmup for the serving engine's prefill retrace space.

Prefill compiles per (admission group size, chunk bucket) shape: the
closed-loop sections of ``benchmarks.serving_bench`` hit each shape
naturally before measuring, but an OPEN-LOOP arrival process admits in
groups of any size from 1 up to ``max_batch`` depending on timing — a
group size first seen mid-run stalls a scheduler tick on a multi-second
XLA compile and wrecks both the client latency distribution and the
circuit breaker's tick clock (the PR 7 follow-up this module fixes:
``launch/serve.py --frontend async`` used to warm only group size 1).

``warmup_prefill`` drains one tiny closed-loop batch per (group size,
prompt-length bucket) combination, so every shape the trace can admit is
already compiled when the clock starts.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np


def warmup_prefill(engine, vocab_size: int,
                   prompt_lens: Iterable[int] = (12,),
                   max_new_tokens: int = 2, seed: int = 99,
                   reset_stats: bool = True) -> None:
    """Warm ``engine``'s jit caches for every admission group size.

    For each prompt length in ``prompt_lens`` (deduplicated; pick one
    representative per chunk bucket the real trace can hit, including any
    shared-prefix length) and each group size ``1..engine.max_batch``,
    submit that many uniform random prompts and drain them closed-loop.
    Also compiles the decode window (and the speculative verify pass when
    ``spec_decode`` is on — fixed-shape, so one group covers it).

    ``reset_stats``: start the engine's ``EngineStats`` fresh afterwards
    so warmup traffic never pollutes measured numbers.
    """
    from repro.serving.engine import EngineStats

    rng = np.random.default_rng(seed)
    for n in sorted({int(n) for n in prompt_lens}):
        if not 0 < n < engine.max_len:
            continue
        for g in range(1, engine.max_batch + 1):
            for _ in range(g):
                engine.submit(rng.integers(1, vocab_size, size=n),
                              max_new_tokens=max_new_tokens)
            engine.run()
    if reset_stats:
        engine.stats = EngineStats()
