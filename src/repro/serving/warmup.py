"""Closed-loop jit warmup for the serving engine's prefill retrace space.

Prefill compiles per (admission group size, chunk bucket) shape: the
closed-loop sections of ``benchmarks.serving_bench`` hit each shape
naturally before measuring, but an OPEN-LOOP arrival process admits in
groups of any size from 1 up to ``max_batch`` depending on timing — a
group size first seen mid-run stalls a scheduler tick on a multi-second
XLA compile and wrecks both the client latency distribution and the
circuit breaker's tick clock (the PR 7 follow-up this module fixes:
``launch/serve.py --frontend async`` used to warm only group size 1).

``warmup_prefill`` drains one tiny closed-loop batch per (group size,
prompt-length bucket) combination, so every shape the trace can admit is
already compiled when the clock starts.
"""
from __future__ import annotations

from typing import Iterable, Sequence, Tuple

import numpy as np


def trace_prompt_lens(trace: Sequence, engine,
                      extra: Iterable[int] = ()) -> Tuple[int, ...]:
    """Representative prompt lengths covering every prefill shape an
    open-loop ``trace`` can make ``engine`` compile.

    Candidates are each item's prompt length AND its preemption-recompute
    worst case (a preempted request replays prompt + generated-so-far as
    one longer prompt, clamped to the cache), plus any ``extra`` lengths
    the caller knows about (e.g. the shared system-prefix length).  Two
    candidates that decompose into the same chunk shapes — same
    has-continuation-chunks bit, same power-of-two bucket of the tail
    chunk — compile the same code, so one representative (the longest,
    which also walks the most continuation chunks) is kept per shape.
    This is THE coverage rule: ``launch.serve`` and the bench's open-loop
    sections both derive their warmup from it, so the launcher can never
    again retrace on a shape the bench had warmed (the PR 7 follow-up).
    """
    from repro.serving.engine import _bucket

    cap = engine.prefill_chunk or engine.max_len
    cand = {int(n) for n in extra}
    for it in trace:
        p = len(it.prompt)
        cand.add(p)
        cand.add(min(p + int(it.max_new_tokens), engine.max_len - 1))
    reps = {}
    for n in sorted(cand):
        if not 0 < n < engine.max_len:
            continue
        tail = n % cap or cap
        key = (n > cap, _bucket(tail, cap))
        reps[key] = n  # sorted iteration: keeps the longest per shape
    return tuple(sorted(reps.values()))


def warmup_prefill(engine, vocab_size: int,
                   prompt_lens: Iterable[int] = (12,),
                   max_new_tokens: int = 2, seed: int = 99,
                   reset_stats: bool = True) -> None:
    """Warm ``engine``'s jit caches for every admission group size.

    For each prompt length in ``prompt_lens`` (deduplicated; pick one
    representative per chunk bucket the real trace can hit, including any
    shared-prefix length) and each group size ``1..engine.max_batch``,
    submit that many uniform random prompts and drain them closed-loop.
    Also compiles the decode window (and the speculative verify pass when
    ``spec_decode`` is on — fixed-shape, so one group covers it).

    ``reset_stats``: start the engine's ``EngineStats`` fresh afterwards
    so warmup traffic never pollutes measured numbers.
    """
    from repro.serving.engine import EngineStats

    rng = np.random.default_rng(seed)
    for n in sorted({int(n) for n in prompt_lens}):
        if not 0 < n < engine.max_len:
            continue
        for g in range(1, engine.max_batch + 1):
            for _ in range(g):
                engine.submit(rng.integers(1, vocab_size, size=n),
                              max_new_tokens=max_new_tokens)
            engine.run()
    if reset_stats:
        engine.stats = EngineStats()
