"""Open-loop arrival driver + latency-distribution report for the frontend.

Closed-loop benchmarking (submit everything, run to drain) measures the
engine at its own pace and therefore HIDES queueing delay — the metric
regime the serving-systems literature cares about is open-loop: requests
arrive on a Poisson clock that does not wait for the scheduler, and the
system is judged on tail latency (p99 TTFT, p99 inter-token latency) and
*goodput under an SLO* — completed requests that met their latency target
per second, not raw throughput.  This module provides that posture for
``AsyncFrontend``:

  * ``poisson_trace(...)`` — a reproducible open-loop trace: exponential
    interarrivals at ``rate_req_s`` with per-request prompts/budgets
    drawn from a seeded ``numpy`` Generator.
  * ``drive(frontend, trace)`` — one asyncio client per trace item that
    sleeps until its arrival time, submits, and consumes its stream,
    timestamping every token on the *client* side (so TTFT includes
    admission queueing, which engine-side stats cannot see).
  * ``run_open_loop(engine, trace, ...)`` — sync wrapper: builds the
    frontend, drives the trace, drains, and returns an
    ``OpenLoopReport`` whose ``summary(slo_ttft_s)`` emits the JSON
    block ``serving_bench`` writes into ``BENCH_serving.json``.

Rejected ("backpressure") and shed ("breaker") arrivals are recorded,
not retried: an open-loop client models the load balancer's view, and
retry policy belongs to the caller.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import EngineStats, ServingEngine
from repro.serving.frontend import AsyncFrontend, CircuitBreaker, \
    RejectedError


@dataclass
class TraceItem:
    """One scheduled arrival in an open-loop trace."""
    arrival_s: float
    prompt: np.ndarray
    max_new_tokens: int
    deadline: Optional[float] = None
    priority: int = 0


@dataclass
class RequestRecord:
    """Client-side outcome of one trace item."""
    arrival_s: float          # scheduled offset from trace start
    #: completed | rejected | shed | timeout | error — "timeout" is a
    #: mid-stream RejectedError(kind="timeout") (per-request wall-clock
    #: budget or failover retry budget exhausted).
    status: str = "pending"
    submit_t: float = 0.0     # wall perf_counter at submit
    token_t: List[float] = field(default_factory=list)
    tokens: List[int] = field(default_factory=list)
    error: Optional[str] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if not self.token_t:
            return None
        return self.token_t[0] - self.submit_t

    @property
    def itl_s(self) -> List[float]:
        return [b - a for a, b in zip(self.token_t, self.token_t[1:])]


def poisson_trace(rng: np.random.Generator, n: int, rate_req_s: float,
                  vocab: int, prompt_len: tuple = (8, 24),
                  budget: tuple = (8, 24),
                  shared_prefix: Optional[np.ndarray] = None,
                  prefix_fraction: float = 0.0) -> List[TraceItem]:
    """Build ``n`` Poisson arrivals at ``rate_req_s`` requests/second.

    Interarrivals are exponential draws; prompt lengths and decode
    budgets are uniform over the given inclusive ranges.  With
    ``prefix_fraction > 0`` that fraction of requests (Bernoulli) start
    with ``shared_prefix`` — the open-loop analogue of the closed-loop
    shared-prefix bench section.
    """
    if rate_req_s <= 0.0:
        raise ValueError(f"rate_req_s must be > 0, got {rate_req_s}")
    arrivals = np.cumsum(rng.exponential(1.0 / rate_req_s, size=n))
    items: List[TraceItem] = []
    for i in range(n):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        toks = rng.integers(0, vocab, size=plen).astype(np.int32)
        if shared_prefix is not None and prefix_fraction > 0.0 \
                and rng.random() < prefix_fraction:
            toks = np.concatenate(
                [np.asarray(shared_prefix, np.int32), toks])
        items.append(TraceItem(
            arrival_s=float(arrivals[i]), prompt=toks,
            max_new_tokens=int(rng.integers(budget[0], budget[1] + 1))))
    return items


async def drive(frontend: AsyncFrontend,
                trace: Sequence[TraceItem]) -> List[RequestRecord]:
    """Run the trace open-loop against a started frontend.

    Every item gets its own client coroutine: sleep until the scheduled
    arrival, submit (a rejection is final — no retry), then consume the
    stream timestamping each token.  Returns records in trace order.
    """
    t0 = time.perf_counter()

    async def one(item: TraceItem) -> RequestRecord:
        rec = RequestRecord(arrival_s=item.arrival_s)
        delay = (t0 + item.arrival_s) - time.perf_counter()
        if delay > 0.0:
            await asyncio.sleep(delay)
        rec.submit_t = time.perf_counter()
        try:
            stream = await frontend.submit(
                item.prompt, max_new_tokens=item.max_new_tokens,
                deadline=item.deadline, priority=item.priority)
        except RejectedError as e:
            rec.status = "shed" if e.kind == "breaker" else "rejected"
            rec.error = str(e)
            return rec
        try:
            async for tok in stream:
                rec.token_t.append(time.perf_counter())
                rec.tokens.append(tok)
            rec.status = "completed"
        except RejectedError as e:
            # Mid-stream rejection: the request was admitted but ended by
            # its wall-clock timeout or the failover retry budget.
            rec.status = "timeout" if e.kind == "timeout" else "shed" \
                if e.kind == "breaker" else "rejected"
            rec.error = str(e)
        except Exception as e:
            rec.status = "error"
            rec.error = f"{type(e).__name__}: {e}"
        return rec

    return list(await asyncio.gather(*(one(it) for it in trace)))


@dataclass
class OpenLoopReport:
    """Everything one open-loop run produced, plus the JSON summary."""
    records: List[RequestRecord]
    wall_s: float
    frontend: AsyncFrontend

    def completed(self) -> List[RequestRecord]:
        return [r for r in self.records if r.status == "completed"]

    def count(self, status: str) -> int:
        return sum(1 for r in self.records if r.status == status)

    @property
    def availability(self) -> float:
        """Completed requests over all arrivals — the fleet-level uptime
        number chaos runs gate on (a dead replica must not cost the
        trace's completions; failover keeps availability at 1.0)."""
        return self.count("completed") / max(len(self.records), 1)

    def goodput_under_slo(self, slo_ttft_s: float) -> Dict[str, float]:
        """Requests that completed AND met the client-side TTFT SLO,
        normalized per wall-clock second (requests and tokens)."""
        good = [r for r in self.completed()
                if r.ttft_s is not None and r.ttft_s <= slo_ttft_s]
        wall = max(self.wall_s, 1e-9)
        return {
            "slo_ttft_s": slo_ttft_s,
            "good_requests": len(good),
            "goodput_req_s": len(good) / wall,
            "goodput_tok_s": sum(len(r.tokens) for r in good) / wall,
        }

    def summary(self, slo_ttft_s: float) -> Dict[str, object]:
        """The JSON block serving_bench embeds in BENCH_serving.json."""
        pct = EngineStats.percentile
        ttfts = [r.ttft_s for r in self.completed()
                 if r.ttft_s is not None]
        itls = [g for r in self.completed() for g in r.itl_s]
        br = self.frontend.breaker
        out = {
            "requests": len(self.records),
            "completed": self.count("completed"),
            "rejected_backpressure": self.count("rejected"),
            "shed_breaker": self.count("shed"),
            "timeouts": self.count("timeout"),
            "errors": self.count("error"),
            "availability": self.availability,
            "wall_s": self.wall_s,
            "client_p50_ttft_s": pct(ttfts, 50.0),
            "client_p99_ttft_s": pct(ttfts, 99.0),
            "client_p50_itl_s": pct(itls, 50.0),
            "client_p99_itl_s": pct(itls, 99.0),
            "goodput": self.goodput_under_slo(slo_ttft_s),
            "breaker": {
                "opens": br.opens,
                "shed": br.shed,
                "final_state": br.state,
                "transitions": [list(t) for t in br.transitions],
            },
        }
        # Fleet frontends (ReplicaRouter) carry fault-tolerance counters
        # — failovers, replica deaths, watchdog trips, retries, drains —
        # threaded into the summary when present.
        ft = getattr(self.frontend, "fault_report", None)
        if callable(ft):
            out["fault_tolerance"] = ft()
        return out


def run_open_loop(engine: ServingEngine, trace: Sequence[TraceItem], *,
                  max_queue_depth: int = 64,
                  breaker: Optional[CircuitBreaker] = None,
                  idle_sleep_s: float = 0.001) -> OpenLoopReport:
    """Drive ``trace`` through a fresh ``AsyncFrontend`` on ``engine``
    and return the report (frontend is started, drained, stopped)."""
    fe = AsyncFrontend(engine, max_queue_depth=max_queue_depth,
                       breaker=breaker, idle_sleep_s=idle_sleep_s)

    async def main() -> List[RequestRecord]:
        await fe.start()
        try:
            return await drive(fe, trace)
        finally:
            await fe.stop(drain=True)

    t0 = time.perf_counter()
    records = asyncio.run(main())
    return OpenLoopReport(records=records,
                          wall_s=time.perf_counter() - t0, frontend=fe)
