"""Deterministic fault injection for replica engines.

The paper's fleet posture (thousands of replicated accelerator modules
serving one workload, §4) makes individual module failure a steady-state
condition — so the serving stack's failure handling must be TESTABLE the
same way its scheduling is: replayable, seeded, and free of wall-clock
races.  This module provides exactly that:

  * ``FaultPlan`` — an immutable schedule of fault events keyed by
    *engine-step index* (the number of ``step()`` calls the replica has
    executed, NOT wall time or pump iterations: idle pump ticks never
    advance it, so a plan replays identically under a live pump or a
    manually-stepped test).  Plans are built explicitly
    (``FaultPlan.crash_at(12)``) or drawn from a seed
    (``FaultPlan.seeded(7)``) via a private ``numpy`` Generator — no
    global RNG, no ``time``.
  * ``FaultyEngine`` — a transparent proxy around a ``ServingEngine``
    that injects the plan at the engine-step boundary and delegates
    everything else untouched (``submit``/``cancel``/stats/probes all
    reach the real engine, so scheduler state stays exactly what the
    health layer must recover).

Fault kinds (``FAULT_KINDS``):

  * ``"crash"`` — from its tick on, every ``step()`` raises
    ``ReplicaCrashed`` forever (a dead module does not come back; the
    router's health tracker must detect it and fail its requests over).
  * ``"hang"`` — the step at its tick does nothing and reports a virtual
    cost of ``duration`` ticks via ``last_step_cost`` (one stalled
    device interaction); a cost above the health watchdog's deadline is
    what marks a replica suspect.
  * ``"raise"`` — the step at its tick raises ``InjectedFault`` once and
    the replica then recovers: the transient-device-error case that must
    NOT kill a replica (only *consecutive* failures may).
  * ``"slow"`` — for ``duration`` steps from its tick, only every
    ``factor``-th step makes progress (the others are skipped beats): a
    straggler replica whose throughput drops by ``factor`` without ever
    tripping the watchdog.

Injection happens BEFORE the wrapped ``step()`` runs, so an injected
fault never leaves a half-applied scheduler iteration — the engine's own
poisoned-step contract (``ServingEngine.step``) covers genuine mid-step
failures.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("crash", "hang", "raise", "slow")


class ReplicaCrashed(RuntimeError):
    """The replica is gone: every ``step()`` raises this, forever."""


class InjectedFault(RuntimeError):
    """A transient injected step failure (the replica recovers)."""


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``tick`` is the engine-step index it fires
    at; ``duration`` is the hang's virtual step cost (in watchdog ticks)
    or the slow window's length (in steps); ``factor`` is the slow
    window's progress divisor (1 real step per ``factor`` calls)."""
    kind: str
    tick: int
    duration: int = 1
    factor: int = 2

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind {self.kind!r} not in {FAULT_KINDS}")
        if self.tick < 0:
            raise ValueError(f"tick must be >= 0, got {self.tick}")
        if self.duration < 1 or self.factor < 1:
            raise ValueError("duration and factor must be >= 1")


class FaultPlan:
    """An immutable, replayable schedule of ``FaultEvent``s.

    Plans compose with ``+`` (union of events); ``seeded`` draws a
    random schedule reproducibly from an integer seed.  All queries are
    by engine-step index and read-only, so one plan object can replay
    any number of runs."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: Tuple[FaultEvent, ...] = tuple(sorted(
            events, key=lambda e: (e.tick, FAULT_KINDS.index(e.kind))))

    # -- constructors --------------------------------------------------------
    @classmethod
    def crash_at(cls, tick: int) -> "FaultPlan":
        return cls([FaultEvent("crash", tick)])

    @classmethod
    def hang_at(cls, tick: int, duration: int) -> "FaultPlan":
        return cls([FaultEvent("hang", tick, duration=duration)])

    @classmethod
    def raise_at(cls, tick: int) -> "FaultPlan":
        return cls([FaultEvent("raise", tick)])

    @classmethod
    def slow_from(cls, tick: int, factor: int,
                  duration: int) -> "FaultPlan":
        return cls([FaultEvent("slow", tick, duration=duration,
                               factor=factor)])

    @classmethod
    def seeded(cls, seed: int, *, horizon: int = 64,
               crash_p: float = 0.0, hang_p: float = 0.05,
               raise_p: float = 0.05, slow_p: float = 0.05,
               max_hang: int = 64, max_factor: int = 4,
               max_slow: int = 8) -> "FaultPlan":
        """Draw a random plan from ``seed`` — the chaos-test entry point.

        Each step index in ``[0, horizon)`` independently hosts a hang /
        raise / slow event with the given probabilities; at most ONE
        crash is placed (uniformly over the horizon, with probability
        ``crash_p``), since nothing after a crash can fire.  Same seed
        and knobs -> the identical plan, always."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        if crash_p > 0.0 and rng.random() < crash_p:
            events.append(FaultEvent(
                "crash", int(rng.integers(0, horizon))))
        for t in range(horizon):
            if rng.random() < hang_p:
                events.append(FaultEvent(
                    "hang", t, duration=int(rng.integers(2, max_hang + 1))))
            if rng.random() < raise_p:
                events.append(FaultEvent("raise", t))
            if rng.random() < slow_p:
                events.append(FaultEvent(
                    "slow", t, duration=int(rng.integers(1, max_slow + 1)),
                    factor=int(rng.integers(2, max_factor + 1))))
        return cls(events)

    # -- queries -------------------------------------------------------------
    def crash_tick(self) -> Optional[int]:
        ticks = [e.tick for e in self.events if e.kind == "crash"]
        return min(ticks) if ticks else None

    def hang_at_tick(self, tick: int) -> Optional[FaultEvent]:
        for e in self.events:
            if e.kind == "hang" and e.tick == tick:
                return e
        return None

    def raises_at(self, tick: int) -> bool:
        return any(e.kind == "raise" and e.tick == tick
                   for e in self.events)

    def slow_at(self, tick: int) -> Optional[FaultEvent]:
        for e in self.events:
            if e.kind == "slow" and e.tick <= tick < e.tick + e.duration:
                return e
        return None

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(self.events + other.events)

    def __len__(self) -> int:
        return len(self.events)

    def describe(self) -> str:
        """One line per event, for logs and bench provenance."""
        if not self.events:
            return "no faults"
        return "; ".join(
            f"{e.kind}@{e.tick}"
            + (f" x{e.duration}" if e.kind in ("hang", "slow") else "")
            + (f" /{e.factor}" if e.kind == "slow" else "")
            for e in self.events)


class FaultyEngine:
    """A ``ServingEngine`` proxy that injects a ``FaultPlan`` at the
    engine-step boundary.

    Everything except ``step()`` delegates to the wrapped engine — the
    frontend/router surface (``submit``, ``cancel``,
    ``has_pending_work``, ``match_cached_blocks``, ``live_blocks``,
    ``pool_saturation``, ``stats``, ``on_token``, ...) behaves exactly
    like the real replica, which is the point: the health layer must
    recover REAL scheduler state, not a mock's.

    ``last_step_cost`` is the virtual duration (in watchdog ticks) of the
    most recent ``step()`` call: 1 normally, the hang's ``duration`` for
    a stalled step.  The frontend forwards it to the router's per-replica
    watchdog, so hang detection is deterministic — no wall clock.
    """

    def __init__(self, engine, plan: FaultPlan):
        self._engine = engine
        self.plan = plan
        #: Engine-step index: increments once per step() CALL (injected
        #: or delegated), never on idle pump ticks.
        self.ticks = 0
        self.crashed = False
        #: Faults actually fired (a plan event past the run's end never
        #: fires; the chaos tests account against this, not the plan).
        self.injected = 0
        self.last_step_cost = 1

    def __getattr__(self, name):
        return getattr(self._engine, name)

    @property
    def engine(self):
        """The wrapped engine (for tests and reports)."""
        return self._engine

    @property
    def on_token(self):
        return self._engine.on_token

    @on_token.setter
    def on_token(self, fn):
        self._engine.on_token = fn

    def step(self):
        if self.crashed:
            raise ReplicaCrashed(
                f"replica crashed at engine step "
                f"{self.plan.crash_tick()} and will not recover")
        t = self.ticks
        self.ticks += 1
        self.last_step_cost = 1
        crash = self.plan.crash_tick()
        if crash is not None and t >= crash:
            self.crashed = True
            self.injected += 1
            raise ReplicaCrashed(f"injected crash at engine step {t}")
        hang = self.plan.hang_at_tick(t)
        if hang is not None:
            self.injected += 1
            self.last_step_cost = hang.duration
            return []
        if self.plan.raises_at(t):
            self.injected += 1
            raise InjectedFault(f"injected step error at engine step {t}")
        slow = self.plan.slow_at(t)
        if slow is not None and (t - slow.tick) % slow.factor != 0:
            self.injected += 1
            return []  # skipped beat: a straggler's lost step
        return self._engine.step()
