"""Prefix-affinity replica router: N serving engines, one admission surface.

Rung 2 of the scale ladder (the paper's §4 cloud posture): tensor
parallelism widens ONE engine over a mesh (``ServingEngine(mesh=...)``);
past that, throughput comes from REPLICAS — independent engines, each its
own single controller (own scheduler, own ``BlockStore``, own KV pool,
own breaker), coordinated only at admission time.  ``ReplicaRouter``
fronts N ``AsyncFrontend``-wrapped engines with exactly the client API of
one frontend (``submit`` -> ``TokenStream``), so the open-loop driver and
any other client code run against a fleet unchanged.

Placement is the router's whole job, and prefix caching makes it
non-trivial: a replica that already holds a request's leading blocks
serves it with most of its prefill skipped, but ONLY that replica —
block pools do not gossip.  Policies:

  * ``"affinity"`` (default) — probe every replica's prefix cache with
    ``engine.match_cached_blocks`` (the SAME hash chain admission uses:
    vlm patch sentinels, per-request chain seed, kv_dtype-namespaced
    root, so a hit here is a hit at admission) and route to the deepest
    match; ties — including the no-match common case — fall back to
    least-loaded by ``live blocks + frontend queue depth``.  Result:
    shared-system-prompt traffic converges onto warm replicas (aggregate
    prefix hit-rate approaches the single-engine rate) while cold
    traffic spreads by load.
  * ``"round_robin"`` — rotate submissions; the affinity-blind baseline
    the bench compares against (shared prefixes get re-prefetched on
    every replica they land on).

Admission folds per-replica backpressure/breaker state into ONE
client-facing surface: a submit tries replicas in preference order and
only raises ``RejectedError`` when EVERY replica rejected — with
``kind="breaker"`` only when all of them were shedding (the fleet is
saturated), else ``kind="backpressure"`` (retry with backoff; some queue
was merely full).  A single overloaded replica therefore sheds onto its
peers before the client ever sees a 503.

Correctness contract: the router never touches tokens — per-request
streams are bit-identical to the same prompt on a solo engine (greedy
sampling; stochastic streams are keyed by per-engine uids and so depend
on placement by construction).  Pinned in tests/test_router.py.

Fault tolerance (PR 10): at fleet scale module failure is steady-state,
so the router also owns the per-replica HEALTH state machine and request
FAILOVER:

  * **health** — each replica walks healthy -> suspect -> dead
    (``ReplicaHealth``), driven by the frontend's per-tick observer: a
    step whose virtual cost exceeds the watchdog deadline marks the
    replica suspect (hung device); ``crash_threshold`` CONSECUTIVE step
    exceptions mark it dead (a transient error alone never kills — the
    next clean step resets the count).  Suspect replicas take only
    ``probes`` probe placements (the breaker's half-open pattern): a
    probe completing cleanly revives them to healthy.  Dead replicas and
    replicas under administrative ``drain(i)`` are excluded from
    placement; draining lets in-flight lanes finish.
  * **failover** — when a replica dies, its pump is halted, its
    in-flight tickets are detached (streams stay open) and each request
    is resubmitted to a healthy replica as prompt + already-emitted
    tokens: exactly the engine's preemption-recompute path (per-position
    PRNG keys make the replay sampling-invariant; with prefix caching
    the recompute is mostly cache hits).  The new ticket's queue is
    ALIASED to the client's queue and the emitted prefix is never
    regenerated, so the client's ``TokenStream`` continues seamlessly
    and the completed output is BIT-IDENTICAL to a failure-free run
    (greedy; the headline test).  A per-request ``retry_budget`` bounds
    re-homing; exhaustion surfaces ``RejectedError(kind="timeout")``
    from the stream.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.engine import EngineStats, ServingEngine
from repro.serving.frontend import (_DONE, AsyncFrontend, CircuitBreaker,
                                    RejectedError, TokenStream)

ROUTER_POLICIES = ("affinity", "round_robin")

#: Replica health states, in degradation order.
HEALTH_STATES = ("healthy", "suspect", "dead")


class ReplicaHealth:
    """Per-replica healthy/suspect/dead state machine (router-owned).

    Inputs are the frontend's per-tick reports (``record_step``): an
    ERROR tick bumps the consecutive-failure count — ``crash_threshold``
    in a row is a crash and the replica is DEAD; fewer mark it SUSPECT
    until a clean probe revives it.  A clean tick whose virtual cost
    exceeds ``deadline_ticks`` is a WATCHDOG trip (hung/stalled device)
    and also marks suspect.  Suspect replicas accept at most ``probes``
    concurrent probe placements (mirroring the circuit breaker's
    half-open state); a probe that completes cleanly returns the replica
    to healthy, a failed probe leaves it suspect (only consecutive step
    errors kill).  Dead is terminal — fleet recovery is failover plus a
    replacement replica, not resurrection.  ``draining`` is orthogonal
    administrative state: no new placements, in-flight lanes finish.

    All counting is in ticks reported by the pump — no wall clock — so
    fault-injection tests replay deterministically."""

    def __init__(self, *, deadline_ticks: int = 16,
                 crash_threshold: int = 3, probes: int = 1):
        if deadline_ticks < 1 or crash_threshold < 1 or probes < 1:
            raise ValueError("health knobs must all be >= 1")
        self.deadline_ticks = deadline_ticks
        self.crash_threshold = crash_threshold
        self.probes = probes
        self.state = "healthy"
        self.draining = False
        self.watchdog_trips = 0
        self.step_errors = 0
        self.consecutive_errors = 0
        #: Every state change, in order, as (from, to).
        self.transitions: List[Tuple[str, str]] = []
        self._probe_live = 0

    def record_step(self, *, error: Optional[BaseException] = None,
                    cost_ticks: int = 1) -> Optional[str]:
        """Fold one tick's outcome in; returns the notable event —
        "watchdog" (deadline trip), "died" (crash threshold reached),
        "error" (a non-fatal step error) or None."""
        if self.state == "dead":
            return None
        if error is not None:
            self.step_errors += 1
            self.consecutive_errors += 1
            if self.consecutive_errors >= self.crash_threshold:
                self._to("dead")
                return "died"
            if self.state == "healthy":
                self._to("suspect")
            return "error"
        self.consecutive_errors = 0
        if cost_ticks > self.deadline_ticks:
            self.watchdog_trips += 1
            if self.state == "healthy":
                self._to("suspect")
            return "watchdog"
        return None

    def can_place(self) -> bool:
        """May this replica take a NEW request right now?"""
        if self.draining or self.state == "dead":
            return False
        if self.state == "suspect":
            return self._probe_live < self.probes
        return True

    def note_placed(self) -> bool:
        """Record one accepted placement; True if it is a health probe
        (the replica is suspect and this request's completion will judge
        it)."""
        if self.state == "suspect":
            self._probe_live += 1
            return True
        return False

    def record_probe_end(self, ok: Optional[bool]) -> None:
        """A probe placement ended: True = completed cleanly (revive),
        False = errored, None = cancelled (no judgement)."""
        self._probe_live = max(0, self._probe_live - 1)
        if ok and self.state == "suspect":
            self._to("healthy")
            self.consecutive_errors = 0

    def mark_dead(self) -> None:
        if self.state != "dead":
            self._to("dead")

    def _to(self, state: str) -> None:
        self.transitions.append((self.state, state))
        self.state = state
        if state == "suspect":
            self._probe_live = 0


@dataclass
class RouterStats:
    """Admission-time routing outcomes (token accounting lives in each
    engine's own ``EngineStats``)."""
    submitted: int = 0
    rejected: int = 0
    #: Submits whose chosen replica already held >= 1 block of the prompt
    #: (over submits where ANY replica did — the router's hit-RATE is
    #: affinity_hits / affinity_eligible).
    affinity_hits: int = 0
    affinity_eligible: int = 0
    #: Submits that overflowed their preferred replica onto a later one.
    spillovers: int = 0
    per_replica: List[int] = field(default_factory=list)
    #: Requests re-homed off a dead replica and ACCEPTED elsewhere.
    failovers: int = 0
    #: Replicas whose health reached "dead".
    replica_deaths: int = 0
    #: Watchdog deadline trips across the fleet (hung/stalled steps).
    watchdog_trips: int = 0
    #: Failover resubmission attempts (accepted or not; >= failovers).
    retries: int = 0
    #: Replicas currently under administrative drain.
    drained_replicas: int = 0


class _FleetBreaker:
    """Read-only aggregate of the replicas' breakers, shaped like one
    ``CircuitBreaker`` for ``OpenLoopReport.summary`` (opens / shed /
    state / transitions).  State is the most-degraded replica's."""

    def __init__(self, breakers: Sequence[CircuitBreaker]):
        self._breakers = list(breakers)

    @property
    def opens(self) -> int:
        return sum(b.opens for b in self._breakers)

    @property
    def shed(self) -> int:
        return sum(b.shed for b in self._breakers)

    @property
    def state(self) -> str:
        states = {b.state for b in self._breakers}
        for worst in ("open", "half_open"):
            if worst in states:
                return worst
        return "closed"

    @property
    def transitions(self) -> List[tuple]:
        return [t for b in self._breakers for t in b.transitions]


class ReplicaRouter:
    """N independent ``ServingEngine`` replicas behind one ``submit``.

    Single-controller-per-replica: each engine keeps its own scheduler
    loop, block store, and pump thread (via its ``AsyncFrontend``);
    NOTHING is shared between replicas — no pool, no stats, no PRNG
    stream — so a replica is exactly a solo engine and the fleet scales
    by copying it.  The router holds only admission-time state (the
    routing counters and round-robin cursor) on the event loop, so
    ``submit`` is safe to call from many client coroutines.

    ``engines`` may be heterogeneous (different meshes, kernels, pool
    sizes); affinity and load probes read each engine's public surface
    (``match_cached_blocks``, ``live_blocks``) without assumptions.
    ``breaker_factory`` builds one breaker PER replica (None = each
    frontend's default); sharing one breaker object across replicas
    would serialize their pump threads on it and is not supported.
    """

    def __init__(self, engines: Sequence[ServingEngine], *,
                 policy: str = "affinity", max_queue_depth: int = 64,
                 breaker_factory: Optional[Callable[[], CircuitBreaker]]
                 = None,
                 idle_sleep_s: float = 0.001,
                 health_factory: Optional[Callable[[], ReplicaHealth]]
                 = None,
                 retry_budget: int = 3):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        if policy not in ROUTER_POLICIES:
            raise ValueError(
                f"policy {policy!r} not in {ROUTER_POLICIES}")
        if retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        self.policy = policy
        self.retry_budget = retry_budget
        self.frontends = [
            AsyncFrontend(e, max_queue_depth=max_queue_depth,
                          breaker=breaker_factory() if breaker_factory
                          else None,
                          idle_sleep_s=idle_sleep_s)
            for e in engines]
        #: Per-replica health state machines, fed by each frontend's
        #: tick observer (``health_factory`` builds one per replica;
        #: None = defaults).
        self.health = [health_factory() if health_factory
                       else ReplicaHealth() for _ in engines]
        for i, fe in enumerate(self.frontends):
            fe.tick_observer = (
                lambda info, i=i: self._observe_tick(i, info))
        self.stats = RouterStats(per_replica=[0] * len(engines))
        self._rr = 0
        #: Replicas declared dead whose failover has not run yet (a live
        #: event loop drains this via a task; manually-stepped tests call
        #: ``fail_over_dead()`` themselves).
        self._dead_pending: List[int] = []
        self._failover_tasks: List[asyncio.Task] = []
        #: Wall seconds from death detection to the failed-over
        #: request's first replacement token (the failover TTFT the
        #: bench's p99 delta prices).
        self.failover_ttft_s: List[float] = []

    @property
    def engines(self) -> List[ServingEngine]:
        return [fe.engine for fe in self.frontends]

    @property
    def breaker(self) -> _FleetBreaker:
        """Aggregate breaker view (``OpenLoopReport.summary`` reads it)."""
        return _FleetBreaker([fe.breaker for fe in self.frontends])

    @property
    def queue_depth(self) -> int:
        return sum(fe.queue_depth for fe in self.frontends)

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> "ReplicaRouter":
        for fe in self.frontends:
            await fe.start()
        return self

    async def stop(self, drain: bool = True) -> None:
        for fe in self.frontends:
            await fe.stop(drain=drain)

    async def __aenter__(self) -> "ReplicaRouter":
        return await self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        await self.stop(drain=exc_type is None)

    async def aclose(self) -> None:
        """Leak-proof teardown: finish pending failovers, cancel every
        in-flight stream on EVERY replica (each cancel releases its KV
        blocks), stop all pumps, and assert the fleet holds zero live
        blocks.  Use instead of ``stop()`` when streams may still be
        open — the solo-frontend cancel path only covers one engine;
        this is the fleet-wide version (and the teardown the chaos tests
        drive)."""
        for task in list(self._failover_tasks):
            if not task.done():
                await task
        if self._dead_pending:  # manually-stepped sessions
            await self.fail_over_dead()
        for fe in self.frontends:
            for t in list(fe._inflight.values()):
                fe._cancel_ticket(t)
            if fe._pump_task is None and not fe._stopped:
                # Never-started frontend (manually-stepped tests): no
                # pump will apply the cancels — flush them inline.
                for _ in range(200):
                    if not fe._has_engine_work():
                        break
                    fe._dispatch(fe._tick())
        await self.stop(drain=True)
        leaked = {
            i: fe.engine.live_blocks
            for i, fe in enumerate(self.frontends)
            if not getattr(fe.engine, "poisoned", False)
            and fe.engine.live_blocks > 0}
        assert not leaked, (
            f"router teardown leaked live KV blocks: {leaked}")

    # -- placement -----------------------------------------------------------
    def _load(self, i: int) -> int:
        """Least-loaded fallback signal: device blocks the replica's
        in-flight requests hold plus requests it has accepted but not
        finished (covers queued work not yet admitted to a lane)."""
        return self.frontends[i].engine.live_blocks \
            + self.frontends[i].queue_depth

    def _order(self, prompt, patch_embeds) -> List[int]:
        """Placeable replica indices in preference order for one submit
        (dead/draining replicas excluded; suspect ones only while they
        have a free probe slot — may be empty if the whole fleet is
        down)."""
        n = len(self.frontends)
        if self.policy == "round_robin":
            order = [(self._rr + k) % n for k in range(n)]
            self._rr = (self._rr + 1) % n
            return [i for i in order if self.health[i].can_place()]
        cand = [i for i in range(n) if self.health[i].can_place()]
        if not cand:
            return []
        matches = {i: self.frontends[i].engine.match_cached_blocks(
            prompt, patch_embeds=patch_embeds) for i in cand}
        if any(matches.values()):
            self.stats.affinity_eligible += 1
        order = sorted(cand,
                       key=lambda i: (-matches[i], self._load(i), i))
        if matches[order[0]] > 0:
            self.stats.affinity_hits += 1
        return order

    # -- submission ----------------------------------------------------------
    async def submit(self, prompt, max_new_tokens: int = 32, *,
                     deadline: Optional[float] = None, priority: int = 0,
                     patch_embeds: Optional[np.ndarray] = None,
                     timeout_s: Optional[float] = None) -> TokenStream:
        """Route one request to a replica; returns its ``TokenStream``.

        Tries PLACEABLE replicas (healthy, plus suspect ones with a free
        probe slot; never dead or draining) in preference order; raises
        ``RejectedError`` only when every one rejected (``kind="breaker"``
        iff ALL were breaker sheds — the whole fleet is saturated) or no
        replica accepts placements at all."""
        order = self._order(prompt, patch_embeds)
        if not order:
            self.stats.rejected += 1
            raise RejectedError(
                f"no replica accepts placements (health: "
                f"{[h.state + ('/draining' if h.draining else '') for h in self.health]})",
                kind="breaker")
        kinds = []
        for k, i in enumerate(order):
            try:
                stream = await self.frontends[i].submit(
                    prompt, max_new_tokens=max_new_tokens,
                    deadline=deadline, priority=priority,
                    patch_embeds=patch_embeds, timeout_s=timeout_s)
            except RejectedError as e:
                kinds.append(e.kind)
                continue
            if self.health[i].note_placed():
                # A suspect replica's placement doubles as its revival
                # probe: completion judges the replica, not just the
                # request.
                stream._ticket.on_done = self.health[i].record_probe_end
            self.stats.submitted += 1
            self.stats.per_replica[i] += 1
            if k > 0:
                self.stats.spillovers += 1
            return stream
        self.stats.rejected += 1
        kind = "breaker" if kinds and all(k == "breaker" for k in kinds) \
            else "backpressure"
        raise RejectedError(
            f"all {len(order)} placeable replicas rejected "
            f"({', '.join(kinds)})",
            kind=kind)

    # -- health + failover ---------------------------------------------------
    def _observe_tick(self, i: int, info: dict) -> None:
        """Per-tick health tap (installed as each frontend's
        ``tick_observer``; runs on the event loop, or inline under
        manually-stepped tests)."""
        event = self.health[i].record_step(
            error=info.get("error"),
            cost_ticks=info.get("cost_ticks", 1))
        if event == "watchdog":
            self.stats.watchdog_trips += 1
        elif event == "died":
            self.stats.replica_deaths += 1
            self._dead_pending.append(i)
            try:
                loop = asyncio.get_running_loop()
            except RuntimeError:
                return  # manual stepping: caller runs fail_over_dead()
            self._failover_tasks.append(
                loop.create_task(self.fail_over_dead()))

    def drain(self, i: int) -> None:
        """Administrative drain: replica ``i`` takes no NEW placements;
        its in-flight lanes run to completion.  Idempotent."""
        h = self.health[i]
        if not h.draining:
            h.draining = True
            self.stats.drained_replicas += 1

    def undrain(self, i: int) -> None:
        """Reopen a drained replica for placements."""
        h = self.health[i]
        if h.draining:
            h.draining = False
            self.stats.drained_replicas -= 1

    async def fail_over_dead(self) -> int:
        """Fail over every replica currently pending death handling;
        returns the number of requests re-homed.  Idempotent — safe to
        call when nothing is pending (manually-stepped tests call it
        after ticking; live pumps schedule it automatically)."""
        moved = 0
        while self._dead_pending:
            moved += await self._fail_over(self._dead_pending.pop(0))
        return moved

    async def _fail_over(self, i: int) -> int:
        """Re-home every in-flight request of dead replica ``i``.

        Order matters: detach the tickets FIRST (streams stay open),
        halt the pump, release the dead engine's blocks (its scheduler
        state is intact unless poisoned — injected crashes fire at the
        step boundary), then resubmit each request as prompt + emitted
        tokens.  The resubmission is the engine's preemption-recompute
        contract: positional PRNG keys replay identically, the clamped
        budget arithmetic matches ``_remaining_budget``, and the emitted
        prefix is never re-streamed — so a completed request's output is
        bit-identical to a failure-free run."""
        fe = self.frontends[i]
        victims = fe.take_inflight()
        await fe.halt()
        eng = fe.engine
        for t in victims:
            if t.uid is not None:
                try:
                    eng.cancel(t.uid)
                except Exception:
                    pass  # poisoned store: blocks are unrecoverable
        t0 = time.perf_counter()
        moved = 0
        for t in victims:
            moved += await self._resubmit(fe, t, t0)
        return moved

    async def _resubmit(self, fe: AsyncFrontend, t, t0: float) -> int:
        """Resubmit one detached ticket elsewhere; returns 1 if it was
        accepted by a healthy replica."""
        emitted = list(t.emitted)
        clamp = min(t.max_new_tokens,
                    fe.engine.max_len - len(t.prompt))
        rem = clamp - len(emitted)
        eos = fe.engine.eos_id
        if rem <= 0 or (emitted and emitted[-1] == eos):
            # Already at budget (or past EOS): only the finish event died
            # with the replica — the stream is complete as emitted.
            t.done, t.result = True, emitted
            t.queue.put_nowait(_DONE)
            return 0
        if t.retries >= self.retry_budget:
            t.done = True
            t.queue.put_nowait(RejectedError(
                f"failover retry budget ({self.retry_budget}) exhausted",
                kind="timeout"))
            return 0
        self.stats.retries += 1
        prompt2 = np.concatenate(
            [np.asarray(t.prompt, np.int32),
             np.asarray(emitted, np.int32)]) if emitted else t.prompt
        try:
            stream2 = await self.submit(
                prompt2, max_new_tokens=rem, deadline=t.deadline,
                patch_embeds=t.patch_embeds)
        except RejectedError as e:
            t.done = True
            t.queue.put_nowait(e)
            return 0
        t2 = stream2._ticket
        # Seamless continuation: the replacement's tokens land straight
        # in the client's queue; cancel/uid/done resolve through the
        # successor chain (TokenStream._live).  No awaits separate the
        # submit from the alias, so no token can slip into t2's original
        # queue first.
        t2.queue = t.queue
        t2.retries = t.retries + 1
        t2.timeout_s, t2.expires_at = t.timeout_s, t.expires_at
        t2.on_first_token = (
            lambda: self.failover_ttft_s.append(time.perf_counter() - t0))
        t.successor = (stream2._fe, t2)
        self.stats.failovers += 1
        return 1

    # -- reporting -----------------------------------------------------------
    def routing_report(self) -> Dict[str, object]:
        """Routing + aggregate engine-side outcomes for the bench."""
        s = self.stats
        engines = self.engines
        cached = sum(e.stats.cached_prompt_tokens for e in engines)
        prefill = sum(e.stats.prefill_tokens for e in engines)
        return {
            "policy": self.policy,
            "replicas": len(engines),
            "submitted": s.submitted,
            "rejected": s.rejected,
            "spillovers": s.spillovers,
            "per_replica_requests": list(s.per_replica),
            "affinity_hit_rate": (s.affinity_hits
                                  / max(s.affinity_eligible, 1)),
            "prefix_hit_rate": cached / max(cached + prefill, 1),
            "generated_tokens": sum(e.stats.generated_tokens
                                    for e in engines),
            "health": [h.state for h in self.health],
        }

    def fault_report(self) -> Dict[str, object]:
        """Fleet fault-tolerance outcomes — ``OpenLoopReport.summary``
        embeds this as its ``fault_tolerance`` block (and the bench's
        section 9 commits it behind the schema gate)."""
        s = self.stats
        pct = EngineStats.percentile
        return {
            "replica_deaths": s.replica_deaths,
            "failovers": s.failovers,
            "retries": s.retries,
            "watchdog_trips": s.watchdog_trips,
            "drained_replicas": s.drained_replicas,
            "health": [h.state for h in self.health],
            "failover_p50_ttft_s": pct(self.failover_ttft_s, 50.0),
            "failover_p99_ttft_s": pct(self.failover_ttft_s, 99.0),
        }


def run_open_loop_router(engines: Sequence[ServingEngine],
                         trace, *, policy: str = "affinity",
                         max_queue_depth: int = 64,
                         breaker_factory: Optional[
                             Callable[[], CircuitBreaker]] = None,
                         idle_sleep_s: float = 0.001,
                         health_factory: Optional[
                             Callable[[], ReplicaHealth]] = None,
                         retry_budget: int = 3,
                         drain: Sequence[int] = ()):
    """Drive an open-loop trace through a fresh router over ``engines``;
    returns ``(OpenLoopReport, ReplicaRouter)``.  The report's
    ``summary()`` works as-is (the router quacks enough like a frontend —
    it has a ``breaker`` and a ``fault_report``); routing detail comes
    from ``router.routing_report()``.  ``engines`` may be
    ``FaultyEngine``-wrapped (``serving.faults``) for chaos runs —
    failover then keeps completed streams bit-identical to a clean
    run.  Replica indices in ``drain`` start administratively drained
    (no placements; the launcher's ``--drain-replica``)."""
    from repro.serving.openloop import OpenLoopReport, drive

    router = ReplicaRouter(engines, policy=policy,
                           max_queue_depth=max_queue_depth,
                           breaker_factory=breaker_factory,
                           idle_sleep_s=idle_sleep_s,
                           health_factory=health_factory,
                           retry_budget=retry_budget)
    for i in drain:
        router.drain(i)

    async def main():
        await router.start()
        try:
            return await drive(router, trace)
        finally:
            await router.aclose()

    t0 = time.perf_counter()
    records = asyncio.run(main())
    report = OpenLoopReport(records=records,
                            wall_s=time.perf_counter() - t0,
                            frontend=router)
    return report, router
