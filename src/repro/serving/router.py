"""Prefix-affinity replica router: N serving engines, one admission surface.

Rung 2 of the scale ladder (the paper's §4 cloud posture): tensor
parallelism widens ONE engine over a mesh (``ServingEngine(mesh=...)``);
past that, throughput comes from REPLICAS — independent engines, each its
own single controller (own scheduler, own ``BlockStore``, own KV pool,
own breaker), coordinated only at admission time.  ``ReplicaRouter``
fronts N ``AsyncFrontend``-wrapped engines with exactly the client API of
one frontend (``submit`` -> ``TokenStream``), so the open-loop driver and
any other client code run against a fleet unchanged.

Placement is the router's whole job, and prefix caching makes it
non-trivial: a replica that already holds a request's leading blocks
serves it with most of its prefill skipped, but ONLY that replica —
block pools do not gossip.  Policies:

  * ``"affinity"`` (default) — probe every replica's prefix cache with
    ``engine.match_cached_blocks`` (the SAME hash chain admission uses:
    vlm patch sentinels, per-request chain seed, kv_dtype-namespaced
    root, so a hit here is a hit at admission) and route to the deepest
    match; ties — including the no-match common case — fall back to
    least-loaded by ``live blocks + frontend queue depth``.  Result:
    shared-system-prompt traffic converges onto warm replicas (aggregate
    prefix hit-rate approaches the single-engine rate) while cold
    traffic spreads by load.
  * ``"round_robin"`` — rotate submissions; the affinity-blind baseline
    the bench compares against (shared prefixes get re-prefetched on
    every replica they land on).

Admission folds per-replica backpressure/breaker state into ONE
client-facing surface: a submit tries replicas in preference order and
only raises ``RejectedError`` when EVERY replica rejected — with
``kind="breaker"`` only when all of them were shedding (the fleet is
saturated), else ``kind="backpressure"`` (retry with backoff; some queue
was merely full).  A single overloaded replica therefore sheds onto its
peers before the client ever sees a 503.

Correctness contract: the router never touches tokens — per-request
streams are bit-identical to the same prompt on a solo engine (greedy
sampling; stochastic streams are keyed by per-engine uids and so depend
on placement by construction).  Pinned in tests/test_router.py.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import ServingEngine
from repro.serving.frontend import (AsyncFrontend, CircuitBreaker,
                                    RejectedError, TokenStream)

ROUTER_POLICIES = ("affinity", "round_robin")


@dataclass
class RouterStats:
    """Admission-time routing outcomes (token accounting lives in each
    engine's own ``EngineStats``)."""
    submitted: int = 0
    rejected: int = 0
    #: Submits whose chosen replica already held >= 1 block of the prompt
    #: (over submits where ANY replica did — the router's hit-RATE is
    #: affinity_hits / affinity_eligible).
    affinity_hits: int = 0
    affinity_eligible: int = 0
    #: Submits that overflowed their preferred replica onto a later one.
    spillovers: int = 0
    per_replica: List[int] = field(default_factory=list)


class _FleetBreaker:
    """Read-only aggregate of the replicas' breakers, shaped like one
    ``CircuitBreaker`` for ``OpenLoopReport.summary`` (opens / shed /
    state / transitions).  State is the most-degraded replica's."""

    def __init__(self, breakers: Sequence[CircuitBreaker]):
        self._breakers = list(breakers)

    @property
    def opens(self) -> int:
        return sum(b.opens for b in self._breakers)

    @property
    def shed(self) -> int:
        return sum(b.shed for b in self._breakers)

    @property
    def state(self) -> str:
        states = {b.state for b in self._breakers}
        for worst in ("open", "half_open"):
            if worst in states:
                return worst
        return "closed"

    @property
    def transitions(self) -> List[tuple]:
        return [t for b in self._breakers for t in b.transitions]


class ReplicaRouter:
    """N independent ``ServingEngine`` replicas behind one ``submit``.

    Single-controller-per-replica: each engine keeps its own scheduler
    loop, block store, and pump thread (via its ``AsyncFrontend``);
    NOTHING is shared between replicas — no pool, no stats, no PRNG
    stream — so a replica is exactly a solo engine and the fleet scales
    by copying it.  The router holds only admission-time state (the
    routing counters and round-robin cursor) on the event loop, so
    ``submit`` is safe to call from many client coroutines.

    ``engines`` may be heterogeneous (different meshes, kernels, pool
    sizes); affinity and load probes read each engine's public surface
    (``match_cached_blocks``, ``live_blocks``) without assumptions.
    ``breaker_factory`` builds one breaker PER replica (None = each
    frontend's default); sharing one breaker object across replicas
    would serialize their pump threads on it and is not supported.
    """

    def __init__(self, engines: Sequence[ServingEngine], *,
                 policy: str = "affinity", max_queue_depth: int = 64,
                 breaker_factory: Optional[Callable[[], CircuitBreaker]]
                 = None,
                 idle_sleep_s: float = 0.001):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        if policy not in ROUTER_POLICIES:
            raise ValueError(
                f"policy {policy!r} not in {ROUTER_POLICIES}")
        self.policy = policy
        self.frontends = [
            AsyncFrontend(e, max_queue_depth=max_queue_depth,
                          breaker=breaker_factory() if breaker_factory
                          else None,
                          idle_sleep_s=idle_sleep_s)
            for e in engines]
        self.stats = RouterStats(per_replica=[0] * len(engines))
        self._rr = 0

    @property
    def engines(self) -> List[ServingEngine]:
        return [fe.engine for fe in self.frontends]

    @property
    def breaker(self) -> _FleetBreaker:
        """Aggregate breaker view (``OpenLoopReport.summary`` reads it)."""
        return _FleetBreaker([fe.breaker for fe in self.frontends])

    @property
    def queue_depth(self) -> int:
        return sum(fe.queue_depth for fe in self.frontends)

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> "ReplicaRouter":
        for fe in self.frontends:
            await fe.start()
        return self

    async def stop(self, drain: bool = True) -> None:
        for fe in self.frontends:
            await fe.stop(drain=drain)

    async def __aenter__(self) -> "ReplicaRouter":
        return await self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        await self.stop(drain=exc_type is None)

    # -- placement -----------------------------------------------------------
    def _load(self, i: int) -> int:
        """Least-loaded fallback signal: device blocks the replica's
        in-flight requests hold plus requests it has accepted but not
        finished (covers queued work not yet admitted to a lane)."""
        return self.frontends[i].engine.live_blocks \
            + self.frontends[i].queue_depth

    def _order(self, prompt, patch_embeds) -> List[int]:
        """Replica indices in preference order for one submit."""
        n = len(self.frontends)
        if self.policy == "round_robin":
            order = [(self._rr + k) % n for k in range(n)]
            self._rr = (self._rr + 1) % n
            return order
        matches = [fe.engine.match_cached_blocks(prompt,
                                                 patch_embeds=patch_embeds)
                   for fe in self.frontends]
        if any(matches):
            self.stats.affinity_eligible += 1
        order = sorted(range(n),
                       key=lambda i: (-matches[i], self._load(i), i))
        if matches[order[0]] > 0:
            self.stats.affinity_hits += 1
        return order

    # -- submission ----------------------------------------------------------
    async def submit(self, prompt, max_new_tokens: int = 32, *,
                     deadline: Optional[float] = None, priority: int = 0,
                     patch_embeds: Optional[np.ndarray] = None
                     ) -> TokenStream:
        """Route one request to a replica; returns its ``TokenStream``.

        Tries replicas in preference order; raises ``RejectedError`` only
        when every replica rejected (``kind="breaker"`` iff ALL were
        breaker sheds — the whole fleet is saturated)."""
        order = self._order(prompt, patch_embeds)
        kinds = []
        for k, i in enumerate(order):
            try:
                stream = await self.frontends[i].submit(
                    prompt, max_new_tokens=max_new_tokens,
                    deadline=deadline, priority=priority,
                    patch_embeds=patch_embeds)
            except RejectedError as e:
                kinds.append(e.kind)
                continue
            self.stats.submitted += 1
            self.stats.per_replica[i] += 1
            if k > 0:
                self.stats.spillovers += 1
            return stream
        self.stats.rejected += 1
        kind = "breaker" if kinds and all(k == "breaker" for k in kinds) \
            else "backpressure"
        raise RejectedError(
            f"all {len(order)} replicas rejected ({', '.join(kinds)})",
            kind=kind)

    # -- reporting -----------------------------------------------------------
    def routing_report(self) -> Dict[str, object]:
        """Routing + aggregate engine-side outcomes for the bench."""
        s = self.stats
        engines = self.engines
        cached = sum(e.stats.cached_prompt_tokens for e in engines)
        prefill = sum(e.stats.prefill_tokens for e in engines)
        return {
            "policy": self.policy,
            "replicas": len(engines),
            "submitted": s.submitted,
            "rejected": s.rejected,
            "spillovers": s.spillovers,
            "per_replica_requests": list(s.per_replica),
            "affinity_hit_rate": (s.affinity_hits
                                  / max(s.affinity_eligible, 1)),
            "prefix_hit_rate": cached / max(cached + prefill, 1),
            "generated_tokens": sum(e.stats.generated_tokens
                                    for e in engines),
        }


def run_open_loop_router(engines: Sequence[ServingEngine],
                         trace, *, policy: str = "affinity",
                         max_queue_depth: int = 64,
                         breaker_factory: Optional[
                             Callable[[], CircuitBreaker]] = None,
                         idle_sleep_s: float = 0.001):
    """Drive an open-loop trace through a fresh router over ``engines``;
    returns ``(OpenLoopReport, ReplicaRouter)``.  The report's
    ``summary()`` works as-is (the router quacks enough like a frontend —
    it has a ``breaker``); routing detail comes from
    ``router.routing_report()``."""
    import time

    from repro.serving.openloop import OpenLoopReport, drive

    router = ReplicaRouter(engines, policy=policy,
                           max_queue_depth=max_queue_depth,
                           breaker_factory=breaker_factory,
                           idle_sleep_s=idle_sleep_s)

    async def main():
        await router.start()
        try:
            return await drive(router, trace)
        finally:
            await router.stop(drain=True)

    t0 = time.perf_counter()
    records = asyncio.run(main())
    report = OpenLoopReport(records=records,
                            wall_s=time.perf_counter() - t0,
                            frontend=router)
    return report, router
