"""Async streaming frontend with admission control over ``ServingEngine``.

The engine is a fast in-process loop (``submit``/``step``); a cloud-scale
service (the paper's premise: thousands of replicated modules absorbing
live traffic under a TCO/token objective) additionally needs the network-
facing layer — streaming responses, an arrival process that does not wait
for the scheduler, and an OVERLOAD story.  ``AsyncFrontend`` is that
layer:

  * **streaming**: ``await frontend.submit(prompt, ...)`` returns a
    ``TokenStream`` — an async iterator yielding the request's tokens as
    the engine emits them (the engine's ``on_token`` hook feeds a
    per-request ``asyncio.Queue``).  Closing the stream mid-flight
    (``aclose``) cancels the request and releases its KV blocks.
  * **one pump, off the event loop**: a single background task drives
    ``engine.step()`` through a one-worker ``run_in_executor`` — the
    event loop never blocks on a jitted step, and because the pump awaits
    each tick before the next, ALL engine access is serialized on that
    worker thread (the engine itself is not thread-safe).
  * **deadlines / priorities**: ``submit(deadline=, priority=)`` maps
    onto the engine's ``preempt_policy="deadline"`` total order — an
    explicit deadline is passed through; a bare ``priority > 0`` becomes
    the synthetic deadline ``-priority`` (earlier than any real,
    non-negative deadline, so prioritized traffic is preempted last);
    neither means ``deadline=None`` (best-effort: first evicted).  Only
    ORDER matters, and only when the engine runs the "deadline" policy.
  * **backpressure**: at most ``max_queue_depth`` requests may be in
    flight (accepted but not finished); ``submit`` beyond it raises
    ``RejectedError(kind="backpressure")`` — the 503 the caller retries
    with backoff instead of queueing unboundedly.
  * **timeouts + fault surface**: ``submit(timeout_s=...)`` bounds a
    request's wall-clock life — expiry cancels it (blocks released) and
    its stream raises ``RejectedError(kind="timeout")``.  A raising
    ``engine.step()`` no longer kills the pump: the error is counted
    (``stats.step_errors``), reported to ``tick_observer`` (the replica
    router's per-replica health tap — see ``serving.router``), and after
    ``max_step_errors`` CONSECUTIVE failures a solo frontend declares
    the engine dead and fails its in-flight streams; under a router the
    health tracker reacts first and fails the requests over instead.
  * **load shedding**: a closed/open/half-open ``CircuitBreaker`` watches
    every scheduler tick's preemption delta and pool saturation.  Too
    much pressure inside a sliding window trips it OPEN — submits raise
    ``RejectedError(kind="breaker")`` (cheap, instant) while in-flight
    work drains.  After a cooldown (measured in scheduler ticks, so a
    draining engine runs its own clock) it goes HALF-OPEN and admits up
    to ``probes`` probe requests: a probe finishing cleanly closes the
    breaker, pressure while probing reopens it.  This is what turns
    saturation into bounded tail latency instead of collapse.

Correctness contract (tests/test_frontend.py): streamed tokens are
bit-identical to the same trace through the in-process ``engine.run()``
path — the frontend adds admission control, never arithmetic.

Typical use::

    engine = ServingEngine(cfg, params, preempt_policy="deadline")
    async with AsyncFrontend(engine, max_queue_depth=32) as fe:
        stream = await fe.submit(prompt, max_new_tokens=64, priority=1)
        async for tok in stream:
            ...  # deliver incrementally
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import ServingEngine

#: Circuit-breaker states (classic closed/open/half-open admission).
BREAKER_STATES = ("closed", "open", "half_open")

#: Stream terminator sentinel (private to this module).
_DONE = object()


class RejectedError(RuntimeError):
    """503-style admission rejection.

    ``kind`` is "backpressure" (queue depth at ``max_queue_depth`` —
    retry with backoff), "breaker" (circuit breaker shedding load —
    back off harder; the service is saturated) or "timeout" (the request
    exceeded its per-request wall-clock budget, or its failover retry
    budget after replica deaths — raised from the STREAM, not from
    ``submit``, since the request was admitted before it expired)."""

    def __init__(self, reason: str, kind: str):
        super().__init__(reason)
        self.kind = kind


class CircuitBreaker:
    """Closed/open/half-open admission gate driven by scheduler ticks.

    The pump reports every tick via ``record_tick(preemptions,
    saturation)``; a tick is a PRESSURE tick when it preempted at least
    one request or the pool's live-block saturation reached
    ``sat_threshold``.  ``trip_pressure`` pressure ticks inside the last
    ``window`` ticks trip the breaker open; ``cooldown_ticks`` ticks
    later it half-opens and admits up to ``probes`` probe requests —
    ``probes`` clean completions close it, any pressure (or a failed
    probe) reopens it.  All counting is in ticks, not wall time, so
    tests can script the walk deterministically and a draining engine
    advances its own cooldown."""

    def __init__(self, window: int = 16, trip_pressure: int = 4,
                 sat_threshold: float = 1.0, cooldown_ticks: int = 8,
                 probes: int = 1):
        if window < 1 or trip_pressure < 1 or cooldown_ticks < 1 \
                or probes < 1:
            raise ValueError("breaker knobs must all be >= 1")
        if trip_pressure > window:
            raise ValueError(
                f"trip_pressure {trip_pressure} can never fire inside a "
                f"{window}-tick window")
        self.window = window
        self.trip_pressure = trip_pressure
        self.sat_threshold = sat_threshold
        self.cooldown_ticks = cooldown_ticks
        self.probes = probes
        self.state = "closed"
        self._pressure: deque = deque(maxlen=window)
        self._cooldown = 0
        self._probe_live = 0
        self._probe_ok = 0
        #: Every state change, in order, as (from, to) — the scripted
        #: overload test asserts the full closed->open->half_open->closed
        #: walk on this.
        self.transitions: List[Tuple[str, str]] = []
        self.opens = 0
        self.shed = 0

    def allow(self) -> Tuple[bool, bool]:
        """Admission decision for one submit: (admit, is_probe)."""
        if self.state == "closed":
            return True, False
        if self.state == "half_open" and self._probe_live < self.probes:
            self._probe_live += 1
            return True, True
        self.shed += 1
        return False, False

    def record_tick(self, preemptions: int, saturation: float) -> None:
        """One scheduler tick's pressure signal (pump-thread only)."""
        pressure = preemptions > 0 or saturation >= self.sat_threshold
        if self.state == "closed":
            self._pressure.append(pressure)
            if sum(self._pressure) >= self.trip_pressure:
                self._to("open")
        elif self.state == "open":
            self._cooldown -= 1
            if self._cooldown <= 0:
                self._to("half_open")
        else:  # half_open: any pressure while probing reopens
            if pressure:
                self._to("open")

    def record_probe_end(self, ok: bool) -> None:
        """A probe request finished (cleanly or not)."""
        if self.state != "half_open":
            return  # breaker moved on while the probe was in flight
        self._probe_live = max(0, self._probe_live - 1)
        if not ok:
            self._to("open")
            return
        self._probe_ok += 1
        if self._probe_ok >= self.probes:
            self._to("closed")

    def abandon_probe(self) -> None:
        """A probe was cancelled before finishing: free its slot without
        judging the service healthy or sick."""
        if self.state == "half_open":
            self._probe_live = max(0, self._probe_live - 1)

    def _to(self, state: str) -> None:
        self.transitions.append((self.state, state))
        self.state = state
        if state == "open":
            self.opens += 1
            self._cooldown = self.cooldown_ticks
            self._probe_live = self._probe_ok = 0
        elif state == "half_open":
            self._probe_live = self._probe_ok = 0
        else:  # closed: forget the bad window
            self._pressure.clear()


@dataclass
class FrontendStats:
    accepted: int = 0
    completed: int = 0
    cancelled: int = 0
    errors: int = 0  # engine-side submit validation failures
    rejected_backpressure: int = 0
    shed_breaker: int = 0
    #: Requests ended by their per-request wall-clock timeout (their
    #: streams raised RejectedError(kind="timeout")).
    timeouts: int = 0
    #: Scheduler ticks whose engine.step() raised (the crash-detection
    #: signal the replica router's health tracker consumes).
    step_errors: int = 0


@dataclass
class _Ticket:
    """One accepted request's frontend-side state."""
    id: int
    prompt: np.ndarray
    max_new_tokens: int
    deadline: Optional[float]
    patch_embeds: Optional[np.ndarray]
    queue: asyncio.Queue
    probe: bool = False
    uid: Optional[int] = None  # engine uid, assigned by the pump
    cancelled: bool = False
    done: bool = False
    #: The engine's final token list (completed requests only) — must
    #: equal exactly what was streamed; the no-token-loss property tests
    #: pin on it.
    result: Optional[List[int]] = None
    #: Tokens DELIVERED to the stream's queue so far (consumed by the
    #: client or not).  Failover resubmits prompt + emitted, so exactly
    #: these tokens are never generated — or streamed — twice.
    emitted: List[int] = field(default_factory=list)
    #: Wall-clock budget: the request times out ``timeout_s`` seconds
    #: after submit (checked each dispatch against ``expires_at``).
    timeout_s: Optional[float] = None
    expires_at: Optional[float] = None  # time.monotonic() deadline
    #: Failover retry count (router-owned): how many times this request
    #: has been re-homed after a replica death.
    retries: int = 0
    #: Set when the request was failed over: (frontend, ticket) of the
    #: live incarnation.  Its queue is ALIASED to this ticket's queue, so
    #: the client's stream continues seamlessly; cancel/done resolve
    #: through the chain (``TokenStream._live``).
    successor: Optional[tuple] = None
    #: Completion tap (router health probes, failover latency): called
    #: with True (completed), False (errored) or None (cancelled/timed
    #: out) exactly once, on the event loop.
    on_done: Optional[Callable[[Optional[bool]], None]] = None
    #: One-shot tap fired when the ticket's FIRST token is dispatched
    #: (failover latency measurement).
    on_first_token: Optional[Callable[[], None]] = None


class TokenStream:
    """Async iterator over one request's generated tokens.

    ``aclose()`` cancels the request if it is still in flight (its KV
    blocks are released at the next scheduler tick); ``collect()`` drains
    to completion and returns the full token list.  ``tokens`` holds
    everything yielded so far."""

    def __init__(self, frontend: "AsyncFrontend", ticket: _Ticket):
        self._fe = frontend
        self._ticket = ticket
        self._exhausted = False
        self.tokens: List[int] = []

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        if self._exhausted:
            raise StopAsyncIteration
        item = await self._ticket.queue.get()
        if item is _DONE:
            self._exhausted = True
            raise StopAsyncIteration
        if isinstance(item, BaseException):
            self._exhausted = True
            raise item
        self.tokens.append(item)
        return item

    async def collect(self) -> List[int]:
        async for _ in self:
            pass
        return self.tokens

    def _live(self) -> Tuple["AsyncFrontend", _Ticket]:
        """The request's live incarnation: failover re-homes a request
        onto another frontend's ticket (queue aliased back to ours), so
        cancel/uid/done must resolve through the successor chain."""
        fe, t = self._fe, self._ticket
        while t.successor is not None:
            fe, t = t.successor
        return fe, t

    async def aclose(self) -> None:
        fe, t = self._live()
        fe._cancel_ticket(t)

    @property
    def uid(self) -> Optional[int]:
        """Engine uid of the LIVE incarnation (None until its pump has
        submitted the request; changes if the request is failed over)."""
        return self._live()[1].uid

    @property
    def done(self) -> bool:
        return self._live()[1].done


class AsyncFrontend:
    """Asyncio serving layer over a continuous-batching ``ServingEngine``
    (module docstring has the full story).

    The frontend may be constructed and submitted to before ``start()``;
    streams only make progress once the pump runs.  Use as an async
    context manager, or pair ``start()`` with ``stop()``.
    """

    def __init__(self, engine: ServingEngine, max_queue_depth: int = 64,
                 breaker: Optional[CircuitBreaker] = None,
                 idle_sleep_s: float = 0.001,
                 max_step_errors: int = 8):
        if engine.mode != "continuous":
            raise ValueError(
                f"AsyncFrontend requires a continuous-mode engine (got "
                f"mode={engine.mode!r}); wave batching has no step() to "
                f"pump")
        if max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1")
        if max_step_errors < 1:
            raise ValueError("max_step_errors must be >= 1")
        self.engine = engine
        self.max_queue_depth = max_queue_depth
        self.breaker = breaker or CircuitBreaker()
        self.idle_sleep_s = idle_sleep_s
        #: Consecutive erroring ticks after which a SOLO frontend gives
        #: the engine up for dead and fails its in-flight streams (a
        #: router-managed frontend never reaches this: the router's
        #: health tracker declares death first and takes the tickets for
        #: failover).
        self.max_step_errors = max_step_errors
        self.stats = FrontendStats()
        #: Per-tick observer (the replica router's health tap): called
        #: once per pump tick, on the event loop, with
        #: ``{"error": exc-or-None, "cost_ticks": int}`` — the step's
        #: outcome and its virtual duration (``engine.last_step_cost``
        #: when present, e.g. under fault injection; else 1).
        self.tick_observer: Optional[Callable[[dict], None]] = None
        self._last_tick_info: Optional[dict] = None
        self.last_step_error: Optional[BaseException] = None
        self._consec_step_errors = 0
        self._engine_dead = False
        self._halt = False
        self._tickets = 0
        #: ticket id -> ticket, accepted and not yet finished/cancelled —
        #: len() of this is the backpressure queue depth.
        self._inflight: Dict[int, _Ticket] = {}
        self._by_uid: Dict[int, _Ticket] = {}
        self._pending: List[_Ticket] = []   # accepted, not yet in engine
        self._cancels: List[_Ticket] = []   # cancel commands for the pump
        #: ("tok", uid, token) / ("err", ticket, exc) events produced on
        #: the pump thread, dispatched to queues on the event loop.
        self._events: List[tuple] = []
        self._wake = asyncio.Event()
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="engine-pump")
        self._pump_task: Optional[asyncio.Task] = None
        self._running = True
        self._stopped = False

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> "AsyncFrontend":
        if self._pump_task is not None:
            raise RuntimeError("frontend already started")
        if self._stopped:
            raise RuntimeError("frontend already stopped")
        self.engine.on_token = self._on_token
        self._pump_task = asyncio.create_task(self._pump())
        return self

    async def stop(self, drain: bool = True) -> None:
        """Shut the pump down.  ``drain=True`` finishes all in-flight
        requests first; ``drain=False`` cancels them (their streams end
        where they are, their blocks are released)."""
        if self._stopped:
            return
        if not drain:
            for t in list(self._inflight.values()):
                self._cancel_ticket(t)
        self._running = False
        self._wake.set()
        if self._pump_task is not None:
            await self._pump_task
        self._executor.shutdown(wait=True)
        self.engine.on_token = None
        self._stopped = True

    async def __aenter__(self) -> "AsyncFrontend":
        return await self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        await self.stop(drain=exc_type is None)

    # -- submission ----------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        """Requests accepted and not yet finished or cancelled."""
        return len(self._inflight)

    async def submit(self, prompt, max_new_tokens: int = 32, *,
                     deadline: Optional[float] = None, priority: int = 0,
                     patch_embeds: Optional[np.ndarray] = None,
                     timeout_s: Optional[float] = None) -> TokenStream:
        """Admit one request and return its token stream.

        Raises ``RejectedError`` when the in-flight window is full
        (``kind="backpressure"``) or the circuit breaker is shedding
        (``kind="breaker"``).  Engine-side validation failures (prompt
        too long for the cache, bad patch shape, ...) surface as the
        original ``ValueError`` out of the stream's first ``__anext__``.

        ``timeout_s`` is a per-request WALL-CLOCK budget: if the request
        has not completed ``timeout_s`` seconds after this call, it is
        cancelled (blocks released) and its stream raises
        ``RejectedError(kind="timeout")``.
        """
        if self._stopped or not self._running:
            raise RuntimeError("frontend is stopped")
        if timeout_s is not None and timeout_s <= 0.0:
            raise ValueError(f"timeout_s must be > 0, got {timeout_s}")
        depth = len(self._inflight)
        if depth >= self.max_queue_depth:
            self.stats.rejected_backpressure += 1
            raise RejectedError(
                f"queue depth {depth} at max_queue_depth="
                f"{self.max_queue_depth}; retry with backoff",
                kind="backpressure")
        admit, probe = self.breaker.allow()
        if not admit:
            self.stats.shed_breaker += 1
            raise RejectedError(
                f"circuit breaker {self.breaker.state}: shedding load",
                kind="breaker")
        self._tickets += 1
        t = _Ticket(self._tickets, np.asarray(prompt, np.int32),
                    max_new_tokens,
                    self._effective_deadline(deadline, priority),
                    patch_embeds, asyncio.Queue(), probe=probe)
        if timeout_s is not None:
            t.timeout_s = timeout_s
            t.expires_at = time.monotonic() + timeout_s
        self._inflight[t.id] = t
        self._pending.append(t)
        self.stats.accepted += 1
        self._wake.set()
        return TokenStream(self, t)

    @staticmethod
    def _effective_deadline(deadline: Optional[float],
                            priority: int) -> Optional[float]:
        """Fold (deadline, priority) into the engine's single deadline
        order (module docstring): explicit deadline wins; a bare positive
        priority becomes ``-priority`` (ahead of any non-negative real
        deadline); neither stays None (best-effort, first evicted)."""
        if deadline is not None:
            return float(deadline)
        if priority > 0:
            return -float(priority)
        return None

    # -- cancellation --------------------------------------------------------
    def _cancel_ticket(self, t: _Ticket) -> None:
        if t.done or t.cancelled:
            return
        t.cancelled = True
        self._inflight.pop(t.id, None)
        self.stats.cancelled += 1
        if t.probe:
            self.breaker.abandon_probe()
        if t.on_done is not None:
            t.on_done(None)
        self._cancels.append(t)
        t.queue.put_nowait(_DONE)  # unblock a waiting consumer now
        self._wake.set()

    def _timeout_ticket(self, t: _Ticket) -> None:
        """The request outlived its wall-clock budget: cancel the engine
        side, end the stream with ``RejectedError(kind="timeout")``."""
        if t.done or t.cancelled:
            return
        t.cancelled = True
        self._inflight.pop(t.id, None)
        self.stats.timeouts += 1
        if t.probe:
            self.breaker.abandon_probe()
        if t.on_done is not None:
            t.on_done(None)
        self._cancels.append(t)
        t.queue.put_nowait(RejectedError(
            f"request exceeded its {t.timeout_s}s wall-clock timeout",
            kind="timeout"))
        self._wake.set()

    # -- failover hand-off (router-owned) ------------------------------------
    def take_inflight(self) -> List[_Ticket]:
        """Detach every in-flight ticket WITHOUT ending its stream.

        The router's failover path: the returned tickets will be
        resubmitted on a healthy replica with their queues kept open, so
        nothing here may push ``_DONE`` or an error.  Engine-side state
        (lanes, blocks) is NOT touched — the caller owns that cleanup
        (``engine.cancel`` per ticket uid)."""
        out = [t for t in self._inflight.values()
               if not t.done and not t.cancelled]
        self._inflight.clear()
        self._pending.clear()
        self._by_uid.clear()
        return out

    async def halt(self) -> None:
        """Hard-stop the pump without draining or cancelling tickets —
        a dead replica cannot drain (its ``step()`` raises forever).
        Idempotent; used by the router after ``take_inflight()``."""
        if self._stopped:
            return
        self._halt = True
        self._running = False
        self._wake.set()
        if self._pump_task is not None:
            await self._pump_task
        self._executor.shutdown(wait=True)
        self.engine.on_token = None
        self._stopped = True

    # -- pump ----------------------------------------------------------------
    def _on_token(self, uid: int, token: int) -> None:
        """Engine ``on_token`` hook — runs on the pump thread inside
        ``step()``; events are routed to queues on the event loop."""
        self._events.append(("tok", uid, token))

    def _tick(self) -> List[Tuple[int, List[int]]]:
        """ONE serialized engine interaction (pump thread): apply
        cancels, submit pending requests in arrival order, step the
        scheduler, feed the breaker."""
        eng = self.engine
        cancels, self._cancels = self._cancels, []
        for t in cancels:
            if t.uid is not None:
                eng.cancel(t.uid)
                self._by_uid.pop(t.uid, None)
        pending, self._pending = self._pending, []
        for t in pending:
            if t.cancelled:
                continue
            try:
                t.uid = eng.submit(
                    t.prompt, max_new_tokens=t.max_new_tokens,
                    deadline=t.deadline, patch_embeds=t.patch_embeds)
            except Exception as e:  # validation error -> the stream
                self._events.append(("err", t, e))
                continue
            self._by_uid[t.uid] = t
        p0 = eng.stats.preemptions
        err: Optional[BaseException] = None
        try:
            finished = eng.step() if eng.has_pending_work() else []
        except Exception as e:
            # A raising step must not kill the pump: the engine's
            # poisoned contract keeps the BlockStore consistent (or the
            # engine refuses further steps), and the health layer — not
            # an exception unwind — decides the replica's fate.
            err, finished = e, []
            self.stats.step_errors += 1
            self.last_step_error = e
            self._consec_step_errors += 1
            if self._consec_step_errors >= self.max_step_errors:
                self._engine_dead = True
        else:
            self._consec_step_errors = 0
        self.breaker.record_tick(eng.stats.preemptions - p0,
                                 eng.pool_saturation)
        self._last_tick_info = {
            "error": err,
            "cost_ticks": int(getattr(eng, "last_step_cost", 1)),
        }
        return finished

    def _dispatch(self, finished: List[Tuple[int, List[int]]]) -> None:
        """Route the tick's events to per-request queues (event loop)."""
        events, self._events = self._events, []
        for kind, a, b in events:
            if kind == "tok":
                t = self._by_uid.get(a)
                if t is not None and not t.cancelled:
                    t.emitted.append(b)
                    t.queue.put_nowait(b)
                    if t.on_first_token is not None:
                        cb, t.on_first_token = t.on_first_token, None
                        cb()
            else:  # "err"
                t = a
                if t.cancelled:
                    continue
                t.done = True
                self._inflight.pop(t.id, None)
                self.stats.errors += 1
                if t.probe:
                    self.breaker.abandon_probe()
                if t.on_done is not None:
                    t.on_done(False)
                t.queue.put_nowait(b)
        for uid, toks in finished:
            t = self._by_uid.pop(uid, None)
            if t is None or t.cancelled:
                continue
            t.done, t.result = True, list(toks)
            self._inflight.pop(t.id, None)
            self.stats.completed += 1
            if t.probe:
                self.breaker.record_probe_end(ok=True)
            if t.on_done is not None:
                t.on_done(True)
            t.queue.put_nowait(_DONE)
        if any(t.expires_at is not None for t in self._inflight.values()):
            now = time.monotonic()
            for t in [t for t in self._inflight.values()
                      if t.expires_at is not None and now >= t.expires_at]:
                self._timeout_ticket(t)
        if self._engine_dead and self.tick_observer is None \
                and self._inflight:
            # Solo frontend on a dead engine: nobody will fail these
            # requests over, so surface the failure instead of hanging.
            self._fail_all(RuntimeError(
                f"engine unresponsive: {self.max_step_errors} consecutive "
                f"step failures (last: {self.last_step_error!r})"))
        info, self._last_tick_info = self._last_tick_info, None
        if info is not None and self.tick_observer is not None:
            self.tick_observer(info)

    def _has_engine_work(self) -> bool:
        if self._engine_dead:
            # Stop ticking a dead engine (its step raises forever); the
            # pump idles so stop()/halt() can complete.
            return False
        return bool(self._pending or self._cancels
                    or self.engine.has_pending_work())

    async def _pump(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while True:
                if self._halt:
                    break
                if not self._has_engine_work() \
                        and self.breaker.state == "closed":
                    if not self._running:
                        break
                    self._wake.clear()
                    if not self._has_engine_work():
                        await self._wake.wait()
                    continue
                if not self._running and not self._has_engine_work():
                    # Stopped while the breaker is open/half-open:
                    # nothing left to drain, the cooldown clock dies
                    # with the service.
                    break
                finished = await loop.run_in_executor(
                    self._executor, self._tick)
                self._dispatch(finished)
                if self._has_engine_work():
                    await asyncio.sleep(0)  # let submitters interleave
                else:
                    # Idle ticks only advance the breaker's cooldown;
                    # don't spin the loop hot while we wait it out.
                    await asyncio.sleep(self.idle_sleep_s)
        except BaseException as e:
            self._fail_all(e)
            raise

    def _fail_all(self, exc: BaseException) -> None:
        """Pump died: no consumer may be left awaiting a queue forever."""
        for t in list(self._inflight.values()):
            if not t.done:
                t.done = True
                t.queue.put_nowait(
                    RuntimeError(f"frontend pump failed: {exc!r}"))
        self._inflight.clear()
