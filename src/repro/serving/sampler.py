"""Token samplers: greedy, temperature, top-k."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0  # 0 => no truncation


def sample(cfg: SamplerConfig, logits: jnp.ndarray, key) -> jnp.ndarray:
    """logits: (B, V) -> token ids (B,)."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
