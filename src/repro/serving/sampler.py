"""Token samplers: greedy, temperature, top-k."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0  # 0 => no truncation


def positional_keys(keys: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Per-row sampling keys for tokens at ``positions``: row b's token at
    position p draws from ``fold_in(keys[b], p)``.

    This is THE positional-PRNG rule the serving engine builds on: with
    ``keys[b] = fold_in(seed, uid_b)``, the key of (request, position) is a
    pure function of the pair — independent of co-tenants, of preemption
    recomputes, and of speculative decoding.  In particular it is why
    speculation needs no explicit stream fast-forwarding: a request's
    position only ever advances by ACCEPTED tokens, and the verify pass
    re-samples each drafted position with exactly this key, so rejected
    drafts never consume (or skip) randomness and stochastic outputs stay
    bit-identical to plain decode.

    keys: (B, key_size) per-row base keys; positions: (B',) int32 with
    B' == B (pass pre-repeated keys for a flattened (B, P) position grid).
    """
    return jax.vmap(jax.random.fold_in)(keys, positions)


def sample(cfg: SamplerConfig, logits: jnp.ndarray, key,
           active: jnp.ndarray = None, pad_id: int = 0) -> jnp.ndarray:
    """logits: (B, V) -> token ids (B,).

    ``key``: a single PRNG key shared by the batch, OR a (B,)-batched key
    array (one per row).  Per-row keys make stochastic sampling
    reproducible PER REQUEST: the continuous-batching engine folds each
    request's uid into its own key stream, so a request's sampled tokens
    do not depend on which co-tenants happen to share its decode batch.

    ``active``: optional (B,) bool mask — rows where it is False emit
    ``pad_id`` instead of a sampled token, so a finished (retired)
    continuous-batching slot is a no-op inside the jitted decode step.
    """
    if cfg.temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        lg = logits.astype(jnp.float32) / cfg.temperature
        if cfg.top_k > 0:
            kth = jax.lax.top_k(lg, cfg.top_k)[0][..., -1:]
            lg = jnp.where(lg < kth, -1e30, lg)
        batched = getattr(key, "ndim", 1) > 1
        if batched:
            tok = jax.vmap(
                lambda k, l: jax.random.categorical(k, l))(key, lg)
            tok = tok.astype(jnp.int32)
        else:
            tok = jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
    if active is not None:
        tok = jnp.where(active, tok, jnp.int32(pad_id))
    return tok
