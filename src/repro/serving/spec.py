"""Draft-token proposers for speculative multi-token decoding.

Decode is memory-bandwidth-bound: every step streams the whole KV pool to
emit ONE token per lane (the regime Chiplet Cloud's Fig 8 prices, and the
reason CC-MEM exists).  Speculative decoding is the standard escape: a
cheap *proposer* drafts up to ``spec_k`` continuation tokens per lane, the
target model scores last-accepted + drafts in ONE pass through the paged
flash-prefill path (which already handles K>1 query positions against the
block pool), and the engine keeps the longest draft prefix that matches
what plain decode would have produced — so every extra accepted token
amortizes one full KV sweep.

A proposer is anything with::

    propose(history: Sequence[int], k: int) -> list[int]

``history`` is the request's effective token stream so far (prompt tail +
generated output, host side); the return is at most ``k`` draft tokens.
Proposers are *advisory only*: the verify-and-accept step guarantees the
emitted stream is bit-identical to ``spec_decode="off"`` regardless of
what is proposed, so a bad proposer costs speed, never correctness.  The
interface is deliberately model-free so a small draft *model* can slot in
later — it only needs to produce host-side token lists per request.

``NgramProposer`` is the self-drafting baseline: it assumes the sequence
repeats — find the longest recent n-gram suffix that occurred earlier in
the history and replay what followed it.  That wins on repetitive or
structured output (code, JSON, quoted context, greedy loops) and proposes
nothing on text with no self-similarity, where speculation degrades to
plain decode plus a cheap host-side scan.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

#: Accepted values for ``ServingEngine(spec_decode=...)``.
SPEC_DECODE_MODES = ("off", "ngram")


class NgramProposer:
    """Suffix-match n-gram drafting over the request's own history.

    For ``n`` from ``max_n`` down to ``min_n``: take the history's last
    ``n`` tokens, find the RIGHTMOST earlier occurrence of that n-gram
    with at least ``k`` continuation tokens available — falling back to
    the rightmost occurrence with ANY continuation — and propose the (up
    to ``k``) tokens that followed it.  Longer matches are preferred
    (more context agreement), and the rightmost occurrence wins so the
    draft tracks the most recent phrasing.  The with-room preference
    matters on short-cycle output (greedy loops): the most recent match
    sits flush against the end of the history and offers a 1-token
    draft, while an occurrence one period earlier replays a full ``k``
    tokens of the same cycle.
    """

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, got "
                             f"({min_n}, {max_n})")
        self.max_n = max_n
        self.min_n = min_n

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        h = [int(t) for t in history]
        n_hist = len(h)
        if k <= 0 or n_hist < self.min_n + 1:
            return []
        for n in range(min(self.max_n, n_hist - 1), self.min_n - 1, -1):
            suffix = h[n_hist - n:]
            fallback = None
            for i in range(n_hist - n - 1, -1, -1):
                if h[i:i + n] == suffix:
                    if n_hist - (i + n) >= k:
                        return h[i + n:i + n + k]
                    if fallback is None:
                        fallback = h[i + n:i + n + k]
            if fallback is not None:
                return fallback
        return []


def make_proposer(spec_decode: str):
    """Map the engine knob to a proposer instance (None when off)."""
    if spec_decode == "off":
        return None
    if spec_decode == "ngram":
        return NgramProposer()
    raise ValueError(
        f"spec_decode must be one of {SPEC_DECODE_MODES}, "
        f"got {spec_decode!r}")
