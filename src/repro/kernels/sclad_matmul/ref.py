"""Pure-jnp oracle for the SCLD matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.sclad_matmul.sclad_matmul import decompress


def sclad_matmul_ref(x, vals, rows):
    """y = x @ decode(vals, rows) — decode in numpy, matmul in fp32."""
    w = decompress(np.asarray(vals), np.asarray(rows))
    return (x.astype(jnp.float32) @ jnp.asarray(w, jnp.float32)
            ).astype(x.dtype)
