"""SCLD matmul: Store-as-Compressed, Load-as-Dense weights (paper §3.2).

TPU adaptation of the CC-MEM compression decoder.  The paper's ASIC decodes
an element-wise tile-CSR format in dedicated hardware next to each SRAM bank
group; a TPU has no such decoder and VMEM wants >= (8, 128) granularity, so
the format here is *block* SCLD:

  * W (K, N) is partitioned into MXU tiles of (128, 128); each tile is
    16 row-units of (8, 128).
  * Store-as-compressed: each tile keeps only its C nonzero row-units
    (values (C, 8, 128) + unit row indices), N:M-style uniform so shapes are
    static.  HBM traffic per tile is C/16 of dense.
  * Load-as-dense: the kernel decodes the units into a dense (128, 128) VMEM
    scratch tile, then issues a dense MXU matmul — compute stays entirely
    sparsity-agnostic, exactly the paper's contract.

Grid: (M/bm, N/bn, K/128), K innermost; accumulation in an f32 VMEM scratch
that is flushed to the output on the last K step.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

UNIT_R = 8  # row-unit height (TPU sublane granularity)
TILE = 128  # MXU tile edge
UNITS_PER_TILE = TILE // UNIT_R  # 16


def _sclad_kernel(x_ref, vals_ref, rows_ref, o_ref, w_scratch, acc_scratch,
                  *, n_k: int):
    """x_ref: (bm, 128); vals_ref: (C, 8, 128); rows_ref: (C,) int32;
    o_ref: (bm, bn=128); scratch: w (128,128), acc (bm, 128) f32."""
    C = vals_ref.shape[0]
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    # Load-as-dense: decode the C stored row-units into a dense VMEM tile.
    w_scratch[...] = jnp.zeros_like(w_scratch)
    for c in range(C):  # C is static (uniform N:M block compression)
        r = rows_ref[c]
        pl.store(w_scratch, (pl.dslice(r * UNIT_R, UNIT_R), slice(None)),
                 vals_ref[c].astype(w_scratch.dtype))

    # Dense MXU matmul on the decoded tile — compute is sparsity-agnostic.
    x = x_ref[...]
    acc_scratch[...] += jax.lax.dot(
        x, w_scratch[...].astype(x.dtype),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[...] = acc_scratch[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def sclad_matmul(x, vals, rows, *, block_m: int = 128,
                 interpret: bool = False):
    """y = x @ decode(vals, rows).

    x:    (M, K)
    vals: (K//128, N//128, C, 8, 128) — stored nonzero row-units
    rows: (K//128, N//128, C) int32  — unit row index within the tile
    Returns (M, N).
    """
    M, K = x.shape
    nk, nn, C = vals.shape[:3]
    N = nn * TILE
    assert K == nk * TILE and M % block_m == 0

    grid = (M // block_m, nn, nk)

    return pl.pallas_call(
        functools.partial(_sclad_kernel, n_k=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, TILE), lambda i, j, k: (i, k)),
            pl.BlockSpec((None, None, C, UNIT_R, TILE),
                         lambda i, j, k: (k, j, 0, 0, 0)),
            pl.BlockSpec((None, None, C), lambda i, j, k: (k, j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, TILE), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            # dense decode tile + accumulator, VMEM-resident
            pltpu.VMEM((TILE, TILE), jnp.float32),
            pltpu.VMEM((block_m, TILE), jnp.float32),
        ],
        interpret=interpret,
    )(x, vals, rows)


# ---------------------------------------------------------------------------
# Block compression (encode side of SCLD)
# ---------------------------------------------------------------------------

def block_compress(w: np.ndarray, units_kept: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Uniform N:M block pruning + compression.

    Keeps the `units_kept` largest-magnitude (8, 128) row-units per (128,128)
    tile.  Returns (vals (nk, nn, C, 8, 128), rows (nk, nn, C) int32).
    """
    K, N = w.shape
    assert K % TILE == 0 and N % TILE == 0
    nk, nn = K // TILE, N // TILE
    C = units_kept
    tiles = w.reshape(nk, TILE, nn, TILE).transpose(0, 2, 1, 3)
    units = tiles.reshape(nk, nn, UNITS_PER_TILE, UNIT_R, TILE)
    mag = np.abs(units).sum(axis=(-1, -2))  # (nk, nn, 16)
    order = np.argsort(-mag, axis=-1)[..., :C]  # top-C units
    rows = np.sort(order, axis=-1).astype(np.int32)
    vals = np.take_along_axis(units, rows[..., None, None], axis=2)
    return vals.astype(w.dtype), rows


def decompress(vals: np.ndarray, rows: np.ndarray) -> np.ndarray:
    """Inverse of block_compress (zero-filled)."""
    nk, nn, C = vals.shape[:3]
    units = np.zeros((nk, nn, UNITS_PER_TILE, UNIT_R, TILE), vals.dtype)
    np.put_along_axis(units, rows[..., None, None], vals, axis=2)
    tiles = units.reshape(nk, nn, TILE, TILE).transpose(0, 2, 1, 3)
    return tiles.reshape(nk * TILE, nn * TILE)
