"""Jit'd public wrapper: SCLD linear layer.

``SCLDLinear`` carries block-compressed weights (the store side) and applies
them with the Pallas kernel on TPU (interpret mode elsewhere).  HBM traffic
for the weights is ``units_kept/16`` of dense — the paper's
memory-capacity/bandwidth win, restated for the TPU hierarchy.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sclad_matmul.sclad_matmul import (
    block_compress, sclad_matmul)
from repro.kernels.sclad_matmul.ref import sclad_matmul_ref


@dataclass
class SCLDLinear:
    vals: jnp.ndarray  # (K/128, N/128, C, 8, 128)
    rows: jnp.ndarray  # (K/128, N/128, C)

    @classmethod
    def from_dense(cls, w, units_kept: int) -> "SCLDLinear":
        vals, rows = block_compress(np.asarray(w), units_kept)
        return cls(vals=jnp.asarray(vals), rows=jnp.asarray(rows))

    @property
    def sparsity(self) -> float:
        return 1.0 - self.vals.shape[2] / 16.0

    def __call__(self, x, interpret: bool | None = None):
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if interpret and x.shape[0] > 512:
            # Interpret mode is slow — fall back to the oracle for big calls.
            return sclad_matmul_ref(x, self.vals, self.rows)
        return sclad_matmul(x, self.vals, self.rows, interpret=interpret)
