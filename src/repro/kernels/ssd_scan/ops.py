"""Jit'd public wrapper for the SSD chunk scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def ssd(x, dt, A, b, c, *, chunk: int = 128):
    """Convenience wrapper matching the mamba block's calling convention.

    x: (BH, S, P); dt: (BH, S) (already softplus'ed); A: per-row decay (BH,);
    b, c: (BH, S, N).  Returns (y, final_state).
    """
    xdt = x * dt[..., None]
    a = dt * A[:, None]
    if jax.default_backend() == "tpu":
        return ssd_scan(xdt, a, b, c, chunk=chunk)
    return ssd_scan_ref(xdt, a, b, c)
