"""Pure-jnp oracle for the SSD chunk-scan kernel: naive state recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(xdt, a, b, c):
    """Sequential SSM recurrence (the definition, O(S) steps).

    xdt: (BH, S, P); a: (BH, S); b, c: (BH, S, N)
    state_t = exp(a_t) * state_{t-1} + xdt_t (outer) b_t
    y_t = c_t . state_t
    """
    BH, S, P = xdt.shape
    N = b.shape[2]

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp
        state = jnp.exp(a_t)[:, None, None] * state \
            + x_t[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bn,bpn->bp", c_t, state)
        return state, y_t

    s0 = jnp.zeros((BH, P, N), jnp.float32)
    xs = (xdt.astype(jnp.float32).transpose(1, 0, 2),
          a.astype(jnp.float32).T,
          b.astype(jnp.float32).transpose(1, 0, 2),
          c.astype(jnp.float32).transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2).astype(xdt.dtype), state
