"""Mamba-2 SSD chunk-scan Pallas kernel (arXiv:2405.21060).

Grid is (batch*heads, num_chunks); TPU iterates the chunk dim sequentially,
so the SSM state is carried across chunk programs in a VMEM scratch — the
inter-chunk recurrence costs no HBM round-trips.  Per chunk the kernel does
the quadratic dual form on an MXU-aligned (Q x Q) tile:

    y_diag = ((C B^T) . L) xdt          L_ij = exp(cum_i - cum_j), i >= j
    y_off  = exp(cum) . (C state^T)
    state  = exp(cum_last) state + (xdt . exp(cum_last - cum))^T B

Inputs are pre-scaled outside the kernel (xdt = x * dt, a = A * dt) so every
program is pure matmul + elementwise work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_scratch, *, n_chunks: int):
    """Blocks: xdt (Q, P), a (Q, 1), b/c (Q, N); scratch state (P, N) f32."""
    Q, P = xdt_ref.shape
    N = b_ref.shape[1]
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scratch[...] = jnp.zeros_like(state_scratch)

    xdt = xdt_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)[:, 0]  # (Q,)
    bmat = b_ref[...].astype(jnp.float32)
    cmat = c_ref[...].astype(jnp.float32)

    cum = jnp.cumsum(a)  # (Q,)
    # L_ij = exp(cum_i - cum_j) for i >= j else 0.
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    scores = (cmat @ bmat.T) * L  # (Q, Q)
    y = scores @ xdt  # intra-chunk

    state = state_scratch[...]
    y += jnp.exp(cum)[:, None] * (cmat @ state.T)  # inter-chunk output

    decay_in = jnp.exp(cum[-1] - cum)  # (Q,)
    new_state = jnp.exp(cum[-1]) * state + (xdt * decay_in[:, None]).T @ bmat
    state_scratch[...] = new_state

    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        state_out_ref[...] = new_state.astype(state_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xdt, a, b, c, *, chunk: int = 128, interpret: bool = False):
    """xdt: (BH, S, P) pre-scaled inputs; a: (BH, S) = A*dt;
    b, c: (BH, S, N). Returns (y (BH, S, P), final_state (BH, P, N))."""
    BH, S, P = xdt.shape
    N = b.shape[2]
    assert S % chunk == 0
    n_chunks = S // chunk

    a2 = a[..., None]  # (BH, S, 1)
    grid = (BH, n_chunks)
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, P), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, chunk, 1), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, chunk, N), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, chunk, N), lambda h, i: (h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, P), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, P, N), lambda h, i: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), xdt.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, a2, b, c)
    return y, state
