"""Flash GQA decode Pallas kernel: one new token against a long KV cache.

Decode is the workload the paper prices (TCO per *generated* token) and is
purely memory-bound: per token, the kernel streams the KV cache once.  The
grid is (batch, kv_heads); each program holds the `rep` query heads that
share one KV head in VMEM and streams that head's K/V in blocks with online
softmax — KV bytes are read exactly once (the CC-MEM contract).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                   sm_scale: float):
    """q_ref: (rep, D); k_ref/v_ref: (S, D); len_ref: (1,) in SMEM."""
    rep, D = q_ref.shape
    S = k_ref.shape[0]
    length = len_ref[0]
    q = q_ref[...].astype(jnp.float32) * sm_scale

    def body(i, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # (rep, block_k)
        pos = i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p.astype(v.dtype) @ v
        return acc, m_new, l

    # Only blocks below `length` contribute.
    upper = jnp.minimum(jax.lax.div(length + block_k - 1, block_k),
                        S // block_k)
    acc0 = jnp.zeros((rep, D), jnp.float32)
    m0 = jnp.full((rep, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, length, *, block_k: int = 128,
                 interpret: bool = False):
    """q: (B, H, D); k_cache/v_cache: (B, S, Hk, D); length: scalar int32
    (number of valid cache positions). Returns (B, H, D)."""
    B, H, D = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hk
    assert S % block_k == 0
    sm_scale = 1.0 / math.sqrt(D)

    qt = q.reshape(B, Hk, rep, D)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, Hk, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    lens = jnp.full((1,), length, jnp.int32)

    grid = (B, Hk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, sm_scale=sm_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, None, rep, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, D), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, rep, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, rep, D), q.dtype),
        interpret=interpret,
    )(lens, qt, kt, vt)
    return out.reshape(B, H, D)
