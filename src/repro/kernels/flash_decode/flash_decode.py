"""Flash GQA decode Pallas kernels: one new token against a long KV cache.

Decode is the workload the paper prices (TCO per *generated* token) and is
purely memory-bound: per token, the kernel streams the KV cache once — the
CC-MEM contract (PAPER.md §CC-MEM).  Two cache layouts share the math:

  * ``flash_decode``       — contiguous (B, S, Hk, D) caches.  The grid is
    (batch, kv_heads); each program holds the ``rep`` query heads that share
    one KV head in VMEM and streams that head's K/V in ``block_k`` tiles
    with online softmax.  ``lengths`` is per-row: rows of a continuous
    batch sit at different sequence offsets.
  * ``paged_flash_decode`` — the serving engine's block-pool layout
    (N, bs, Hk, D) addressed through per-lane block tables
    (``serving.paged.BlockStore``).  The grid is (batch, kv_heads,
    table_width) and the block table rides the scalar-prefetch channel
    (``PrefetchScalarGridSpec``): the index map resolves ``tables[b, i]``
    BEFORE the program body runs, so each program's K/V block is DMA'd
    straight from the shared pool — no dense per-lane copy of the pool is
    ever materialized (the O(B·T·bs·Hk·D) gather this kernel replaces).
    The online-softmax accumulator lives in VMEM scratch and persists
    across the (sequential, innermost) block dimension of the grid; blocks
    at or beyond a row's length are skipped, and the trash blocks dead
    lanes' tables point at are naturally masked by ``lengths``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pv_dtype(v):
    """MXU-friendly dtype for the probs @ V matmul: the cache dtype, except
    f8 (too coarse for probabilities) which is computed in bf16."""
    return jnp.bfloat16 if v.dtype == jnp.float8_e4m3fn else v.dtype


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                   sm_scale: float):
    """q_ref: (rep, D); k_ref/v_ref: (S, D); len_ref: (B,) in SMEM."""
    rep, D = q_ref.shape
    S = k_ref.shape[0]
    length = len_ref[pl.program_id(0)]
    q = q_ref[...].astype(jnp.float32) * sm_scale

    def body(i, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # (rep, block_k)
        pos = i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p.astype(_pv_dtype(v)) @ v.astype(_pv_dtype(v))
        return acc, m_new, l

    # Only blocks below `length` contribute.
    upper = jnp.minimum(jax.lax.div(length + block_k - 1, block_k),
                        S // block_k)
    acc0 = jnp.zeros((rep, D), jnp.float32)
    m0 = jnp.full((rep, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, block_k: int = 128,
                 interpret: bool = False):
    """q: (B, H, D); k_cache/v_cache: (B, S, Hk, D); lengths: scalar int32
    or a per-row (B,) int32 vector (number of valid cache positions per
    row).  Returns (B, H, D)."""
    B, H, D = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hk
    assert S % block_k == 0
    sm_scale = 1.0 / math.sqrt(D)

    qt = q.reshape(B, Hk, rep, D)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, Hk, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    lens = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (B,))

    grid = (B, Hk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, sm_scale=sm_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, None, rep, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, D), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, rep, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, rep, D), q.dtype),
        interpret=interpret,
    )(lens, qt, kt, vt)
    return out.reshape(B, H, D)


def _paged_decode_kernel(lens_ref, tbl_ref, q_ref, k_ref, v_ref, *rest,
                         bs: int, block_k: int, sm_scale: float,
                         quantized: bool):
    """One program = one pool block of one (row, kv_head) pair.

    lens_ref (B,) / tbl_ref (B, T): scalar-prefetch SMEM (the table also
    drives the K/V index maps); q_ref (rep, D); k_ref/v_ref (bs, D): THIS
    grid step's pool block, already resolved through the table; o_ref
    (rep, D).  acc/m/l: VMEM scratch carrying the online softmax across
    the T (innermost, sequential) grid dimension.

    ``quantized`` (SCLAD pool): two extra (bs, 1) fp32 refs ks/vs carry the
    block's per-position scales (resolved through the SAME table walk), and
    the load path expands payload * scale in fp32 before the usual math —
    compressed bytes are all that crosses HBM; compute sees dense values.
    """
    if quantized:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    b, i = pl.program_id(0), pl.program_id(2)
    T = pl.num_programs(2)
    length = lens_ref[b]

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Blocks wholly at/beyond the row's length are dead: skip their compute
    # (their table entries point at the trash block for unallocated tails).
    @pl.when(i * bs < length)
    def _block():
        q = q_ref[...].astype(jnp.float32) * sm_scale
        for s0 in range(0, bs, block_k):  # static sub-tiling of the block
            k = k_ref[s0:s0 + block_k, :]
            v = v_ref[s0:s0 + block_k, :]
            if quantized:
                # Load-as-Dense: (bs', D) payload * (bs', 1) scale in fp32,
                # then ROUNDED to the compute dtype — the exact cast chain
                # of ``kv_quant.dequantize(..., q.dtype)`` in the jnp
                # reference, so both implementations score bitwise-equal
                # dense values and the fp path's ref/kernel greedy
                # bit-identity carries over to quantized pools.
                k = (k.astype(jnp.float32)
                     * ks_ref[s0:s0 + block_k, :]).astype(q_ref.dtype)
                v = (v.astype(jnp.float32)
                     * vs_ref[s0:s0 + block_k, :]).astype(q_ref.dtype)
            s = q @ k.astype(jnp.float32).T  # (rep, block_k)
            pos = i * bs + s0 + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(pos < length, s, NEG_INF)
            m = m_ref[...]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l_ref[...] = l_ref[...] * corr \
                + jnp.sum(p, axis=-1, keepdims=True)
            acc_ref[...] = acc_ref[...] * corr \
                + p.astype(_pv_dtype(v)) @ v.astype(_pv_dtype(v))
            m_ref[...] = m_new

    @pl.when(i == T - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def paged_flash_decode(q, k_pool, v_pool, lengths, block_tables, *,
                       block_k: int = 0, interpret: bool = False,
                       kv_scales=None):
    """Decode attention straight out of the paged KV block pool.

    q:            (B, H, D) — one new token per row;
    k_pool/v_pool:(N, bs, Hk, D) — the SHARED block pool
                  (``model.init_paged_cache`` layout, trash block included);
    lengths:      (B,) int32 — valid cache positions per row (dead lanes'
                  lengths only cover trash blocks, so their output is
                  garbage that the caller's active mask discards);
    block_tables: (B, T) int32 — per-lane table mapping block index
                  ``j`` to the pool block holding positions
                  [j*bs, (j+1)*bs); unallocated entries point at the trash
                  block and are masked by ``lengths``.
    block_k:      inner tile over a block's token dim (<= bs; 0 => whole
                  block per step).  Rounded down to a divisor of ``bs`` so
                  a caller tuned for the dense kernel's 128 can pass the
                  same value against any pool block size.
    kv_scales:    optional (k_scale, v_scale) (N, bs, Hk) fp32 — the SCLAD
                  quantized pool's per-position-per-head scales.  They ride
                  the same table-walk BlockSpecs as the payload (one (bs, 1)
                  scale tile per program) and the dequant multiply is fused
                  into the block-streaming loop in VMEM.

    Returns (B, H, D).  KV bytes are read exactly once per token, block by
    block through the table — never gathered into a per-lane dense copy.
    """
    B, H, D = q.shape
    bs, Hk = k_pool.shape[1], k_pool.shape[2]
    T = block_tables.shape[1]
    rep = H // Hk
    bk = bs if block_k <= 0 else min(block_k, bs)
    while bs % bk:
        bk -= 1
    sm_scale = 1.0 / math.sqrt(D)
    qt = q.reshape(B, Hk, rep, D)
    quantized = kv_scales is not None

    pool_blk = pl.BlockSpec((None, bs, None, D),
                            lambda b, h, i, lens, tbl: (tbl[b, i], 0, h, 0))
    # Scales get a trailing singleton ((N, bs, Hk) -> (N, bs, Hk, 1), a
    # layout-preserving view) so their table-walked tile is 2D (bs, 1).
    scale_blk = pl.BlockSpec((None, bs, None, 1),
                             lambda b, h, i, lens, tbl: (tbl[b, i], 0, h, 0))
    in_specs = [
        pl.BlockSpec((None, None, rep, D),
                     lambda b, h, i, lens, tbl: (b, h, 0, 0)),
        # The pool is indexed THROUGH the prefetched table: each grid
        # step DMAs exactly one shared block for one kv head.
        pool_blk,
        pool_blk,
    ]
    inputs = [jnp.asarray(lengths, jnp.int32),
              jnp.asarray(block_tables, jnp.int32), qt, k_pool, v_pool]
    if quantized:
        k_scale, v_scale = kv_scales
        in_specs += [scale_blk, scale_blk]
        inputs += [k_scale.astype(jnp.float32)[..., None],
                   v_scale.astype(jnp.float32)[..., None]]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # lengths, block_tables
        grid=(B, Hk, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, rep, D),
                               lambda b, h, i, lens, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, D), jnp.float32),  # acc
            pltpu.VMEM((rep, 1), jnp.float32),  # running max
            pltpu.VMEM((rep, 1), jnp.float32),  # running denom
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, bs=bs, block_k=bk,
                          sm_scale=sm_scale, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, rep, D), q.dtype),
        interpret=interpret,
    )(*inputs)
    return out.reshape(B, H, D)
