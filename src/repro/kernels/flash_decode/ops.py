"""Public decode-attention entry point: one call, both cache layouts.

``decode_attention`` is what ``models.layers.attention_decode`` (and
therefore ``model.decode_step`` and the serving engine's jitted decode
window) dispatches through.  Layout is selected by ``block_tables``
(None = contiguous (B, S, Hk, D) caches; else the (N, bs, Hk, D) block
pool), and the implementation by the ``kernel`` knob:

  * ``"auto"`` (default) — the Pallas kernel on TPU, the jnp reference
    elsewhere.  The probe is ``jax.default_backend()`` (respects
    JAX_PLATFORMS, no eager device enumeration) combined with this
    explicit knob — NOT ``jax.devices()[0].platform``, which forces
    device initialization and ignores how the caller placed its arrays.
  * ``"on"``   — always the kernel; off-TPU it runs in Pallas interpret
    mode (the CI/CPU parity path — bit-for-bit the kernel's math, executed
    by the interpreter).
  * ``"off"``  — always the jnp reference (the pre-kernel gather path).

The knob threads down from ``ModelConfig.attn_kernel`` /
``ServingEngine(attn_kernel=...)`` / ``launch.serve --attn-kernel``; the
same knob selects the prefill-side ``kernels.flash_prefill`` twin.
Deprecated spellings: ``ServingEngine(decode_kernel=...)`` and
``--decode-kernel`` still map onto ``attn_kernel`` (DeprecationWarning),
and ``cfg.decode_kernel`` remains readable as a property — but
``ModelConfig(decode_kernel=...)`` construction is gone with the field.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_decode.flash_decode import (flash_decode,
                                                     paged_flash_decode)
from repro.kernels.flash_decode.ref import decode_ref, paged_decode_ref
from repro.parallel import sharding

DECODE_KERNEL_MODES = ("auto", "on", "off")


def resolve_kernel(kernel: str = "auto"):
    """-> (use_kernel, interpret) for the current backend."""
    if kernel not in DECODE_KERNEL_MODES:
        raise ValueError(
            f"decode kernel mode {kernel!r} not in {DECODE_KERNEL_MODES}")
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if kernel == "auto" else kernel == "on"
    return use_kernel, use_kernel and not on_tpu


def decode_attention(q, k_cache, v_cache, lengths, *, block_tables=None,
                     kernel: str = "auto", block_k: int = 128,
                     kv_scales=None, mesh=None):
    """One decode-attention step.

    q: (B, H, D) — the new token's (rotated) queries;
    k_cache/v_cache: (B, S, Hk, D) contiguous caches, OR — when
        ``block_tables`` (B, T) int32 is given — the shared (N, bs, Hk, D)
        block pool they index;
    lengths: scalar or (B,) int32 valid positions per row;
    kv_scales: optional (k_scale, v_scale) (N, bs, Hk) fp32 scales of a
        SCLAD quantized pool (paged layout only) — both implementations
        dequantize the compressed payload on the load path.
    mesh: optional mesh with a ``model`` axis — the paged path then runs
        under ``shard_map`` with the pool's KV-head axis (payload and
        scale leaves) and the query head groups sharded over it; tables
        and lengths broadcast; per-shard body unchanged.  Ignored (plain
        single-device dispatch) when the axis can't split Hk evenly.

    Returns (B, H, D).  The caller owns the cache scatter of the new K/V;
    this is the read side only.
    """
    use_kernel, interpret = resolve_kernel(kernel)
    if block_tables is not None:
        if sharding.attn_shard_size(mesh, k_cache.shape[2]) > 1:
            return _sharded_paged_decode(q, k_cache, v_cache, lengths,
                                         block_tables, kernel, block_k,
                                         kv_scales, mesh)
        if not use_kernel:
            return paged_decode_ref(q, k_cache, v_cache, lengths,
                                    block_tables, kv_scales=kv_scales)
        return paged_flash_decode(q, k_cache, v_cache, lengths, block_tables,
                                  block_k=block_k, interpret=interpret,
                                  kv_scales=kv_scales)
    assert kv_scales is None, "kv_scales is a paged-pool layout"
    if not use_kernel:
        return decode_ref(q, k_cache, v_cache, lengths)
    S = k_cache.shape[1]
    bk = min(block_k, S)
    while S % bk:  # largest divisor of S at most block_k
        bk -= 1
    if bk < 8 and bk < S:
        # Degenerate tiling (e.g. prime S): a token-at-a-time kernel loop
        # would be far slower than the fused reference — use that instead.
        return decode_ref(q, k_cache, v_cache, lengths)
    return flash_decode(q, k_cache, v_cache, lengths, block_k=bk,
                        interpret=interpret)


def _sharded_paged_decode(q, k_cache, v_cache, lengths, block_tables,
                          kernel, block_k, kv_scales, mesh):
    """shard_map the paged decode read over the mesh's ``model`` axis.

    Attention is independent per KV head, so splitting the pool's Hk axis
    changes no arithmetic: shard i reads its own contiguous Hk/m pool
    slice with the matching contiguous H/m query-head group (head h
    attends kv-head h // rep, and contiguous chunks keep rep per shard),
    and outputs concat back on the head axis — no collective at all on
    this read path (the downstream ``@ wo`` psum lives in the layer).
    Block tables and lengths — the kernel's scalar-prefetch operands —
    are broadcast so every shard walks the identical table.
    """
    sp = sharding.paged_attn_specs()
    args = [q, k_cache, v_cache, lengths, block_tables]
    in_specs = [sp["q_decode"], sp["pool"], sp["pool"], sp["host"],
                sp["host"]]
    if kv_scales is not None:
        args += list(kv_scales)
        in_specs += [sp["scale"], sp["scale"]]

    def body(q, k, v, lengths, tables, *scales):
        return decode_attention(q, k, v, lengths, block_tables=tables,
                                kernel=kernel, block_k=block_k,
                                kv_scales=tuple(scales) or None)

    return sharding.shard_map(body, mesh, in_specs=tuple(in_specs),
                              out_specs=sp["out_decode"],
                              check_vma=False)(*args)
