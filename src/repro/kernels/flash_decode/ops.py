"""Jit'd public wrapper for flash decode with CPU fallback."""
from __future__ import annotations

import jax

from repro.kernels.flash_decode.flash_decode import flash_decode
from repro.kernels.flash_decode.ref import decode_ref


def decode_attention(q, k_cache, v_cache, length, *, block_k: int = 128):
    if jax.devices()[0].platform == "tpu":
        return flash_decode(q, k_cache, v_cache, length, block_k=block_k)
    return decode_ref(q, k_cache, v_cache, length)
