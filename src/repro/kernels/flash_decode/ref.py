"""Pure-jnp oracle for flash decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_ref(q, k_cache, v_cache, length):
    """q: (B, H, D); caches: (B, S, Hk, D); length: scalar -> (B, H, D)."""
    B, H, D = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Hk, rep, D)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bhrd,bkhd->bhrk", qf, kf) / math.sqrt(D)
    mask = jnp.arange(S)[None, None, None, :] < length
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrk,bkhd->bhrd", p, vf)
    return o.reshape(B, H, D).astype(q.dtype)
