"""Pure-jnp oracles for flash decode (dense and paged layouts).

``decode_ref`` reproduces ``models.layers._sdpa`` arithmetic EXACTLY
(compute-dtype score einsum, fp32 masked softmax, compute-dtype probs @ V):
it is both the kernel parity oracle and the engine's CPU fallback, so the
serving bit-identity matrix (tests/test_continuous_batching.py) holds
bitwise against the pre-kernel gather path.  Masked lanes score ``-1e30``,
which underflows to an exact 0 after the softmax's max-subtraction —
results are therefore independent of how much dead padding the cache
carries, which is what makes dense (S_max) and paged (table_width * bs)
layouts bit-comparable.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_ref(q, k_cache, v_cache, lengths):
    """q: (B, H, D); caches: (B, S, Hk, D); lengths: scalar int32 or (B,)
    valid positions per row -> (B, H, D)."""
    B, H, D = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hk
    lens = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (B,))
    qg = q.reshape(B, 1, Hk, rep, D)
    k = k_cache.astype(q.dtype)
    v = v_cache.astype(q.dtype)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    mask = jnp.arange(S)[None] < lens[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v)
    return out.reshape(B, H, D)


def paged_decode_ref(q, k_pool, v_pool, lengths, block_tables,
                     kv_scales=None):
    """Gather oracle for the paged kernel: resolve each lane's block table
    into a dense per-lane cache copy, then run ``decode_ref``.

    q: (B, H, D); pools: (N, bs, Hk, D); lengths: (B,) int32;
    block_tables: (B, T) int32.  This MATERIALIZES the (B, T*bs, Hk, D)
    copy the kernel exists to avoid — it is the correctness oracle (and the
    ``attn_kernel="off"`` fallback), not the hot path.

    kv_scales: (k_scale, v_scale) (N, bs, Hk) fp32 for a SCLAD quantized
    pool — the gathered payload is dequantized (fp32 multiply, one cast to
    q.dtype: ``models.kv_quant.dequantize``) before attention, the
    load-as-dense half of the compressed layout.
    """
    B = q.shape[0]
    Hk, D = k_pool.shape[2], k_pool.shape[3]
    kc = k_pool[block_tables].reshape(B, -1, Hk, D)
    vc = v_pool[block_tables].reshape(B, -1, Hk, D)
    if kv_scales is not None:
        from repro.models import kv_quant
        k_scale, v_scale = kv_scales
        ks = k_scale[block_tables].reshape(B, -1, Hk)
        vs = v_scale[block_tables].reshape(B, -1, Hk)
        kc = kv_quant.dequantize(kc, ks, q.dtype)
        vc = kv_quant.dequantize(vc, vs, q.dtype)
    return decode_ref(q, kc, vc, lengths)
