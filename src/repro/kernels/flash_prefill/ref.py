"""Pure-jnp oracle for paged chunked-prefill attention (+ K/V scatter).

``prefill_attention_ref`` reproduces the pre-kernel ``model.prefill_slots``
per-layer arithmetic EXACTLY:

  * the cached-context gather ``k_pool[block_tables]`` materializing the
    dense (B, T*bs, Hk, D) per-lane copy the kernel exists to avoid,
  * the dense (B, S, S) causal/left-pad mask and its (B, S, T*bs) context
    extension,
  * ``models.layers._sdpa`` arithmetic (compute-dtype score einsum, fp32
    masked softmax, compute-dtype probs @ V),
  * the host-side left-compact roll + block-table scatter of the chunk's
    new-token K/V (``.at[blk, off].set(..., mode="drop")``).

It is both the kernel parity oracle and the engine's CPU fallback
(``attn_kernel="off"`` / "auto" off-TPU), so the serving bit-identity
matrix in tests/test_continuous_batching.py holds bitwise against the
pre-refactor gather path.  Masked lanes score ``-1e30`` (exact 0 after the
softmax max-subtraction), so results are independent of how much dead
padding the gathered context carries.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def prefill_attention_ref(q, k_new, v_new, k_pool, v_pool, lengths,
                          block_tables,
                          start: Optional[jnp.ndarray] = None,
                          prefix: int = 0,
                          kv_scales=None, kv_dtype: Optional[str] = None):
    """One layer of chunked-prefill attention against a paged KV pool.

    q:             (B, S, H, D)  rotated queries of this chunk (S = prefix
                   + P: an optional vlm patch prefix plus P LEFT-padded
                   prompt tokens);
    k_new/v_new:   (B, S, Hk, D) this chunk's rotated K/V (compute dtype);
    k_pool/v_pool: (N, bs, Hk, D) the shared block pool (pool storage
                   dtype; trash block included);
    lengths:       (B,) int32 true token count of the chunk (<= P);
    block_tables:  (B, T) int32 per-lane tables;
    start:         None => first chunk (rows start at cache position 0, no
                   cached context); else (B,) int32 cache positions already
                   filled per row — the chunk attends to positions
                   [0, start) gathered through the table;
    prefix:        static vlm patch-prefix length (first chunk only);
    kv_scales:     optional (k_scale, v_scale) (N, bs, Hk) fp32 scales of a
                   SCLAD quantized pool, with ``kv_dtype`` ("int8"/"fp8")
                   naming the payload encoding.  Quantized semantics: the
                   gathered context payload is dequantized on load, the
                   chunk's OWN in-flight K/V is fake-quantized before
                   attention (every reader observes each token through
                   ``dequantize(quantize(x))`` — in-chunk and from-pool
                   scoring agree, so greedy bit-identity across chunk
                   sizes / prefix hits / preemption recomputes survives
                   quantization), and the scatter writes payload + scales.

    Returns (attn_out (B, S, H*D) in q.dtype, k_pool', v_pool') with the
    chunk's new K/V left-compacted and scattered through the table at
    positions ``start + i`` (junk-tail writes dropped); quantized calls
    append (k_scale', v_scale').
    """
    B, S, H, D = q.shape
    Hk = k_new.shape[2]
    rep = H // Hk
    P = S - prefix
    lengths = jnp.asarray(lengths, jnp.int32)
    pad = (P - lengths).astype(jnp.int32)  # (B,)
    start_v = jnp.zeros((B,), jnp.int32) if start is None \
        else jnp.asarray(start, jnp.int32)
    quantized = kv_scales is not None
    if quantized:
        from repro.models import kv_quant

    # Key j is visible to query i iff causal AND j is not a pad slot.
    sidx = jnp.arange(S)
    real_key = (sidx[None] < prefix) | (sidx[None] >= prefix + pad[:, None])
    mask = (sidx[None, None, :] <= sidx[None, :, None]) \
        & real_key[:, None, :]  # (B, S, S)

    kk, vv = k_new, v_new
    if quantized:
        # Store-as-compressed consistency: attend to the chunk's K/V as a
        # pool reader will see it once written.
        kk = kv_quant.fake_quant(k_new, kv_dtype)
        vv = kv_quant.fake_quant(v_new, kv_dtype)
    if start is not None:
        # Dense per-lane context gather — the O(B*T*bs*Hk*D) copy this
        # oracle pins and the kernel path provably never materializes.
        bs = k_pool.shape[1]
        kg = k_pool[block_tables].reshape(B, -1, *k_pool.shape[2:])
        vg = v_pool[block_tables].reshape(B, -1, *v_pool.shape[2:])
        if quantized:
            k_scale, v_scale = kv_scales
            ksg = k_scale[block_tables].reshape(B, -1, Hk)
            vsg = v_scale[block_tables].reshape(B, -1, Hk)
            kg = kv_quant.dequantize(kg, ksg, q.dtype)
            vg = kv_quant.dequantize(vg, vsg, q.dtype)
        ctx_len = block_tables.shape[1] * bs
        ctx_mask = jnp.arange(ctx_len)[None] < start_v[:, None]  # (B, T*bs)
        kk = jnp.concatenate([kg.astype(q.dtype), kk], axis=1)
        vv = jnp.concatenate([vg.astype(q.dtype), vv], axis=1)
        mask = jnp.concatenate(
            [jnp.broadcast_to(ctx_mask[:, None, :], (B, S, ctx_len)),
             jnp.broadcast_to(mask, (B, S, S))], axis=-1)

    # models.layers._sdpa arithmetic, reproduced exactly.
    qg = q.reshape(B, S, Hk, rep, D)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kk).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, vv).reshape(B, S, H * D)

    if quantized:
        k_pool, v_pool, k_scale, v_scale = scatter_new_kv_ref(
            k_new, v_new, k_pool, v_pool, lengths, block_tables,
            start=start, prefix=prefix, kv_scales=kv_scales,
            kv_dtype=kv_dtype)
        return out, k_pool, v_pool, k_scale, v_scale
    k_pool, v_pool = scatter_new_kv_ref(k_new, v_new, k_pool, v_pool,
                                        lengths, block_tables,
                                        start=start, prefix=prefix)
    return out, k_pool, v_pool


def scatter_new_kv_ref(k_new, v_new, k_pool, v_pool, lengths, block_tables,
                       start: Optional[jnp.ndarray] = None, prefix: int = 0,
                       kv_scales=None, kv_dtype: Optional[str] = None):
    """Host-side new-token K/V scatter (the ``attn_kernel="off"`` write
    path, bit-exact with the pre-fusion ``prefill_slots`` epilogue).

    Left-compacts each row's token K/V — real tokens to offsets 0..len-1
    after the prefix — then scatters through the block table at cache
    positions ``start + i``.  Junk-tail entries are redirected out of
    bounds and dropped so they cannot touch another row's blocks.

    With ``kv_scales`` + ``kv_dtype`` (SCLAD pool) the compacted rows are
    quantized (``models.kv_quant.quantize`` — per-row, path-independent,
    so compaction and quantization commute) and both payload and scales
    scatter through the same indices; returns the 4-tuple
    (k_pool, v_pool, k_scale, v_scale).
    """
    B, S = k_new.shape[0], k_new.shape[1]
    N, bs = k_pool.shape[0], k_pool.shape[1]
    T = block_tables.shape[1]
    P = S - prefix
    lengths = jnp.asarray(lengths, jnp.int32)
    pad = (P - lengths).astype(jnp.int32)
    start_v = jnp.zeros((B,), jnp.int32) if start is None \
        else jnp.asarray(start, jnp.int32)
    kvd = k_pool.dtype

    roll_idx = (jnp.arange(P)[None] + pad[:, None]) % P  # (B, P)

    def compact(kv):  # (B, S, Hk, D), token part rolled left
        head, tail = kv[:, :prefix], kv[:, prefix:]
        tail = jnp.take_along_axis(tail, roll_idx[:, :, None, None], axis=1)
        return jnp.concatenate([head, tail], axis=1) if prefix else tail

    dest = start_v[:, None] + jnp.arange(S)[None]  # (B, S) cache positions
    blk_idx = jnp.minimum(dest // bs, T - 1)
    blk = jnp.take_along_axis(block_tables, blk_idx, axis=1)  # (B, S)
    writable = jnp.arange(S)[None] < prefix + lengths[:, None]
    blk = jnp.where(writable, blk, N)  # junk -> out of bounds -> dropped
    off = dest % bs
    if kv_scales is not None:
        from repro.models import kv_quant
        k_scale, v_scale = kv_scales
        kq, ks1 = kv_quant.quantize(compact(k_new), kv_dtype)
        vq, vs1 = kv_quant.quantize(compact(v_new), kv_dtype)
        k_pool = k_pool.at[blk, off].set(kq, mode="drop")
        v_pool = v_pool.at[blk, off].set(vq, mode="drop")
        k_scale = k_scale.at[blk, off].set(ks1, mode="drop")
        v_scale = v_scale.at[blk, off].set(vs1, mode="drop")
        return k_pool, v_pool, k_scale, v_scale
    k_pool = k_pool.at[blk, off].set(compact(k_new).astype(kvd), mode="drop")
    v_pool = v_pool.at[blk, off].set(compact(v_new).astype(kvd), mode="drop")
    return k_pool, v_pool
