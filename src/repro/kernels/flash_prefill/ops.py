"""Public chunked-prefill attention entry point: one call, both paths.

``prefill_attention`` is what ``models.model.prefill_slots`` (and therefore
the serving engine's jitted prefill chunks) dispatches through.  The
implementation is selected by the ``attn_kernel`` knob — the generalization
of PR 4's ``decode_kernel`` to BOTH attention hot paths:

  * ``"auto"`` (default) — the Pallas kernel on TPU, the jnp reference
    elsewhere (probe: ``jax.default_backend()``, same as flash_decode);
  * ``"on"``   — always the kernel; off-TPU it runs in Pallas interpret
    mode (the CI/CPU parity path — bit-for-bit the kernel's math, executed
    by the interpreter);
  * ``"off"``  — always the jnp reference: the pre-kernel dense context
    gather + host-side K/V scatter.

The knob threads down from ``ModelConfig.attn_kernel`` /
``ServingEngine(attn_kernel=...)`` / ``launch.serve --attn-kernel``.
Deprecated spellings: ``ServingEngine(decode_kernel=...)`` and
``--decode-kernel`` map onto ``attn_kernel`` with a DeprecationWarning,
and ``cfg.decode_kernel`` remains readable as a property.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

# Same probe + mode set as the decode-side kernel: "attn_kernel" selects
# both, so resolve_kernel is single-sourced there.
from repro.kernels.flash_decode.ops import (DECODE_KERNEL_MODES,
                                            resolve_kernel)
from repro.kernels.flash_prefill.flash_prefill import paged_flash_prefill
from repro.kernels.flash_prefill.ref import prefill_attention_ref
from repro.parallel import sharding

ATTN_KERNEL_MODES = DECODE_KERNEL_MODES  # ("auto", "on", "off")


def prefill_attention(q, k_new, v_new, k_pool, v_pool, lengths,
                      block_tables, *, start: Optional[jnp.ndarray] = None,
                      prefix: int = 0, kernel: str = "auto",
                      kv_scales=None, kv_dtype: Optional[str] = None,
                      mesh=None):
    """One layer of paged chunked-prefill attention + new-token K/V scatter.

    q: (B, S, H, D) rotated chunk queries (S = prefix + P, prompt tokens
    LEFT-padded to P); k_new/v_new: (B, S, Hk, D) the chunk's rotated K/V;
    k_pool/v_pool: (N, bs, Hk, D) shared block pool; lengths: (B,) int32
    true chunk token counts; block_tables: (B, T) int32; start: None for
    first chunks, else (B,) int32 cached positions per row; prefix: static
    vlm patch-prefix length (first chunk only); kv_scales + kv_dtype:
    (k_scale, v_scale) (N, bs, Hk) fp32 scale leaves and the payload
    encoding ("int8"/"fp8") of a SCLAD quantized pool — both paths
    dequantize context on load, fake-quantize the chunk's own K/V before
    attending, and write quantized payload + scales (returning the
    5-tuple with k_scale'/v_scale' appended).

    Returns (attn_out (B, S, H*D), k_pool', v_pool').  On the kernel path
    the cached context is streamed through the block table (no dense
    per-lane gather, no dense (B, S, S) mask) and the scatter happens
    inside the kernel; the reference path gathers and scatters host-side,
    bit-exact with the pre-kernel engine.

    mesh: optional mesh with a ``model`` axis — the call then runs under
    ``shard_map`` with the pools (payload AND scale leaves, which are both
    inputs and outputs here: the scatter is fused in), the chunk's new
    K/V, and the query heads sharded over it; tables, lengths, and start
    broadcast.  Ignored when the axis can't split Hk evenly.
    """
    if sharding.attn_shard_size(mesh, k_pool.shape[2]) > 1:
        return _sharded_paged_prefill(q, k_new, v_new, k_pool, v_pool,
                                      lengths, block_tables, start, prefix,
                                      kernel, kv_scales, kv_dtype, mesh)
    use_kernel, interpret = resolve_kernel(kernel)
    if not use_kernel:
        return prefill_attention_ref(q, k_new, v_new, k_pool, v_pool,
                                     lengths, block_tables, start=start,
                                     prefix=prefix, kv_scales=kv_scales,
                                     kv_dtype=kv_dtype)
    B = q.shape[0]
    start_v = jnp.zeros((B,), jnp.int32) if start is None \
        else jnp.asarray(start, jnp.int32)
    return paged_flash_prefill(q, k_new, v_new, k_pool, v_pool, lengths,
                               block_tables, start_v, prefix=prefix,
                               has_ctx=start is not None,
                               interpret=interpret, kv_scales=kv_scales,
                               kv_dtype=kv_dtype)


def _sharded_paged_prefill(q, k_new, v_new, k_pool, v_pool, lengths,
                           block_tables, start, prefix, kernel, kv_scales,
                           kv_dtype, mesh):
    """shard_map chunked prefill over the mesh's ``model`` axis.

    Unlike the decode read, the pools are inputs AND outputs (the new-token
    scatter is fused into the call), so the pool/scale out_specs mirror the
    in_specs — each shard scatters its own Hk/m slice in place and the
    stitched result is exactly the single-device write-back.  The attn
    output is (B, S, H*D) head-major, so concatenating shards on the last
    axis restores full head order.  Tables, lengths, and start (scalar-
    prefetch operands) broadcast.
    """
    sp = sharding.paged_attn_specs()
    args = [q, k_new, v_new, k_pool, v_pool, lengths, block_tables]
    in_specs = [sp["q_chunk"], sp["new_kv"], sp["new_kv"], sp["pool"],
                sp["pool"], sp["host"], sp["host"]]
    out_specs = [sp["out_chunk"], sp["pool"], sp["pool"]]
    has_start = start is not None
    if has_start:
        args.append(jnp.asarray(start, jnp.int32))
        in_specs.append(sp["host"])
    if kv_scales is not None:
        args += list(kv_scales)
        in_specs += [sp["scale"], sp["scale"]]
        out_specs += [sp["scale"], sp["scale"]]

    def body(q, k_new, v_new, k_pool, v_pool, lengths, tables, *rest):
        rest = list(rest)
        start_s = rest.pop(0) if has_start else None
        return prefill_attention(q, k_new, v_new, k_pool, v_pool, lengths,
                                 tables, start=start_s, prefix=prefix,
                                 kernel=kernel,
                                 kv_scales=tuple(rest) or None,
                                 kv_dtype=kv_dtype)

    return sharding.shard_map(body, mesh, in_specs=tuple(in_specs),
                              out_specs=tuple(out_specs),
                              check_vma=False)(*args)
