"""Paged flash-prefill Pallas kernel: chunked-prefill attention straight
out of the block-pool KV cache, with the new-token K/V scatter fused in.

This is the prefill-side twin of ``kernels.flash_decode.paged_flash_decode``
and removes the last dense gather from the serving engine's hot path.  The
chunked-prefill continuation step — the path every prefix-cache hit,
long-prompt chunk and preemption recompute takes — previously materialized,
PER LAYER, a dense per-lane copy of the shared KV pool
(``k_pool[block_tables]``: O(B*T*bs*Hk*D) bytes) plus a host-built dense
(B, S, S+T*bs) mask, then round-tripped the chunk's compacted K/V through
HBM again as a separate ``.at[].set`` scatter.  Here instead:

  * the grid is (batch, kv_heads, T_read + W): the first ``T_read`` steps
    walk the lane's block table on the scalar-prefetch channel
    (``PrefetchScalarGridSpec`` — the index map resolves ``tbl[b, i]``
    BEFORE the body runs), streaming cached context K/V block by block
    straight out of the shared (N, bs, Hk, D) pool with online softmax in
    VMEM scratch (CC-MEM: each cached KV byte crosses HBM exactly once);
  * the causal/left-pad mask is derived INSIDE the kernel from the
    ``start``/``lengths`` scalars and the static ``prefix`` — no dense
    (B, S, S) mask is ever built;
  * step ``T_read`` adds the in-chunk self-attention (keys = this chunk's
    K, masked causally with pad keys dropped), fusing what used to be the
    concatenated tail of the dense mask;
  * the last ``W`` steps SCATTER the chunk's new-token K/V into the pool
    through the table (``input_output_aliases`` pins the pool in place):
    each step merges one destination block — old rows kept, new rows
    placed by a one-hot (bs, S) matmul that folds the left-pad compaction
    (dest ``start + j`` reads padded row ``j + pad``) — so compacted K/V
    never round-trips through HBM as a separate scatter.

Write-target blocks are exclusive to their lane (the engine's grow +
copy-on-write barrier runs before prefill), so the in-place pool update
can never be observed by a concurrently-read shared block; steps whose
block index clamps past the row's real write span re-merge identical
content (idempotent) or copy the old block through unchanged.

CI exercises the kernel in Pallas interpret mode (CPU); the BlockSpecs /
grid are the TPU deployment artifacts and real-TPU validation remains
open (see ROADMAP).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pv_dtype(v):
    """MXU-friendly dtype for the probs @ V matmul: the operand dtype,
    except f8 (too coarse for probabilities) which is computed in bf16."""
    return jnp.bfloat16 if v.dtype == jnp.float8_e4m3fn else v.dtype


def _prefill_kernel(len_ref, start_ref, tbl_ref, q_ref, kn_ref, vn_ref,
                    kp_ref, vp_ref, *rest, bs: int, prefix: int,
                    t_read: int, sm_scale: float, kv_dtype=None):
    """One program = one grid step of one (row, kv_head) pair.

    len/start (B,) and tbl (B, T): scalar-prefetch SMEM (the table also
    drives the pool index maps); q_ref (S*rep, D); kn/vn_ref (S, D): the
    chunk's rotated K/V for THIS kv head; kp/vp_ref (bs, D): this step's
    pool block resolved through the table — cached context on read steps,
    the scatter destination's old content on write steps; o_ref (S*rep, D);
    ko/vo_ref (bs, D): the (aliased) pool block being written back.
    acc/m/l: VMEM scratch carrying the online softmax across the
    (innermost, sequential) grid dimension.

    ``kv_dtype`` ("int8"/"fp8"; None = fp pool) switches on the SCLAD
    layout: ksp/vsp_ref and kso/vso_ref carry the (bs, 1) per-position
    scale tiles riding the same table walk.  Context loads expand
    payload * scale in fp32; the chunk phase fake-quantizes its own K/V
    (matching what the scatter will store, so in-chunk and from-pool
    scoring agree); the scatter phase reproduces
    ``models.kv_quant.quantize`` operation-for-operation so pool bytes are
    bitwise identical to the host-side reference scatter.
    """
    if kv_dtype is not None:
        (ksp_ref, vsp_ref, o_ref, ko_ref, vo_ref, kso_ref, vso_ref,
         acc_ref, m_ref, l_ref) = rest
        qm = 127.0 if kv_dtype == "int8" else 448.0
    else:
        ksp_ref = vsp_ref = kso_ref = vso_ref = None
        o_ref, ko_ref, vo_ref, acc_ref, m_ref, l_ref = rest
        qm = None
    b, i = pl.program_id(0), pl.program_id(2)
    n_i = pl.num_programs(2)
    T = tbl_ref.shape[1]
    S, D = kn_ref.shape
    rows = q_ref.shape[0]
    rep = rows // S
    P = S - prefix
    length = len_ref[b]
    start = start_ref[b]
    pad = P - length

    def fake_quant(x):
        """fp32 (rows, D) -> the value a pool reader will observe: the
        round-trip of ``kv_quant.quantize``/``dequantize`` without the
        payload-dtype container (exact for int8 — round() already yields
        the representable integral grid — and an actual f8 cast for fp8).
        """
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        # Constant multiply, not division — matches kv_quant.quantize
        # bitwise in every tracing context (XLA rewrites /const under jit).
        scale = jnp.where(amax > 0, amax * (1.0 / qm), 1.0)
        qv = x / scale
        if kv_dtype == "int8":
            qv = jnp.round(qv)
        else:
            qv = qv.astype(jnp.float8_e4m3fn).astype(jnp.float32)
        return qv * scale

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def online_update(s, v):
        """Fold scores s (rows, K) and values v (K, D) into acc/m/l."""
        m = m_ref[...]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr \
            + p.astype(_pv_dtype(v)) @ v.astype(_pv_dtype(v))
        m_ref[...] = m_new

    # Context phase: blocks wholly at/beyond the row's cached length are
    # dead (their table entries point at the trash block); context is
    # query-independent — every cached position < start is visible to the
    # whole chunk (all chunk positions are causally after it).
    @pl.when((i < t_read) & (i * bs < start))
    def _ctx():
        q = q_ref[...].astype(jnp.float32) * sm_scale
        k = kp_ref[...].astype(jnp.float32)
        v = vp_ref[...]
        if kv_dtype is not None:
            # Load-as-Dense: (bs, D) payload * (bs, 1) scale in fp32,
            # then ROUNDED to the compute dtype — the reference's
            # ``kv_quant.dequantize(..., q.dtype)`` cast chain, so both
            # implementations attend to bitwise-equal dense values.
            k = (k * ksp_ref[...]).astype(q_ref.dtype) \
                .astype(jnp.float32)
            v = (v.astype(jnp.float32) * vsp_ref[...]).astype(q_ref.dtype)
        s = q @ k.T  # (rows, bs)
        pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(pos < start, s, NEG_INF)
        online_update(s, v)

    # In-chunk self-attention: causal over this call's tokens with pad
    # keys dropped — the mask the pre-kernel path materialized densely,
    # rebuilt here from iota against the start/length scalars.
    @pl.when(i == t_read)
    def _chunk():
        q = q_ref[...].astype(jnp.float32) * sm_scale
        k = kn_ref[...].astype(jnp.float32)
        v = vn_ref[...]
        if kv_dtype is not None:
            # Attend to the chunk's K/V as quantized — identical to how a
            # later chunk / decode step reads it back from the pool.  The
            # compute-dtype round-trip matches ``kv_quant.fake_quant``
            # (which returns x.dtype) bitwise.
            k = fake_quant(k).astype(kn_ref.dtype).astype(jnp.float32)
            v = fake_quant(v.astype(jnp.float32)).astype(vn_ref.dtype)
        s = q @ k.T  # (rows, S)
        qpos = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // rep
        kpos = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)
        real = (kpos < prefix) | (kpos >= prefix + pad)
        s = jnp.where((kpos <= qpos) & real, s, NEG_INF)
        online_update(s, v)

    # Scatter phase: merge one destination block.  Offset o holds cache
    # position w*bs + o = start + j; compacted index j maps back to padded
    # source row j (vlm prefix) or j + pad (prompt tokens).  The one-hot
    # matmul places each valid destination row exactly (0/1 coefficients
    # in fp32 — bit-exact with the host-side scatter after the cast).
    @pl.when(i >= t_read)
    def _scatter():
        w = jnp.minimum(start // bs + (i - t_read), T - 1)
        o = jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
        j = w * bs + o - start
        valid = (j >= 0) & (j < prefix + length)
        src = jnp.where(j < prefix, j, j + pad)
        col = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)
        oh = ((col == src) & valid).astype(jnp.float32)  # (bs, S)
        kvd = ko_ref.dtype
        # The one-hot matmul places each valid destination row EXACTLY
        # (0/1 fp32 coefficients copy the fp32 view of the bf16 row), so
        # the quantization below starts from the same fp32 values as the
        # host-side reference — payload and scales match bitwise.
        new_kf = oh @ kn_ref[...].astype(jnp.float32)  # (bs, D)
        new_vf = oh @ vn_ref[...].astype(jnp.float32)
        if kv_dtype is not None:
            def quant(xf):  # kv_quant.quantize, op-for-op
                amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
                scale = jnp.where(amax > 0, amax * (1.0 / qm), 1.0)
                qv = xf / scale
                if kv_dtype == "int8":
                    qv = jnp.round(qv)
                return qv.astype(kvd), scale
            new_k, ksc = quant(new_kf)
            new_v, vsc = quant(new_vf)
            # Invalid rows quantize garbage (all-zero -> scale 1), but the
            # merge passes the OLD payload/scale through bitwise.
            kso_ref[...] = jnp.where(valid, ksc, ksp_ref[...])
            vso_ref[...] = jnp.where(valid, vsc, vsp_ref[...])
        else:
            new_k = new_kf.astype(kvd)
            new_v = new_vf.astype(kvd)
        ko_ref[...] = jnp.where(valid, new_k, kp_ref[...])
        vo_ref[...] = jnp.where(valid, new_v, vp_ref[...])

    @pl.when(i == n_i - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("prefix", "has_ctx", "interpret",
                                    "kv_dtype"))
def paged_flash_prefill(q, k_new, v_new, k_pool, v_pool, lengths,
                        block_tables, start, *, prefix: int = 0,
                        has_ctx: bool = True, interpret: bool = False,
                        kv_scales=None, kv_dtype=None):
    """Chunked-prefill attention + fused K/V scatter on the paged pool.

    q:             (B, S, H, D) rotated chunk queries (S = prefix + P,
                   prompt tokens LEFT-padded to P);
    k_new/v_new:   (B, S, Hk, D) the chunk's rotated K/V (compute dtype);
    k_pool/v_pool: (N, bs, Hk, D) the SHARED block pool
                   (``model.init_paged_cache`` layout, trash block
                   included) — updated in place via
                   ``input_output_aliases``;
    lengths:       (B,) int32 true chunk token count per row (<= P);
    block_tables:  (B, T) int32 per-lane tables (unallocated entries point
                   at the trash block);
    start:         (B,) int32 cache positions already filled per row;
    prefix:        static vlm patch-prefix length (first chunk only);
    has_ctx:       static — False for first chunks (start == 0 rows): the
                   table-walk read phase is dropped from the grid;
    kv_scales:     optional (k_scale, v_scale) (N, bs, Hk) fp32 scales of a
                   SCLAD quantized pool, with static ``kv_dtype``
                   ("int8"/"fp8") naming the payload encoding.  The scales
                   ride the same table-walked BlockSpecs as the payload
                   (reshaped to (N, bs, Hk, 1) so their tile is 2D) and are
                   aliased in place alongside it; the chunk's new K/V is
                   QUANTIZED IN-KERNEL before the write-back, so compressed
                   bytes are the only thing that round-trips HBM.

    Returns (attn_out (B, S, H*D), k_pool', v_pool') — plus
    (k_scale', v_scale') for quantized pools.  Cached KV bytes are read
    exactly once per chunk, block by block through the table — never
    gathered into a per-lane dense copy — and the new K/V lands in the
    pool inside the same kernel invocation.
    """
    B, S, H, D = q.shape
    Hk = k_new.shape[2]
    rep = H // Hk
    bs = k_pool.shape[1]
    T = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(D)
    quantized = kv_scales is not None
    assert quantized == (kv_dtype is not None)

    qt = q.reshape(B, S, Hk, rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Hk, S * rep, D)
    knt = k_new.transpose(0, 2, 1, 3)  # (B, Hk, S, D)
    vnt = v_new.transpose(0, 2, 1, 3)

    # Writes span <= ceil(S/bs)+1 blocks (the +1 absorbs a start%bs
    # straddle); steps clamped past the table end re-merge idempotently.
    t_read = T if has_ctx else 0
    w_steps = min(T, -(-S // bs) + 1)
    grid = (B, Hk, t_read + w_steps)

    def pool_read_blk(b, h, i, lens, starts, tbl):
        wr = jnp.minimum(starts[b] // bs + (i - t_read), T - 1)
        idx = jnp.where(i < t_read, jnp.minimum(i, T - 1), wr)
        return (tbl[b, idx], 0, h, 0)

    def pool_write_blk(b, h, i, lens, starts, tbl):
        # Parked on the FIRST write block during the read phase so the
        # (unwritten) output buffer is never flushed over a context block.
        j = jnp.maximum(i - t_read, 0)
        return (tbl[b, jnp.minimum(starts[b] // bs + j, T - 1)], 0, h, 0)

    seq_blk = pl.BlockSpec((None, None, S, D),
                           lambda b, h, i, lens, starts, tbl: (b, h, 0, 0))
    pool_rd = pl.BlockSpec((None, bs, None, D), pool_read_blk)
    pool_wr = pl.BlockSpec((None, bs, None, D), pool_write_blk)
    # Scales get a trailing singleton ((N, bs, Hk) -> (N, bs, Hk, 1), a
    # layout-preserving view) so their table-walked tile is 2D (bs, 1).
    scale_rd = pl.BlockSpec((None, bs, None, 1), pool_read_blk)
    scale_wr = pl.BlockSpec((None, bs, None, 1), pool_write_blk)

    in_specs = [
        pl.BlockSpec((None, None, S * rep, D),
                     lambda b, h, i, lens, starts, tbl: (b, h, 0, 0)),
        seq_blk, seq_blk, pool_rd, pool_rd,
    ]
    out_specs = [
        pl.BlockSpec((None, None, S * rep, D),
                     lambda b, h, i, lens, starts, tbl: (b, h, 0, 0)),
        pool_wr, pool_wr,
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, Hk, S * rep, D), q.dtype),
        jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
        jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
    ]
    inputs = [jnp.asarray(lengths, jnp.int32), jnp.asarray(start, jnp.int32),
              jnp.asarray(block_tables, jnp.int32), qt, knt, vnt,
              k_pool, v_pool]
    # Flat input indices (scalar-prefetch leaves included): pools are
    # inputs 6/7 -> outputs 1/2 (and scales 8/9 -> 3/4 when quantized), so
    # every pool update happens in place.
    aliases = {6: 1, 7: 2}
    if quantized:
        k_scale, v_scale = kv_scales
        ks4 = k_scale.astype(jnp.float32)[..., None]
        vs4 = v_scale.astype(jnp.float32)[..., None]
        in_specs += [scale_rd, scale_rd]
        out_specs += [scale_wr, scale_wr]
        out_shape += [jax.ShapeDtypeStruct(ks4.shape, jnp.float32),
                      jax.ShapeDtypeStruct(vs4.shape, jnp.float32)]
        inputs += [ks4, vs4]
        aliases = {6: 1, 7: 2, 8: 3, 9: 4}

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # lengths, start, block_tables
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((S * rep, D), jnp.float32),  # acc
            pltpu.VMEM((S * rep, 1), jnp.float32),  # running max
            pltpu.VMEM((S * rep, 1), jnp.float32),  # running denom
        ],
    )
    results = pl.pallas_call(
        functools.partial(_prefill_kernel, bs=bs, prefix=prefix,
                          t_read=t_read, sm_scale=sm_scale,
                          kv_dtype=kv_dtype),
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*inputs)
    out = results[0].reshape(B, Hk, S, rep, D).transpose(0, 2, 1, 3, 4)
    out = out.reshape(B, S, H * D)
    if quantized:
        _, k_pool, v_pool, ks4, vs4 = results
        return out, k_pool, v_pool, ks4[..., 0], vs4[..., 0]
    _, k_pool, v_pool = results
    return out, k_pool, v_pool
