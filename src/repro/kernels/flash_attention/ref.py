"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, Sq, H, D); k, v: (B, Sk, Hk, D) -> (B, Sq, H, D). fp32 math."""
    B, Sq, H, D = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    rep = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Sq, Hk, rep, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kf) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)
