"""Flash attention (prefill/train) Pallas TPU kernel.

Blockwise causal attention with online softmax.  The grid is
(batch * kv_heads * q_rep, num_q_blocks); each program streams the KV
sequence in VMEM-resident blocks, keeping the working set at
O(block_q * head_dim + block_q * block_k) — this is the CC-MEM insight
mapped to the TPU memory hierarchy: the hot operand (the KV block) lives in
fast memory and is never spilled.

Block shapes are MXU-aligned (multiples of 128 on the lane dim, 8+ on the
sublane dim).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                 sm_scale: float, seq_k: int):
    """One (batch-head, q-block) program: stream KV blocks, online softmax.

    q_ref: (block_q, d); k_ref/v_ref: (seq_k, d); o_ref: (block_q, d).
    """
    block_q, d = q_ref.shape
    q_blk = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale
    q_pos = q_blk * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1), 0)

    num_kv = seq_k // block_k

    def body(i, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # (block_q, block_k)
        if causal:
            k_pos = i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p.astype(v.dtype) @ v
        return acc, m_new, l

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)

    if causal:
        # Skip fully-masked KV blocks: only blocks with start <= q_end run.
        upper = jax.lax.div((q_blk + 1) * block_q + block_k - 1, block_k)
        upper = jnp.minimum(upper, num_kv)
    else:
        upper = num_kv
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Sk, Hk, D), H % Hk == 0 -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    rep = H // Hk
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk)
    sm_scale = 1.0 / math.sqrt(D)

    # Layout: programs over (B * H) with q/k/v transposed to head-major.
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, D)

    grid = (B * H, Sq // block_q)

    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_k=block_k, causal=causal,
                          sm_scale=sm_scale, seq_k=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Sk, D), lambda h, i: (h // rep, 0, 0)),
            pl.BlockSpec((None, Sk, D), lambda h, i: (h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
