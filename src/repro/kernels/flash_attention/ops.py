"""Jit'd public wrapper for flash attention with CPU fallback.

On TPU this calls the Pallas kernel; on CPU (tests, smoke runs) it uses
interpret mode for small shapes and the jnp oracle otherwise.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, *, causal: bool = True, block_q: int = 128,
              block_k: int = 128):
    # default_backend honors JAX_PLATFORMS and does not force eager device
    # enumeration (unlike jax.devices()[0].platform).
    if jax.default_backend() == "tpu":
        return flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k)
    return attention_ref(q, k, v, causal=causal)
