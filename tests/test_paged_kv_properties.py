"""Ref-counted block store: randomized property sweeps (needs hypothesis).

The deterministic pins of the same invariants live in test_paged_kv.py so
they run even without hypothesis; these traces sweep the state space:

  * refcounts never go negative and always equal the number of owning lanes;
  * a block is freed iff its refcount hits zero AND it leaves the LRU pool
    (the free/pool/live partition in ``check_invariants``);
  * prefix sharing is sound: lanes share block ``i`` only when their
    contents agree on every token through block ``i``;
  * release (the preemption path) frees exactly the non-shared blocks;
  * truncate (the speculative-rollback path) frees exactly the exclusive
    over-length blocks — never into the LRU pool — and live-block
    accounting stays exact under arbitrary truncate/grow interleavings.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.paged import (BlockStore, OutOfBlocks, TRASH_BLOCK,
                                 chain_hashes, chain_root_for)

# Shared with the frontend interleaving suite (which also runs seeded,
# hypothesis-free traces); the helper itself has no hypothesis dependency.
from paged_invariants import shared_prefix_sound as _shared_prefix_sound


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_random_traces_preserve_invariants(data):
    """Drive a random admit/grow/commit/cow/truncate/release trace over a
    tiny token alphabet (so prefix collisions actually happen); check every
    invariant after every operation."""
    num_blocks = data.draw(st.integers(2, 24), label="num_blocks")
    bs = data.draw(st.integers(1, 4), label="block_size")
    num_slots = data.draw(st.integers(1, 5), label="num_slots")
    width = data.draw(st.integers(1, 8), label="table_width")
    store = BlockStore(num_blocks, bs, num_slots, width)

    contents = {}  # slot -> full intended token sequence
    lens = {}      # slot -> grown length (mirror)
    for _ in range(data.draw(st.integers(1, 50), label="n_ops")):
        op = data.draw(st.sampled_from(
            ["admit", "grow", "commit", "cow", "truncate", "release"]))
        if op == "admit":
            free_slots = [s for s in range(num_slots) if s not in lens]
            if not free_slots:
                continue
            slot = data.draw(st.sampled_from(free_slots))
            n = data.draw(st.integers(1, width * bs), label="content_len")
            content = data.draw(st.lists(
                st.integers(0, 1), min_size=n, max_size=n), label="content")
            cached = store.admit(slot, content,
                                 max_cached_tokens=len(content) - 1)
            assert cached % bs == 0
            assert cached <= len(content) - 1 or cached == 0
            contents[slot] = content
            lens[slot] = cached
        elif op == "grow" and lens:
            slot = data.draw(st.sampled_from(sorted(lens)))
            target = data.draw(
                st.integers(lens[slot], len(contents[slot])), label="target")
            try:
                fresh = store.grow(slot, target)
                assert all(b != TRASH_BLOCK for b in fresh)
                # New blocks are exclusive: refcount exactly 1.
                assert all(store.ref_count(b) == 1 for b in fresh)
                lens[slot] = target
            except OutOfBlocks:
                # Optimistic admission: the engine would preempt.  The
                # store must stay consistent; replay the grown length.
                lens[slot] = store.seq_len(slot)
        elif op == "commit" and lens:
            slot = data.draw(st.sampled_from(sorted(lens)))
            store.commit_full(slot, contents[slot][:lens[slot]])
        elif op == "cow" and lens:
            slot = data.draw(st.sampled_from(sorted(lens)))
            if lens[slot] == 0:
                continue
            pos = data.draw(st.integers(0, lens[slot] - 1), label="pos")
            others = {s: list(b) for s, b in store._blocks.items()
                      if s != slot}
            try:
                mv = store.ensure_writable(slot, pos)
            except OutOfBlocks:
                continue
            if mv is not None:
                src, dst = mv
                # COW isolation: nobody else's table changed, and the
                # fresh block is reachable only by the writer.
                for s, b in others.items():
                    assert store._blocks[s] == b
                    assert dst not in b
                assert store.ref_count(dst) == 1
        elif op == "truncate" and lens:
            slot = data.draw(st.sampled_from(sorted(lens)))
            new_len = data.draw(st.integers(0, lens[slot]), label="new_len")
            owned = list(store._blocks[slot])
            refs = {b: store.ref_count(b) for b in owned}
            cut = owned[store.blocks_for(new_len):]
            dropped = store.truncate(slot, new_len)
            # Exactly the exclusive over-length blocks are freed — and a
            # rolled-back block never lands in the LRU pool (its tail
            # bytes are untrusted; a stale digest must not revive it).
            assert sorted(dropped) == sorted(
                b for b in cut if refs[b] == 1)
            assert all(b not in store._pool for b in dropped)
            for b in cut:
                if refs[b] > 1:
                    assert store.ref_count(b) == refs[b] - 1
            lens[slot] = new_len
        elif op == "release" and lens:
            slot = data.draw(st.sampled_from(sorted(lens)))
            before = {b: store.ref_count(b) for b in store._blocks[slot]}
            dropped = store.release(slot)
            # Exactly the non-shared blocks left live ownership.
            assert sorted(dropped) == sorted(
                b for b, r in before.items() if r == 1)
            for b, r in before.items():
                if r > 1:
                    assert store.ref_count(b) == r - 1  # never negative
            del lens[slot]
            del contents[slot]
        store.check_invariants()
        _shared_prefix_sound(store, contents)
        assert store.available == store.num_blocks - store.live_blocks


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_no_false_sharing_across_kv_dtypes(data):
    """For ANY content, digests hashed under one pool encoding's chain
    root never match a store built for another encoding: an int8 block's
    compressed payload is not the fp block's bytes, so cross-encoding
    hash hits would revive wrong KV.  Same-encoding matching must keep
    working (the control)."""
    bs = data.draw(st.integers(1, 4), label="block_size")
    n_blocks = data.draw(st.integers(1, 4), label="n_full_blocks")
    n = n_blocks * bs
    content = data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n),
                        label="content")
    own, other = data.draw(st.sampled_from(
        [("fp", "int8"), ("int8", "fp"), ("int8", "fp8"), ("fp8", "int8")]),
        label="encodings")
    store = BlockStore(num_blocks=n_blocks + 1, block_size=bs, num_slots=2,
                       max_blocks_per_slot=n_blocks + 1, kv_dtype=own)
    store.admit(0, content)
    store.grow(0, n)
    store.commit_full(0, content)
    foreign = chain_hashes(content, bs, seed=chain_root_for(other))
    assert store.match_digests(foreign) == (0, 0)
    native = chain_hashes(content, bs, seed=chain_root_for(own))
    assert store.match_digests(native)[0] == n_blocks
