"""End-to-end behaviour tests: per-arch smoke (reduced configs) + training.

Covers the assigned-architecture deliverable: every arch instantiates a
reduced same-family config, runs one forward and one train step on CPU, and
asserts output shapes + finiteness; decode agrees with the full-sequence
oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, list_archs
from repro.models import model as M
from repro.launch import steps as steps_lib
from repro.training import optimizer as opt_lib

ARCHS = list_archs()


def make_batch(cfg, B, S, key=0, labels=False):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S + 1), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if labels:
        batch["labels"] = toks[:, 1:S + 1]
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 1),
            (B, cfg.num_patches, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 2),
            (B, cfg.encdec.encoder_seq_len, cfg.d_model)).astype(jnp.bfloat16)
    return batch, toks


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch, _ = make_batch(cfg, B, S)
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt_lib.init(params)
    step = jax.jit(steps_lib.make_train_step(cfg))
    batch, _ = make_batch(cfg, 2, 16, labels=True)
    params2, opt2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # Parameters actually moved.
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved
    assert int(opt2.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward_oracle(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch, toks = make_batch(cfg, B, S)
    full = dict(batch, tokens=toks[:, :S + 1])
    logits_full, _ = M.forward(cfg, params, full)
    _, cache = M.prefill(cfg, params, batch, max_len=32)
    logits_dec, _ = M.decode_step(cfg, params, cache, toks[:, S:S + 1],
                                  jnp.int32(S))
    got = np.asarray(logits_dec[:, 0], np.float32)
    want = np.asarray(logits_full[:, S], np.float32)
    scale = np.max(np.abs(want)) + 1e-9
    # SSM families accumulate differently in the chunked vs step form (bf16).
    tol = 0.05 if cfg.family in ("ssm", "hybrid") else 1e-2
    assert np.max(np.abs(got - want)) / scale < tol


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_cache_extends(arch):
    """Two decode steps after prefill: cache layout stays consistent."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    batch, toks = make_batch(cfg, B, S)
    _, cache = M.prefill(cfg, params, batch, max_len=16)
    l1, cache = M.decode_step(cfg, params, cache, toks[:, S:S + 1],
                              jnp.int32(S))
    l2, cache = M.decode_step(cfg, params, cache,
                              jnp.argmax(l1, -1).astype(jnp.int32),
                              jnp.int32(S + 1))
    assert l2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(l2.astype(jnp.float32))))


def test_loss_decreases_tinyllama():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt_lib.init(params)
    step = jax.jit(steps_lib.make_train_step(
        cfg, opt_lib.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=30)))
    batch, _ = make_batch(cfg, 4, 32, labels=True)
    losses = []
    for _ in range(12):  # same batch -> loss must fall
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_shape_grid_definition():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524288
    # long_500k runs only for sub-quadratic archs.
    for arch in ARCHS:
        cfg = get_config(arch)
        ok, why = cfg.shape_supported("long_500k")
        assert ok == cfg.sub_quadratic, (arch, why)
