"""Continuous batching: slot admission, per-row decode, greedy bit-identity.

Acceptance: >= 2 concurrent requests with different prompt lengths AND
different completion lengths decode through one shared jitted masked step,
with per-request outputs bit-identical (greedy) to running each request
alone through ``model.prefill`` + scalar-position ``model.decode_step``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine

MAX_LEN = 32


def _make(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def tiny():
    return _make("tinyllama-1.1b")


def solo_greedy(cfg, params, prompt, max_new):
    """Reference: one request alone via prefill + scalar-position decode."""
    batch = {"tokens": jnp.asarray(np.asarray(prompt)[None], jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (1, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    logits, cache = M.prefill(cfg, params, batch, max_len=MAX_LEN)
    toks, pos = [], len(prompt)
    for _ in range(max_new):
        t = int(jnp.argmax(logits.reshape(-1)))
        toks.append(t)
        logits, cache = M.decode_step(
            cfg, params, cache, jnp.full((1, 1), t, jnp.int32),
            jnp.int32(pos))
        logits = logits[:, 0]
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
def test_continuous_bit_identical_to_solo(arch):
    """Mixed prompt lengths AND mixed completion budgets in one batch."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n) for n in (5, 9, 13)]
    budgets = (4, 6, 3)
    eng = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN, eos_id=-1)
    assert eng.mode == "continuous"
    uids = [eng.submit(p, max_new_tokens=m) for p, m in zip(prompts, budgets)]
    out = eng.run()
    # All three decode concurrently through the shared step: total steps is
    # the longest budget, not the sum.
    assert eng.stats.decode_steps == max(budgets)
    for uid, p, m in zip(uids, prompts, budgets):
        assert out[uid] == solo_greedy(cfg, params, p, m)


def test_slot_freed_by_eos_is_reused(tiny):
    cfg, params = tiny
    p1, p2 = np.arange(1, 9), np.arange(3, 10)
    # Probe the first greedy token of p1, then make it the EOS id.
    probe = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                          eos_id=-1)
    probe.submit(p1, max_new_tokens=1)
    eos = list(probe.run().values())[0][0]

    eng = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                        eos_id=eos)
    u1 = eng.submit(p1, max_new_tokens=8)
    u2 = eng.submit(p2, max_new_tokens=3)  # queued behind the only slot
    out = eng.run()
    assert out[u1] == [eos]  # retired on EOS long before its budget
    assert eng.stats.admissions == 2  # the freed slot was re-admitted
    expect = solo_greedy(cfg, params, p2, 3)
    # p2 may also hit the probed EOS token; compare up to retirement.
    cut = expect.index(eos) + 1 if eos in expect else len(expect)
    assert out[u2] == expect[:cut]


def test_more_requests_than_slots(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(1, cfg.vocab_size, size=int(rng.integers(3, 14))),
             int(rng.integers(2, 6))) for _ in range(7)]
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN, eos_id=-1)
    uids = [eng.submit(p, max_new_tokens=m) for p, m in reqs]
    out = eng.run()
    assert eng.stats.admissions == 7  # every request got a slot eventually
    assert 0.0 < eng.stats.slot_occupancy <= 1.0
    for uid, (p, m) in zip(uids, reqs):
        assert out[uid] == solo_greedy(cfg, params, p, m)


def test_step_api_incremental(tiny):
    """step() returns finished requests as they retire, not at drain."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN, eos_id=-1)
    u_short = eng.submit(np.arange(1, 6), max_new_tokens=2)
    u_long = eng.submit(np.arange(1, 9), max_new_tokens=5)
    finished = {}
    steps_at_finish = {}
    n = 0
    while len(finished) < 2:
        n += 1
        for uid, toks in eng.step():
            finished[uid] = toks
            steps_at_finish[uid] = n
    assert steps_at_finish[u_short] == 2
    assert steps_at_finish[u_long] == 5
    assert len(finished[u_short]) == 2 and len(finished[u_long]) == 5


def test_prefill_slots_and_reset_slot_primitives(tiny):
    """Slot-level cache ops: targeted write, bit-identical logits, reset."""
    cfg, params = tiny
    prompt = np.arange(1, 8)  # length 7, bucket-padded to 8
    cache = M.init_cache(cfg, 2, MAX_LEN)
    P = 8
    toks = np.zeros((1, P), np.int32)
    toks[0, P - len(prompt):] = prompt  # left-pad
    logits, cache = M.prefill_slots(
        cfg, params, cache, jnp.asarray(toks),
        jnp.asarray([len(prompt)], jnp.int32), jnp.asarray([1], jnp.int32))

    # Slot 0 untouched, slot 1 populated at offsets [0, len).
    assert not np.any(np.asarray(cache["k"][:, 0]))
    assert np.any(np.asarray(cache["k"][:, 1, :len(prompt)]))
    assert not np.any(np.asarray(cache["k"][:, 1, P:]))

    # Left-pad-masked prefill is bit-identical to the unpadded prefill.
    ref_logits, ref_cache = M.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None], jnp.int32)},
        max_len=MAX_LEN)
    np.testing.assert_array_equal(np.asarray(logits[0]),
                                  np.asarray(ref_logits[0]))
    np.testing.assert_array_equal(
        np.asarray(cache["k"][:, 1, :len(prompt)]),
        np.asarray(ref_cache["k"][:, 0, :len(prompt)]))

    cache = M.reset_slot(cache, 1)
    assert not np.any(np.asarray(cache["k"])), "reset_slot must zero the row"


def test_moe_dispatch_valid_mask_frees_capacity():
    """Dead lanes (retired slots, pads) must not displace live tokens from
    expert capacity buffers."""
    from repro.models import moe as moe_lib

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    # 10 single-assignment tokens all routed to expert 0, capacity 4.
    experts = jnp.zeros((1, 10, 1), jnp.int32)
    C = 4
    # Dead tokens FIRST: without a mask they eat the whole capacity.
    slot_unmasked, _ = moe_lib._dispatch_indices(cfg, experts, C)
    assert int(slot_unmasked[0, 8, 0]) == C  # live token dropped
    valid = jnp.asarray([[False] * 8 + [True] * 2])
    slot, _ = moe_lib._dispatch_indices(cfg, experts, C, valid)
    assert (np.asarray(slot[0, :8, 0]) == C).all()  # dead -> drop bin
    assert int(slot[0, 8, 0]) == 0 and int(slot[0, 9, 0]) == 1  # live kept


def test_engine_threads_serve_shardings(tiny):
    """mesh= places params/cache with the serve layout; results unchanged."""
    from jax.sharding import Mesh
    from repro.parallel import sharding as sh

    cfg, params = tiny
    prompt = np.arange(1, 9)
    ref = solo_greedy(cfg, params, prompt, 3)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    try:
        eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                            eos_id=-1, mesh=mesh)
        uid = eng.submit(prompt, max_new_tokens=3)
        assert eng.run()[uid] == ref
    finally:
        # set_mesh_axis_sizes is module-global: restore the no-mesh state.
        class _NoMesh:
            axis_names = ()
            devices = np.zeros((1,))
        sh.set_mesh_axis_sizes(_NoMesh())


def test_decode_step_vector_positions(tiny):
    """Rows at different offsets through one decode_step == scalar decode."""
    cfg, params = tiny
    pa, pb = np.arange(1, 7), np.arange(2, 12)  # lengths 6 and 10

    def solo_next(prompt):
        logits, cache = M.prefill(
            cfg, params, {"tokens": jnp.asarray(prompt[None], jnp.int32)},
            max_len=MAX_LEN)
        t = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits2, _ = M.decode_step(cfg, params, cache, t[:, None],
                                   jnp.int32(len(prompt)))
        return int(t[0]), np.asarray(logits2[0, 0])

    ta, la = solo_next(pa)
    tb, lb = solo_next(pb)

    cache = M.init_cache(cfg, 2, MAX_LEN)
    toks = np.zeros((2, 16), np.int32)
    toks[0, 16 - 6:] = pa
    toks[1, 16 - 10:] = pb
    logits, cache = M.prefill_slots(
        cfg, params, cache, jnp.asarray(toks),
        jnp.asarray([6, 10], jnp.int32), jnp.asarray([0, 1], jnp.int32))
    t = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert (int(t[0]), int(t[1])) == (ta, tb)
    logits2, _ = M.decode_step(cfg, params, cache, t[:, None],
                               jnp.asarray([6, 10], jnp.int32))
    np.testing.assert_array_equal(np.asarray(logits2[:, 0]),
                                  np.stack([la, lb]))
