"""Continuous batching: paged KV admission, per-row decode, bit-identity.

Acceptance: >= 2 concurrent requests with different prompt lengths AND
different completion lengths decode through one shared jitted masked step
over a PAGED (block-table) KV cache, with per-request outputs bit-identical
(greedy) to running each request alone through ``model.prefill`` +
scalar-position ``model.decode_step`` — including when prompts are
prefilled in chunks interleaved with in-flight decodes, when blocks are
SHARED through the prefix cache (concurrent sharers, LRU revival after the
donor retired), when the pool over-commits and the engine preempts, and
when ``decode_steps > 1`` amortizes the host sync.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.paged import BlockStore

MAX_LEN = 32


def _make(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def tiny():
    return _make("tinyllama-1.1b")


def solo_greedy(cfg, params, prompt, max_new):
    """Reference: one request alone via prefill + scalar-position decode."""
    batch = {"tokens": jnp.asarray(np.asarray(prompt)[None], jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (1, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    logits, cache = M.prefill(cfg, params, batch, max_len=MAX_LEN)
    toks, pos = [], len(prompt)
    for _ in range(max_new):
        t = int(jnp.argmax(logits.reshape(-1)))
        toks.append(t)
        logits, cache = M.decode_step(
            cfg, params, cache, jnp.full((1, 1), t, jnp.int32),
            jnp.int32(pos))
        logits = logits[:, 0]
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
def test_continuous_bit_identical_to_solo(arch):
    """Mixed prompt lengths AND mixed completion budgets in one batch."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n) for n in (5, 9, 13)]
    budgets = (4, 6, 3)
    eng = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN, eos_id=-1)
    assert eng.mode == "continuous"
    uids = [eng.submit(p, max_new_tokens=m) for p, m in zip(prompts, budgets)]
    out = eng.run()
    # All three decode concurrently through the shared step: total steps is
    # the longest budget, not the sum.
    assert eng.stats.decode_steps == max(budgets)
    for uid, p, m in zip(uids, prompts, budgets):
        assert out[uid] == solo_greedy(cfg, params, p, m)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
@pytest.mark.parametrize("block_size,chunk", [(4, 4), (8, 16)])
def test_paged_chunked_bit_identical_to_solo(arch, block_size, chunk):
    """The paged allocator + chunked prefill matrix: long and short prompts
    share the block pool, prompts longer than ``chunk`` prefill across
    several interleaved calls — outputs stay bit-identical to solo."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, size=n)
               for n in (3, 17, 6, 21)]  # mixed long/short
    budgets = (5, 3, 4, 6)
    eng = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN, eos_id=-1,
                        block_size=block_size, prefill_chunk=chunk)
    uids = [eng.submit(p, max_new_tokens=m) for p, m in zip(prompts, budgets)]
    out = eng.run()
    # Long prompts really were chunked (admission interleaves with decode).
    assert eng.stats.prefill_chunks > 1
    for uid, p, m in zip(uids, prompts, budgets):
        assert out[uid] == solo_greedy(cfg, params, p, m)
    # Everything retired: every block is back on the free list.
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0


def test_long_prompt_admitted_mid_decode(tiny):
    """A long prompt admitted while short requests decode must (a) not stall
    them — its prefill chunks interleave with their decode steps — and (b)
    come out bit-identical to its solo run."""
    cfg, params = tiny
    rng = np.random.default_rng(3)
    short = [rng.integers(1, cfg.vocab_size, size=4) for _ in range(2)]
    long = rng.integers(1, cfg.vocab_size, size=24)
    eng = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN, eos_id=-1,
                        block_size=4, prefill_chunk=4)
    u_short = [eng.submit(p, max_new_tokens=8) for p in short]
    done = {}
    for _ in range(2):  # shorts are mid-decode...
        for uid, toks in eng.step():
            done[uid] = toks
    steps_before = eng.stats.decode_steps
    assert steps_before == 2
    u_long = eng.submit(long, max_new_tokens=3)  # ...when the long arrives
    while len(done) < 3:
        for uid, toks in eng.step():
            done[uid] = toks
    # The shorts kept decoding during the long prompt's 6 prefill chunks:
    # they finish their 8 tokens after 8 decode steps, strictly before the
    # long request (6 chunks + 3 decode steps from its admission).
    assert done[u_long] == solo_greedy(cfg, params, long, 3)
    for uid, p in zip(u_short, short):
        assert done[uid] == solo_greedy(cfg, params, p, 8)
    assert eng.stats.prefill_chunks >= 1 + 6  # shorts together + 24/4 chunks


def test_block_pool_admits_beyond_stripe_capacity(tiny):
    """Block-granular admission: with a pool worth 2 full stripes, THREE
    short requests run concurrently because each reserves only its own
    blocks — the fragmentation win over per-slot striping."""
    cfg, params = tiny
    rng = np.random.default_rng(4)
    # 2 stripes of MAX_LEN=32 tokens = 16 blocks of 4; each request needs
    # ceil((4 + 6)/4) = 3 blocks, so 3 requests fit with room to spare.
    prompts = [rng.integers(1, cfg.vocab_size, size=4) for _ in range(3)]
    eng = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN, eos_id=-1,
                        block_size=4, num_blocks=2 * (MAX_LEN // 4))
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    out = eng.run()
    assert eng.stats.decode_steps == 6  # all three decoded concurrently
    assert eng.stats.mean_active_requests == 3.0
    for uid, p in zip(uids, prompts):
        assert out[uid] == solo_greedy(cfg, params, p, 6)


def test_slot_freed_by_eos_is_reused(tiny):
    cfg, params = tiny
    p1, p2 = np.arange(1, 9), np.arange(3, 10)
    # Probe the first greedy token of p1, then make it the EOS id.
    probe = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                          eos_id=-1)
    probe.submit(p1, max_new_tokens=1)
    eos = list(probe.run().values())[0][0]

    eng = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                        eos_id=eos)
    u1 = eng.submit(p1, max_new_tokens=8)
    u2 = eng.submit(p2, max_new_tokens=3)  # queued behind the only slot
    out = eng.run()
    assert out[u1] == [eos]  # retired on EOS long before its budget
    assert eng.stats.admissions == 2  # the freed slot was re-admitted
    expect = solo_greedy(cfg, params, p2, 3)
    # p2 may also hit the probed EOS token; compare up to retirement.
    cut = expect.index(eos) + 1 if eos in expect else len(expect)
    assert out[u2] == expect[:cut]


def test_more_requests_than_slots(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(1, cfg.vocab_size, size=int(rng.integers(3, 14))),
             int(rng.integers(2, 6))) for _ in range(7)]
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN, eos_id=-1)
    uids = [eng.submit(p, max_new_tokens=m) for p, m in reqs]
    out = eng.run()
    assert eng.stats.admissions == 7  # every request got a slot eventually
    assert 0.0 < eng.stats.slot_occupancy <= 1.0
    for uid, (p, m) in zip(uids, reqs):
        assert out[uid] == solo_greedy(cfg, params, p, m)


def test_step_api_incremental(tiny):
    """step() returns finished requests as they retire, not at drain."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN, eos_id=-1)
    u_short = eng.submit(np.arange(1, 6), max_new_tokens=2)
    u_long = eng.submit(np.arange(1, 9), max_new_tokens=5)
    finished = {}
    steps_at_finish = {}
    n = 0
    while len(finished) < 2:
        n += 1
        for uid, toks in eng.step():
            finished[uid] = toks
            steps_at_finish[uid] = n
    assert steps_at_finish[u_short] == 2
    assert steps_at_finish[u_long] == 5
    assert len(finished[u_short]) == 2 and len(finished[u_long]) == 5


def test_prefill_slots_paged_primitives(tiny):
    """Block-level cache ops: the prefill writes land ONLY in the blocks
    the row's table names, in position order, bit-identical to the dense
    reference cache."""
    cfg, params = tiny
    prompt = np.arange(1, 8)  # length 7, bucket-padded to 8
    bs = 4
    alloc = BlockStore(num_blocks=8, block_size=bs, num_slots=2,
                       max_blocks_per_slot=MAX_LEN // bs)
    cache = M.init_paged_cache(cfg, alloc.num_blocks + 1, bs)
    alloc.admit(1)
    alloc.grow(1, len(prompt))  # 2 blocks: positions 0..3, 4..6
    P = 8
    toks = np.zeros((1, P), np.int32)
    toks[0, P - len(prompt):] = prompt  # left-pad
    tables = jnp.asarray(alloc.block_table()[[1]])
    logits, cache = M.prefill_slots(
        cfg, params, cache, jnp.asarray(toks),
        jnp.asarray([len(prompt)], jnp.int32), tables)

    owned = list(np.asarray(alloc.block_table()[1, :2]))
    k = np.asarray(cache["k"], np.float32)
    # Only the two owned blocks hold data: trash (0) and the free pool are
    # untouched (junk-tail writes are dropped, not spilled).
    for b in range(alloc.num_blocks + 1):
        assert np.any(k[:, b]) == (b in owned), f"block {b}"

    # Block-gathered K == the dense reference cache, position for position,
    # and the last-token logits are bit-identical to unpadded prefill.
    ref_logits, ref_cache = M.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None], jnp.int32)},
        max_len=MAX_LEN)
    np.testing.assert_array_equal(np.asarray(logits[0]),
                                  np.asarray(ref_logits[0]))
    gathered = k[:, owned].reshape(k.shape[0], 2 * bs, *k.shape[3:])
    np.testing.assert_array_equal(
        gathered[:, :len(prompt)],
        np.asarray(ref_cache["k"][:, 0, :len(prompt)], np.float32))

    # Release: blocks return to the pool, table row points at trash.
    freed = alloc.release(1)
    assert sorted(freed) == sorted(owned)
    assert alloc.live_blocks == 0
    assert (alloc.block_table() == 0).all()
    alloc.check_invariants()


def test_moe_dispatch_valid_mask_frees_capacity():
    """Dead lanes (retired slots, pads) must not displace live tokens from
    expert capacity buffers."""
    from repro.models import moe as moe_lib

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    # 10 single-assignment tokens all routed to expert 0, capacity 4.
    experts = jnp.zeros((1, 10, 1), jnp.int32)
    C = 4
    # Dead tokens FIRST: without a mask they eat the whole capacity.
    slot_unmasked, _ = moe_lib._dispatch_indices(cfg, experts, C)
    assert int(slot_unmasked[0, 8, 0]) == C  # live token dropped
    valid = jnp.asarray([[False] * 8 + [True] * 2])
    slot, _ = moe_lib._dispatch_indices(cfg, experts, C, valid)
    assert (np.asarray(slot[0, :8, 0]) == C).all()  # dead -> drop bin
    assert int(slot[0, 8, 0]) == 0 and int(slot[0, 9, 0]) == 1  # live kept


def test_engine_threads_serve_shardings(tiny):
    """mesh= places params/cache with the serve layout; results unchanged.
    Axis state is engine-scoped (context-var), so the ambient sharding
    state is untouched by building and running a meshed engine."""
    from jax.sharding import Mesh
    from repro.parallel import sharding as sh

    cfg, params = tiny
    prompt = np.arange(1, 9)
    ref = solo_greedy(cfg, params, prompt, 3)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    ambient_before = sh.axis_state()
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                        eos_id=-1, mesh=mesh)
    uid = eng.submit(prompt, max_new_tokens=3)
    assert eng.run()[uid] == ref
    assert eng._axes.sizes == (("data", 1), ("model", 1))
    assert sh.axis_state() == ambient_before, \
        "engine leaked mesh axis state into the ambient context"


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
def test_prefix_cache_bit_identical_on_vs_off(arch):
    """Greedy outputs are bit-identical with prefix caching on vs off,
    including (a) two requests sharing a prefix CONCURRENTLY and (b) a
    request admitted after its prefix donor retired (LRU revival)."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(7)
    shared = rng.integers(1, cfg.vocab_size, size=13)  # > 1 full block
    prompts = [np.concatenate([shared, rng.integers(1, cfg.vocab_size,
                                                    size=n)])
               for n in (3, 5, 2)]
    budgets = (4, 3, 5)

    def run(prefix_cache):
        eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                            eos_id=-1, block_size=4, prefill_chunk=8,
                            prefix_cache=prefix_cache)
        # First two share the prefix CONCURRENTLY (2 lanes); the third is
        # admitted only after a donor retired, so its hit revives pooled
        # blocks.
        uids = [eng.submit(p, max_new_tokens=m)
                for p, m in zip(prompts, budgets)]
        out = eng.run()
        return eng, [out[u] for u in uids]

    eng_off, out_off = run(False)
    eng_on, out_on = run(True)
    assert out_on == out_off
    for out, p, m in zip(out_on, prompts, budgets):
        assert out == solo_greedy(cfg, params, p, m)
    # The cache actually did something: prompt tokens were skipped, and
    # the post-retirement admission revived pooled blocks.
    assert eng_off.stats.cached_prompt_tokens == 0
    assert eng_on.stats.cached_prompt_tokens > 0
    assert eng_on.stats.prefix_hit_rate > 0
    assert eng_on._alloc.lru_hits > 0
    eng_on._alloc.check_invariants()


def test_concurrent_sharers_hold_live_references(tiny):
    """A request admitted while its prefix donor is STILL DECODING shares
    the donor's live blocks (refcount >= 2 observed mid-run); outputs stay
    bit-identical to solo."""
    cfg, params = tiny
    rng = np.random.default_rng(11)
    shared = rng.integers(1, cfg.vocab_size, size=12)  # 3 full 4-blocks
    p1 = np.concatenate([shared, rng.integers(1, cfg.vocab_size, size=3)])
    p2 = np.concatenate([shared, rng.integers(1, cfg.vocab_size, size=2)])
    p3 = np.concatenate([shared, rng.integers(1, cfg.vocab_size, size=4)])
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                        eos_id=-1, block_size=4, prefill_chunk=None)
    # p1 (long budget) and p2 (short) enter cold; p3 is admitted onto p2's
    # freed lane while p1 is still mid-decode and shares p1's live blocks.
    uids = [eng.submit(p, max_new_tokens=m)
            for p, m in zip((p1, p2, p3), (8, 2, 3))]
    done, max_ref = {}, 0
    while len(done) < 3:
        for uid, toks in eng.step():
            done[uid] = toks
        if eng._alloc._ref:
            max_ref = max(max_ref, max(eng._alloc._ref.values()))
    assert max_ref >= 2, "prefix blocks were never concurrently shared"
    for uid, p, m in zip(uids, (p1, p2, p3), (8, 2, 3)):
        assert done[uid] == solo_greedy(cfg, params, p, m)
    eng._alloc.check_invariants()


def test_preemption_recompute_bit_identical(tiny):
    """Optimistic admission over-commits a small pool; the engine preempts
    the youngest request and recomputes it — final outputs bit-identical
    to an unpressured run."""
    cfg, params = tiny
    rng = np.random.default_rng(8)
    reqs = [(rng.integers(1, cfg.vocab_size, size=5), 16) for _ in range(3)]

    def run(num_blocks):
        eng = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                            eos_id=-1, block_size=4, num_blocks=num_blocks)
        uids = [eng.submit(p, max_new_tokens=m) for p, m in reqs]
        out = eng.run()
        return eng, [out[u] for u in uids]

    eng_big, ref = run(num_blocks=24)  # worst case fits: no pressure
    assert eng_big.stats.preemptions == 0
    # 3 lanes admit on prompt need (2 blocks each) but grow to
    # ceil((5+16)/4) = 6 blocks each = 18 > 10: preemption must kick in.
    eng_small, out = run(num_blocks=10)
    assert eng_small.stats.preemptions >= 1
    assert out == ref
    for out_i, (p, m) in zip(out, reqs):
        assert out_i == solo_greedy(cfg, params, p, m)
    eng_small._alloc.check_invariants()
    assert eng_small._alloc.live_blocks == 0


@pytest.mark.parametrize("k", [2, 3])
def test_decode_steps_bit_identical(tiny, k):
    """decode_steps=k runs k decode iterations per host sync with masked
    early-exit on retirement; outputs match the single-step engine even
    when budgets are not multiples of k."""
    cfg, params = tiny
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab_size, size=n) for n in (4, 9, 6)]
    budgets = (5, 7, 1)  # deliberately not multiples of k

    eng = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                        eos_id=-1, decode_steps=k)
    uids = [eng.submit(p, max_new_tokens=m) for p, m in zip(prompts, budgets)]
    out = eng.run()
    for uid, p, m in zip(uids, prompts, budgets):
        assert out[uid] == solo_greedy(cfg, params, p, m)
    # Host syncs amortize: ceil(max_budget / k) windows of k iterations.
    assert eng.stats.decode_steps == -(-max(budgets) // k) * k
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0


def test_submit_rejects_impossible_request(tiny):
    """A request whose worst case exceeds what the pool/block table can
    EVER hold is rejected at submit with a clear error, not silently
    clamped or left to starve the queue."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                        eos_id=-1, block_size=4, num_blocks=3)
    with pytest.raises(ValueError, match="KV blocks"):
        eng.submit(np.arange(1, 14), max_new_tokens=8)  # needs 6 > 3 blocks
    # Oversized prompts keep the dedicated message.
    with pytest.raises(ValueError, match="decode room"):
        eng.submit(np.arange(1, MAX_LEN + 2), max_new_tokens=1)
    # The pool was never touched.
    assert eng._alloc.live_blocks == 0
    assert eng.stats.admissions == 0


def test_zero_budget_request_retires_without_touching_pool(tiny):
    """max_new_tokens=0 completes immediately with an empty output — no
    admission, no blocks, no decode steps."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                        eos_id=-1)
    u0 = eng.submit(np.arange(1, 6), max_new_tokens=0)
    u1 = eng.submit(np.arange(1, 6), max_new_tokens=2)
    out = eng.run()
    assert out[u0] == []
    assert out[u1] == solo_greedy(cfg, params, np.arange(1, 6), 2)
    assert eng.stats.admissions == 1  # only the real request
    # step() also delivers instant retirements when nothing else runs.
    u2 = eng.submit(np.arange(1, 4), max_new_tokens=0)
    assert eng.step() == [(u2, [])]
    assert eng._alloc.live_blocks == 0


def test_decode_step_vector_positions_paged(tiny):
    """Rows at different offsets through one block-table decode_step ==
    scalar decode on the dense reference cache."""
    cfg, params = tiny
    pa, pb = np.arange(1, 7), np.arange(2, 12)  # lengths 6 and 10

    def solo_next(prompt):
        logits, cache = M.prefill(
            cfg, params, {"tokens": jnp.asarray(prompt[None], jnp.int32)},
            max_len=MAX_LEN)
        t = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits2, _ = M.decode_step(cfg, params, cache, t[:, None],
                                   jnp.int32(len(prompt)))
        return int(t[0]), np.asarray(logits2[0, 0])

    ta, la = solo_next(pa)
    tb, lb = solo_next(pb)

    bs = 8
    alloc = BlockStore(num_blocks=8, block_size=bs, num_slots=2,
                       max_blocks_per_slot=MAX_LEN // bs)
    cache = M.init_paged_cache(cfg, alloc.num_blocks + 1, bs)
    alloc.admit(0)
    alloc.admit(1)
    alloc.grow(0, 6)
    alloc.grow(1, 10)
    toks = np.zeros((2, 16), np.int32)
    toks[0, 16 - 6:] = pa
    toks[1, 16 - 10:] = pb
    logits, cache = M.prefill_slots(
        cfg, params, cache, jnp.asarray(toks),
        jnp.asarray([6, 10], jnp.int32), jnp.asarray(alloc.block_table()))
    t = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert (int(t[0]), int(t[1])) == (ta, tb)
    alloc.grow(0, 7)
    alloc.grow(1, 11)
    logits2, _ = M.decode_step(cfg, params, cache, t[:, None],
                               jnp.asarray([6, 10], jnp.int32),
                               block_tables=jnp.asarray(alloc.block_table()))
    np.testing.assert_array_equal(np.asarray(logits2[:, 0]),
                                  np.stack([la, lb]))
