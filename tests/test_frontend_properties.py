"""Randomized interleavings of submit / cancel / drain on AsyncFrontend.

The pump is never started: each trace drives the frontend's serialized
engine interaction directly (``fe._tick()`` standing in for the pump
thread, then ``fe._dispatch()`` for the event loop), so every
interleaving is a deterministic schedule — no wall clocks, no thread
races.

The trace core is written against a tiny draw interface so it runs two
ways: seeded ``random.Random`` traces ALWAYS run (this is the tier-1
gate), and the same core sweeps under hypothesis where it is installed
(shrinking a failing trace to its minimal prefix).

Properties checked after EVERY tick and at drain:

  * ``BlockStore`` invariants hold and shared blocks imply identical
    content prefixes (``shared_prefix_sound``, shared with the paged-KV
    property suite — the frontend must not be able to corrupt the pool);
  * no token loss: a completed stream's queue drains to exactly the
    engine's final token list (``ticket.result``), one token per budget;
  * cancelled streams end at a prefix (never over-deliver, never hang);
  * engine uids are never duplicated across admitted requests;
  * refcounts are zero at drain: ``live_blocks == 0``, every rejection
    was a real backpressure rejection at full depth, and the stats
    ledger balances (completed + cancelled == accepted).
"""
import asyncio
import random

import numpy as np
import pytest

import jax

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.frontend import AsyncFrontend, CircuitBreaker, RejectedError
from paged_invariants import shared_prefix_sound

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_ENG = None


def _eng():
    """One module-lifetime engine: jit traces compile once, every trace
    reuses them (a fresh engine per trace would recompile its jitted
    step and turn each trace into minutes)."""
    global _ENG
    if _ENG is None:
        cfg = get_config("tinyllama-1.1b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        # 3 lanes x up to 4 blocks vs a 10-block pool: full interleavings
        # over-commit, so preemption/recompute paths are exercised too.
        _ENG = ServingEngine(cfg, params, max_batch=3, max_len=32,
                             eos_id=-1, block_size=4, num_blocks=10,
                             prefill_chunk=8)
    return _ENG


def _never_trips():
    """The breaker is unit-tested elsewhere; here it must not reject, so
    admission outcomes depend only on queue depth."""
    return CircuitBreaker(window=4096, trip_pressure=4096,
                          sat_threshold=2.0)


def _lane_contents(eng):
    """slot -> canonical cache contents for shared_prefix_sound; blocks
    only ever cover a prefix of these, which is all the helper compares."""
    contents = {}
    for i, r in enumerate(eng._slot_req):
        if r is not None:
            contents[i] = eng._content_ids(r)
    for s in eng._prefilling:
        contents[s.lane] = eng._content_ids(s.req)
    return contents


class _SeededDraw:
    """random.Random-backed draw source (always available)."""

    def __init__(self, seed):
        self._r = random.Random(seed)

    def ints(self, lo, hi, label=""):
        return self._r.randint(lo, hi)

    def maybe_int(self, lo, hi, label=""):
        if self._r.random() < 0.4:
            return None
        return self._r.randint(lo, hi)


class _HypothesisDraw:
    """hypothesis ``st.data()``-backed draw source (shrinks traces)."""

    def __init__(self, data):
        self._data = data

    def ints(self, lo, hi, label=""):
        return self._data.draw(st.integers(lo, hi), label=label)

    def maybe_int(self, lo, hi, label=""):
        return self._data.draw(st.one_of(st.none(), st.integers(lo, hi)),
                               label=label)


def _run_interleaving(d):
    eng = _eng()
    depth = d.ints(2, 5, label="max_queue_depth")
    fe = AsyncFrontend(eng, max_queue_depth=depth, breaker=_never_trips())
    # The pump is never started, so wire the streaming hook the way
    # ``start()`` would (undone in the finally).
    eng.on_token = fe._on_token
    n = d.ints(1, 5, label="n_requests")
    specs = []
    for k in range(n):
        plen = d.ints(4, 8, label=f"plen{k}")
        # Tiny alphabet: prefix collisions (and thus block sharing) are
        # common, not astronomically rare.
        prompt = np.array([d.ints(1, 4, label=f"tok{k}")
                           for _ in range(plen)], np.int32)
        specs.append({
            "prompt": prompt,
            "budget": d.ints(1, 5, label=f"budget{k}"),
            "submit_tick": d.ints(0, 4, label=f"submit{k}"),
            "cancel_delay": d.maybe_int(0, 6, label=f"cancel{k}"),
        })
    streams, rejected = {}, set()
    try:
        for tick in range(80):
            for k, sp in enumerate(specs):
                if sp["submit_tick"] == tick:
                    try:
                        streams[k] = asyncio.run(fe.submit(
                            sp["prompt"], max_new_tokens=sp["budget"]))
                    except RejectedError as e:
                        # Only backpressure can reject, and only at depth.
                        assert e.kind == "backpressure"
                        assert fe.queue_depth == depth
                        rejected.add(k)
                if (k in streams and sp["cancel_delay"] is not None
                        and tick == sp["submit_tick"] + sp["cancel_delay"]):
                    asyncio.run(streams[k].aclose())
            fe._dispatch(fe._tick())
            eng._alloc.check_invariants()
            shared_prefix_sound(eng._alloc, _lane_contents(eng))
            assert fe.queue_depth <= depth
            done_submitting = tick >= max(sp["submit_tick"]
                                          for sp in specs)
            if done_submitting and not fe._inflight \
                    and not fe._has_engine_work():
                break
        else:
            raise AssertionError("trace did not drain in 80 ticks")
    finally:
        # Leave the shared engine clean for the next trace even when an
        # assertion above fired mid-flight.
        for s in streams.values():
            asyncio.run(s.aclose())
        for _ in range(80):
            if not fe._has_engine_work() and not fe._inflight:
                break
            fe._dispatch(fe._tick())
        eng.on_token = None

    # -- drain-time properties ----------------------------------------------
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0, "refcounts must be zero at drain"
    uids = [s.uid for s in streams.values() if s.uid is not None]
    assert len(uids) == len(set(uids)), "duplicate engine uids"
    for k, s in streams.items():
        toks = asyncio.run(s.collect())
        assert s._ticket.queue.qsize() == 0, "tokens after the terminator"
        if s.done:  # completed (eos_id=-1: always exactly the budget)
            assert toks == s._ticket.result
            assert len(toks) == specs[k]["budget"]
        else:       # cancelled mid-flight: a prefix, never over-delivery
            assert s._ticket.cancelled
            assert len(toks) <= specs[k]["budget"]
    # Ledger balances: every accepted request completed or was cancelled.
    assert fe.stats.rejected_backpressure == len(rejected)
    assert fe.stats.accepted == n - len(rejected)
    assert fe.stats.completed + fe.stats.cancelled == fe.stats.accepted
    assert fe.stats.errors == 0


@pytest.mark.parametrize("seed", range(8))
def test_seeded_interleavings(seed):
    """Tier-1: fixed-seed traces of the same core — run everywhere."""
    _run_interleaving(_SeededDraw(seed))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.data())
    def test_hypothesis_interleavings(data):
        _run_interleaving(_HypothesisDraw(data))
else:
    @pytest.mark.skip(reason="hypothesis not installed; the seeded "
                             "traces above cover the same core")
    def test_hypothesis_interleavings():
        pass
