"""Ref-counted paged KV block store: deterministic invariant pins.

``serving.paged.BlockStore`` backs prefix caching + optimistic admission in
the serving engine.  These pins run without hypothesis (the randomized
sweeps of the same invariants live in test_paged_kv_properties.py):

  * refcounts never go negative and redistribute correctly under sharing,
    copy-on-write and release;
  * a block is freed iff its refcount hits zero AND it leaves the LRU
    retired pool;
  * copy-on-write never mutates a block another lane can read;
  * release (the preemption path) frees exactly the non-shared blocks;
  * the retired pool evicts oldest-first and revives as LRU hits.
"""
import numpy as np
import pytest

from repro.serving.paged import (BlockStore, CHAIN_ROOT, OutOfBlocks,
                                 TRASH_BLOCK, chain_hashes, chain_root_for)


def test_prefix_sharing_and_cow_isolation():
    """Two lanes admitted with the same content share every full block;
    copy-on-write gives the writer a fresh block and leaves the reader's
    view untouched."""
    bs, n_blocks_each = 2, 3
    n = n_blocks_each * bs
    content = list(np.arange(1, n + 1))
    store = BlockStore(num_blocks=4 * n_blocks_each + 2, block_size=bs,
                       num_slots=2, max_blocks_per_slot=n_blocks_each + 2)
    assert store.admit(0, content) == 0  # cold: nothing registered yet
    store.grow(0, n)
    store.commit_full(0, content)
    cached = store.admit(1, content)  # warm: every full block hits
    assert cached == n
    assert store.hit_blocks == n_blocks_each
    donor = list(store._blocks[0])
    assert store._blocks[1] == donor  # physically shared
    assert all(store.ref_count(b) == 2 for b in donor)
    # Sharing is memory, not tokens: 3 live blocks serve 12 logical tokens.
    assert store.live_blocks == n_blocks_each
    assert store.live_tokens == 2 * n
    store.check_invariants()

    # COW on a shared position: lane 1 gets a fresh block, lane 0 keeps
    # the original, refcount redistributes 2 -> 1+1.
    mv = store.ensure_writable(1, 0)
    assert mv is not None
    src, dst = mv
    assert src == donor[0] and dst != src
    assert store._blocks[0][0] == src, "COW mutated the reader's table"
    assert store.ref_count(src) == 1 and store.ref_count(dst) == 1
    assert dst not in store._blocks[0]
    assert store.cow_copies == 1
    store.check_invariants()

    # The un-shared tail write needs no copy.
    store.grow(1, n + 1)
    assert store.ensure_writable(1, n) is None
    store.check_invariants()

    # Release the sharer: only ITS exclusive blocks drop out; the donor's
    # blocks stay live with refcount 1 (preemption releases exactly the
    # non-shared blocks).
    exclusive = [b for b in store._blocks[1] if store.ref_count(b) == 1]
    dropped = store.release(1)
    assert sorted(dropped) == sorted(exclusive)
    assert all(store.ref_count(b) == 1 for b in donor)
    store.check_invariants()


def test_release_pools_registered_blocks_and_lru_revives():
    """Retired registered blocks park in the LRU pool (not the free list)
    and a same-prefix admission revives them as an LRU hit."""
    bs = 2
    content = [5, 6, 7, 8]
    store = BlockStore(num_blocks=6, block_size=bs, num_slots=2,
                       max_blocks_per_slot=4)
    store.admit(0, content)
    store.grow(0, 4)
    store.commit_full(0, content)
    blocks = list(store._blocks[0])
    dropped = store.release(0)
    assert sorted(dropped) == sorted(blocks)
    assert store.pooled_blocks == 2 and store.num_free == 4
    assert store.live_blocks == 0  # pooled blocks are reclaimable

    cached = store.admit(1, content, max_cached_tokens=3)
    assert cached == 2  # capped to one block (always recompute the tail)
    assert store._blocks[1] == [blocks[0]]
    assert store.lru_hits == 1
    store.check_invariants()


def test_lru_eviction_is_oldest_first():
    """Allocation pressure blanks the OLDEST retiree; newer retirees stay
    matchable."""
    bs = 1
    store = BlockStore(num_blocks=4, block_size=bs, num_slots=2,
                       max_blocks_per_slot=4)
    store.admit(0, [1, 2])
    store.grow(0, 2)
    store.commit_full(0, [1, 2])
    store.release(0)          # retires the [1], [1,2] chains (oldest)
    store.admit(0, [7, 8])
    store.grow(0, 2)
    store.commit_full(0, [7, 8])
    store.release(0)          # retires the [7], [7,8] chains (newest)
    assert store.pooled_blocks == 4 and store.num_free == 0

    # Two fresh exclusive blocks evict the two oldest pooled blocks.
    store.admit(1)
    store.grow(1, 2)
    assert store.evictions == 2
    # The [1, 2] chain is gone; the [7, 8] chain still matches.
    assert store.match_prefix([1, 2]) == 0
    assert store.match_prefix([7, 8]) == 2
    store.check_invariants()


def test_out_of_blocks_and_width_bounds():
    store = BlockStore(num_blocks=2, block_size=2, num_slots=2,
                       max_blocks_per_slot=4)
    store.admit(0)
    store.grow(0, 4)  # both blocks
    store.admit(1)
    with pytest.raises(OutOfBlocks):
        store.grow(1, 1)
    store.release(0)  # unregistered blocks -> straight to the free list
    assert store.num_free == 2
    store.grow(1, 1)  # now fine
    with pytest.raises(ValueError):
        store.grow(1, 9)  # beyond the table width
    with pytest.raises(ValueError):
        store.grow(1, 0)  # sequences cannot shrink
    with pytest.raises(ValueError):
        store.admit(1)  # double admit
    store.release(1)
    with pytest.raises(ValueError):
        store.release(1)  # double release


def test_partial_grow_failure_keeps_state_consistent():
    """A grow that runs dry mid-way keeps the blocks it did assign (the
    engine retries after preemption and continues where it left off)."""
    store = BlockStore(num_blocks=3, block_size=1, num_slots=2,
                       max_blocks_per_slot=8)
    store.admit(0)
    store.grow(0, 2)
    store.admit(1)
    with pytest.raises(OutOfBlocks):
        store.grow(1, 3)  # gets 1 of 3, then dry
    store.check_invariants()
    assert store.seq_len(1) == 1  # rounded to what it holds
    store.release(0)
    store.grow(1, 3)  # retry completes
    store.check_invariants()


def test_prefix_cache_disabled_degenerates_to_plain_allocator():
    store = BlockStore(num_blocks=4, block_size=2, num_slots=2,
                       max_blocks_per_slot=4, prefix_cache=False)
    content = [1, 2, 3, 4]
    assert store.admit(0, content) == 0
    store.grow(0, 4)
    assert store.commit_full(0, content) == 0
    store.release(0)
    assert store.pooled_blocks == 0 and store.num_free == 4
    assert store.admit(1, content) == 0  # nothing ever matches
    store.check_invariants()


def test_table_rows_match_block_order():
    """The device table maps position p to row blocks[p // bs]; the
    unallocated tail stays trash."""
    bs, lens = 3, [4, 7, 1]
    width = -(-max(lens) // bs)
    store = BlockStore(num_blocks=sum(-(-n // bs) for n in lens),
                       block_size=bs, num_slots=len(lens),
                       max_blocks_per_slot=width)
    for slot, n in enumerate(lens):
        store.admit(slot)
        store.grow(slot, n)
    table = store.block_table()
    for slot, n in enumerate(lens):
        k = -(-n // bs)
        assert list(table[slot, :k]) == store._blocks[slot]
        assert TRASH_BLOCK not in table[slot, :k]
        assert (table[slot, k:] == TRASH_BLOCK).all()
    store.check_invariants()


def test_chain_hash_commits_to_whole_prefix():
    """Same block content under a different prefix must NOT collide."""
    a = chain_hashes([1, 2, 3, 4], 2)
    b = chain_hashes([9, 9, 3, 4], 2)
    assert a[1] != b[1]
    assert chain_hashes([1, 2, 3], 2) == a[:1]  # partial tail: no digest


def test_chain_root_namespaced_by_kv_dtype():
    """The pool encoding is part of the content address: quantized stores
    hash from a kv_dtype-derived root; fp-family spellings keep the
    historic root so existing digests stay valid."""
    assert chain_root_for("fp") == CHAIN_ROOT
    assert chain_root_for("bf16") == CHAIN_ROOT
    assert chain_root_for("f8") == CHAIN_ROOT
    roots = {chain_root_for(d) for d in ("fp", "int8", "fp8")}
    assert len(roots) == 3
    content = [1, 2, 3, 4]
    fp = chain_hashes(content, 2)
    i8 = chain_hashes(content, 2, seed=chain_root_for("int8"))
    f8 = chain_hashes(content, 2, seed=chain_root_for("fp8"))
    assert fp[0] != i8[0] and fp[0] != f8[0] and i8[0] != f8[0]


def test_quantized_store_shares_within_not_across_encoding():
    """An int8 store's lanes share prefix blocks exactly as an fp store's
    do — but digests hashed under a DIFFERENT kv_dtype root never match
    its registrations (an int8 block's payload bytes are not the fp
    block's, so cross-encoding revival would serve wrong KV)."""
    bs, nb = 2, 3
    n = nb * bs
    content = list(np.arange(1, n + 1))
    store = BlockStore(num_blocks=4 * nb + 2, block_size=bs, num_slots=2,
                      max_blocks_per_slot=nb + 2, kv_dtype="int8")
    assert store.chain_root == chain_root_for("int8")
    assert store.admit(0, content) == 0
    store.grow(0, n)
    store.commit_full(0, content)
    # Intra-encoding sharing is untouched: a second int8 lane hits fully.
    assert store.admit(1, content) == n
    assert store.hit_blocks == nb
    store.check_invariants()
    # Digests hashed under the fp root (or another quantized root) find
    # nothing in the int8 store's index.
    for other in (CHAIN_ROOT, chain_root_for("fp8")):
        foreign = chain_hashes(content, bs, seed=other)
        assert store.match_digests(foreign) == (0, 0)
    # Symmetric: an fp store never serves int8-rooted digests.
    fp_store = BlockStore(num_blocks=4 * nb + 2, block_size=bs, num_slots=2,
                          max_blocks_per_slot=nb + 2)
    assert fp_store.chain_root == CHAIN_ROOT
    fp_store.admit(0, content)
    fp_store.grow(0, n)
    fp_store.commit_full(0, content)
    i8_digests = chain_hashes(content, bs, seed=chain_root_for("int8"))
    assert fp_store.match_digests(i8_digests) == (0, 0)
    assert fp_store.match_prefix(content) == nb  # same-root control


def test_truncate_rolls_back_across_block_boundary():
    """Speculative rollback past a block edge: dropped blocks go to the
    FREE list (never the LRU pool), the now-partial boundary block is
    unregistered, and a later commit re-hashes the suffix the lane
    actually wrote instead of reviving the stale chain."""
    bs = 4
    store = BlockStore(num_blocks=8, block_size=bs, num_slots=2,
                       max_blocks_per_slot=4)
    content = list(range(1, 11))  # 10 tokens = 2 full blocks + a partial
    store.admit(0, content)
    store.grow(0, 10)
    store.commit_full(0, content)  # registers the 2 full blocks
    free_before = store.num_free
    dropped = store.truncate(0, 5)  # rewind into block 1
    assert len(dropped) == 1  # blocks_for(5) = 2: the partial 3rd freed
    assert store.seq_len(0) == 5 and store.owned_blocks(0) == 2
    assert store.num_free == free_before + 1
    assert store.pooled_blocks == 0, "rolled-back block must not be pooled"
    b0, b1 = store._blocks[0]
    assert b0 in store._hash, "untouched full block keeps its digest"
    assert b1 not in store._hash, (
        "partial boundary block's tail is rolled-back bytes — digest "
        "must not bind")
    assert len(store._chain[0]) == 1  # suffix digests invalidated
    store.check_invariants()
    # The lane regrows and writes a DIFFERENT suffix: commit_full hashes
    # what was written, not the stale pre-rollback chain.
    store.grow(0, 8)
    rewritten = content[:5] + [77, 78, 79]
    store.commit_full(0, rewritten)
    assert store._chain[0] == chain_hashes(rewritten, bs)
    assert store._hash[b1] == store._chain[0][1]
    store.check_invariants()


def test_truncate_shared_boundary_block_leaves_donor_intact():
    """Rolling back INTO a shared block never mutates it: the COW barrier
    guarantees this lane never wrote it, so its registration and every
    other owner's view survive."""
    bs = 4
    store = BlockStore(num_blocks=8, block_size=bs, num_slots=2,
                       max_blocks_per_slot=3)
    content = list(range(1, 9))  # exactly 2 full blocks
    store.admit(0, content)
    store.grow(0, 8)
    store.commit_full(0, content)
    assert store.admit(1, content) == 8  # full prefix hit: shares both
    donor = list(store._blocks[0])
    store.grow(1, 10)  # lane 1 drafts into a 3rd, exclusive block
    dropped = store.truncate(1, 6)  # reject the draft: rewind mid-block 1
    assert len(dropped) == 1  # only the exclusive draft block freed
    assert store._blocks[1] == donor, "rollback must not swap shared blocks"
    assert store.ref_count(donor[1]) == 2
    assert donor[1] in store._hash, (
        "shared boundary block keeps its digest — its content still "
        "matches (this lane never wrote it)")
    assert store.seq_len(0) == 8 and store.seq_len(1) == 6
    store.check_invariants()
    store.release(0)
    # Donor's view was truly untouched: its full chain still matches.
    assert store.match_prefix(content) == 2
    store.check_invariants()


def test_truncate_dropped_digest_cannot_revive_stale_prefix():
    """A REGISTERED block rolled back wholly out of a lane is freed and
    unregistered: a new request with the identical content must re-hit
    only the surviving prefix, never the dropped block's stale digest."""
    bs = 4
    store = BlockStore(num_blocks=6, block_size=bs, num_slots=2,
                       max_blocks_per_slot=2)
    content = list(range(1, 9))
    store.admit(0, content)
    store.grow(0, 8)
    store.commit_full(0, content)  # both blocks registered
    dropped = store.truncate(0, 4)  # second (registered) block dropped
    assert len(dropped) == 1
    assert store.match_prefix(content) == 1, (
        "dropped block's digest must leave the prefix index")
    cached = store.admit(1, content)
    assert cached == 4  # only block 0 revives; the tail re-prefills
    store.check_invariants()
    # Rewind-to-zero edge: every block freed, slot stays admitted.
    store.truncate(1, 0)
    assert store.seq_len(1) == 0 and store.owned_blocks(1) == 0
    store.check_invariants()
    store.release(1)
    store.check_invariants()


def test_truncate_validates_slot_and_length():
    store = BlockStore(num_blocks=4, block_size=2, num_slots=2,
                       max_blocks_per_slot=2)
    store.admit(0)
    store.grow(0, 3)
    with pytest.raises(ValueError):
        store.truncate(1, 0)  # not admitted
    with pytest.raises(ValueError):
        store.truncate(0, 4)  # beyond grown length
    with pytest.raises(ValueError):
        store.truncate(0, -1)
    assert store.truncate(0, 3) == []  # no-op keeps everything
    store.check_invariants()
