"""Paged KV block allocator properties (needs hypothesis).

Random submit/decode/retire traces against ``serving.paged.BlockAllocator``
pin the invariants the serving engine leans on:

  * no block is ever assigned to two lanes at once;
  * released blocks return to the free list (nothing leaks);
  * live-block count always equals the sum of per-lane sequence lengths
    rounded up to block size (allocation is exactly lazy);
  * a reservation made at admission can always be grown into — ``grow``
    never runs the pool dry mid-decode.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.paged import TRASH_BLOCK, BlockAllocator


def _expected_live(alloc, lens):
    return sum(-(-n // alloc.block_size) for n in lens.values())


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_random_traces_preserve_invariants(data):
    """Drive a random admit/grow/release trace; check every invariant after
    every operation."""
    num_blocks = data.draw(st.integers(2, 40), label="num_blocks")
    bs = data.draw(st.integers(1, 8), label="block_size")
    num_slots = data.draw(st.integers(1, 6), label="num_slots")
    width = data.draw(st.integers(1, 12), label="table_width")
    alloc = BlockAllocator(num_blocks, bs, num_slots, width)

    lens = {}      # slot -> current seq len (mirror of the allocator)
    reserved = {}  # slot -> reserved token budget
    for _ in range(data.draw(st.integers(1, 40), label="n_ops")):
        op = data.draw(st.sampled_from(["admit", "grow", "release"]))
        if op == "admit":
            free_slots = [s for s in range(num_slots) if s not in lens]
            if not free_slots:
                continue
            slot = data.draw(st.sampled_from(free_slots))
            tokens = data.draw(st.integers(1, width * bs), label="tokens")
            if alloc.can_admit(tokens):
                alloc.admit(slot, tokens)
                lens[slot] = 0
                reserved[slot] = tokens
            else:
                with pytest.raises(ValueError):
                    alloc.admit(slot, tokens)
        elif op == "grow" and lens:
            slot = data.draw(st.sampled_from(sorted(lens)))
            # Decode-style growth: anywhere up to the reservation.
            new_len = data.draw(
                st.integers(lens[slot], reserved[slot]), label="new_len")
            fresh = alloc.grow(slot, new_len)
            lens[slot] = new_len
            assert all(b != TRASH_BLOCK for b in fresh)
        elif op == "release" and lens:
            slot = data.draw(st.sampled_from(sorted(lens)))
            freed = alloc.release(slot)
            assert len(freed) == -(-lens[slot] // bs)
            del lens[slot]
            del reserved[slot]
        alloc.check_invariants()
        assert alloc.live_blocks == _expected_live(alloc, lens)
        assert alloc.num_free == num_blocks - alloc.live_blocks


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(0, 10_000))
def test_grow_within_reservation_never_fails(bs, seed):
    """Admission guarantees: once admitted, every lane can grow to its full
    reservation even when the pool is otherwise fully reserved."""
    rng = np.random.default_rng(seed)
    num_slots, width = 4, 8
    alloc = BlockAllocator(num_blocks=num_slots * width, block_size=bs,
                           num_slots=num_slots, max_blocks_per_slot=width)
    budgets = {}
    for slot in range(num_slots):
        tokens = int(rng.integers(1, width * bs + 1))
        if alloc.can_admit(tokens):
            alloc.admit(slot, tokens)
            budgets[slot] = tokens
    # Interleave single-token growth across lanes (decode order is
    # arbitrary); nothing may ever raise.
    heads = {s: 0 for s in budgets}
    while any(heads[s] < budgets[s] for s in budgets):
        live = [s for s in budgets if heads[s] < budgets[s]]
        s = live[int(rng.integers(len(live)))]
        heads[s] += 1
        alloc.grow(s, heads[s])
        alloc.check_invariants()
    for s in budgets:
        alloc.release(s)
    alloc.check_invariants()
    assert alloc.live_blocks == 0 and alloc.num_free == alloc.num_blocks


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.lists(st.integers(1, 30), min_size=1,
                                   max_size=12))
def test_block_table_rows_match_position_order(bs, lens):
    """The table maps position p to row blocks[p // bs]: entries appear in
    allocation order, unallocated tail stays trash."""
    width = -(-max(lens) // bs)
    alloc = BlockAllocator(num_blocks=sum(-(-n // bs) for n in lens),
                           block_size=bs, num_slots=len(lens),
                           max_blocks_per_slot=width)
    for slot, n in enumerate(lens):
        alloc.admit(slot, n)
        alloc.grow(slot, n)
    table = alloc.block_table()
    seen = set()
    for slot, n in enumerate(lens):
        blocks = table[slot, :-(-n // bs)]
        assert TRASH_BLOCK not in blocks
        assert not (set(blocks.tolist()) & seen), "row shares a block"
        seen |= set(blocks.tolist())
        assert (table[slot, -(-n // bs):] == TRASH_BLOCK).all()
    alloc.check_invariants()


def test_reservation_blocks_oversubscription():
    """can_admit prices the worst case: a pool of 4 blocks holds two
    2-block requests but not a third, until one retires."""
    alloc = BlockAllocator(num_blocks=4, block_size=4, num_slots=3,
                           max_blocks_per_slot=4)
    assert alloc.can_admit(8)
    alloc.admit(0, 8)
    alloc.admit(1, 8)
    assert not alloc.can_admit(1)  # fully reserved though nothing is live
    with pytest.raises(ValueError):
        alloc.admit(2, 1)
    alloc.grow(0, 3)  # lazy: one live block, reservation unchanged
    assert alloc.live_blocks == 1
    alloc.release(0)
    assert alloc.can_admit(8)


def test_shrink_and_overgrow_rejected():
    alloc = BlockAllocator(num_blocks=4, block_size=2, num_slots=1,
                           max_blocks_per_slot=4)
    alloc.admit(0, 4)
    alloc.grow(0, 3)
    with pytest.raises(ValueError):
        alloc.grow(0, 2)  # sequences cannot shrink
    with pytest.raises(ValueError):
        alloc.grow(0, 5)  # beyond the admission reservation
    with pytest.raises(ValueError):
        alloc.admit(0, 1)  # double admit
    alloc.release(0)
    with pytest.raises(ValueError):
        alloc.release(0)  # double release
