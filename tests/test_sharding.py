"""Sharding rules: spec/tree alignment, divisibility sanitation (property
tests), serve-vs-train layouts, roofline HLO parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, list_archs
from repro.core import roofline
from repro.launch import specs as specs_lib
from repro.models import model as M
from repro.parallel import sharding


class FakeMesh:
    axis_names = ("data", "model")
    class devices:
        shape = (16, 16)


def setup_module(_m=None):
    sharding.set_mesh_axis_sizes(FakeMesh())


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_param_specs_cover_tree(arch, mode):
    cfg = get_config(arch)
    pshape = M.param_specs(cfg)
    spec = sharding.param_specs(cfg, pshape, mode=mode)
    spec = sharding.sanitize_specs(spec, pshape)
    flat_s = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree.leaves(pshape)
    assert len(flat_s) == len(flat_p)
    for s, p in zip(flat_s, flat_p):
        assert isinstance(s, P)
        assert len(s) <= len(p.shape)
        # Post-sanitation: every sharded dim divides evenly.
        for i, axes in enumerate(s):
            if axes is None:
                continue
            axes_t = axes if isinstance(axes, tuple) else (axes,)
            size = int(np.prod([dict(data=16, model=16, pod=2).get(a, 1)
                                for a in axes_t]))
            assert p.shape[i] % size == 0, (s, p.shape)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 200), min_size=1, max_size=4),
       st.integers(0, 2))
def test_sanitize_never_leaves_undivisible(dims, which):
    spec = P(*["data" if i == which else None for i in range(len(dims))])
    leaf = jax.ShapeDtypeStruct(tuple(dims), jnp.float32)
    out = sharding.sanitize_specs(spec, leaf)
    for i, axes in enumerate(out):
        if axes is not None:
            assert dims[i] % 16 == 0


def test_serve_mode_drops_fsdp():
    cfg = get_config("tinyllama-1.1b")
    pshape = M.param_specs(cfg)
    train = sharding.param_specs(cfg, pshape, mode="train")
    serve = sharding.param_specs(cfg, pshape, mode="serve")
    # wq: train has both axes, serve only model.
    assert train["blocks"]["attn"]["wq"] == P(None, "data", "model")
    assert serve["blocks"]["attn"]["wq"] == P(None, None, "model")


def test_moe_expert_layout_is_ep_x_tp():
    # EP over data, TP over the d_model dim (matches apply_moe_manual's
    # d-sliced all-to-all payloads).
    cfg = get_config("qwen3-moe-235b-a22b")
    pshape = M.param_specs(cfg)
    for mode in ("train", "serve"):
        spec = sharding.param_specs(cfg, pshape, mode=mode)
        assert spec["blocks"]["moe"]["w_gate"] == P(None, "data", "model",
                                                    None)
        assert spec["blocks"]["moe"]["w_down"] == P(None, "data", None,
                                                    "model")


@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_input_specs_shapes(shape_name):
    cfg = get_config("tinyllama-1.1b")
    ins = specs_lib.input_specs(cfg, shape_name)
    assert "params" in ins
    if shape_name == "train_4k":
        assert ins["batch"]["tokens"].shape == (256, 4096)
        assert ins["batch"]["labels"].shape == (256, 4096)
    elif shape_name == "prefill_32k":
        assert ins["batch"]["tokens"].shape == (32, 32768)
    else:
        assert ins["tokens"].shape == (128, 1)
        assert ins["cache"]["k"].shape[2] == 32768


# ---------------------------------------------------------------------------
# Roofline HLO parsing
# ---------------------------------------------------------------------------

def test_parse_collectives_synthetic():
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag = bf16[256,64]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}
  %cp = f32[8]{0} collective-permute(%z)
  %other = f32[4]{0} add(%a, %b)
"""
    stats = roofline.parse_collectives(hlo, total_devices=256)
    assert set(stats.by_op) == {"all-reduce", "all-gather",
                                "collective-permute"}
    ar = stats.by_op["all-reduce"]
    assert ar[1] == 16 * 128 * 4  # result bytes
    # ring all-reduce wire factor 2*(g-1)/g with g=16
    assert np.isclose(ar[2], 16 * 128 * 4 * 2 * 15 / 16 * 256)
    ag = stats.by_op["all-gather"]
    assert ag[1] == 256 * 64 * 2
    assert np.isclose(ag[2], 256 * 64 * 2 * 3 / 4 * 256)


def test_roofline_terms_bottleneck():
    t = roofline.RooflineTerms(flops=197e12 * 256, bytes_hbm=0.0,
                               wire_bytes=0.0, chips=256)
    assert np.isclose(t.t_compute, 1.0)
    assert t.bottleneck == "compute"
    t2 = roofline.RooflineTerms(flops=0, bytes_hbm=819e9 * 256 * 2,
                                wire_bytes=0, chips=256)
    assert t2.bottleneck == "memory" and np.isclose(t2.t_memory, 2.0)


@given(st.floats(1, 1e18), st.floats(1, 1e18), st.floats(1, 1e18))
def test_roofline_bound_is_max(f, b, w):
    t = roofline.RooflineTerms(flops=f, bytes_hbm=b, wire_bytes=w, chips=256)
    assert np.isclose(t.t_bound,
                      max(t.t_compute, t.t_memory, t.t_collective))
