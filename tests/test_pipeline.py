"""Pipeline parallelism: shard_map GPipe schedule equals sequential apply.

Runs in a subprocess with a forced 4-device host platform (the main test
process must keep the default single device for everything else).
"""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.parallel.pipeline import pipeline_apply, split_microbatches

    mesh = make_mesh((4,), ("stage",))
    n_stages, n_mb, mb, d = 4, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    w = jax.random.normal(ks[0], (n_stages, d, d)) * 0.3
    x = jax.random.normal(ks[1], (n_mb * mb, d))

    def stage_fn(wi, h):
        return jnp.tanh(h @ wi)

    mbs = split_microbatches(x, n_mb)
    out = pipeline_apply(stage_fn, w, mbs, mesh)
    out = out.reshape(n_mb * mb, d)

    ref = x
    for i in range(n_stages):
        ref = stage_fn(w[i], ref)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, err
    print("PIPELINE_OK", err)
""")


@pytest.mark.slow  # forced-4-device subprocess: multi-minute XLA compile
def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                       cwd=__file__.rsplit("/tests", 1)[0], timeout=600)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
