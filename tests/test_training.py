"""Training substrate: data determinism, checkpoint atomicity/restart,
optimizer behaviour, straggler monitor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.training import checkpoint as ckpt
from repro.training import data as data_lib
from repro.training import optimizer as opt_lib
from repro.training.elastic import ElasticPlan, StragglerMonitor
from repro.training.train_loop import TrainConfig, train


@given(st.integers(0, 10_000), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_data_stream_is_index_pure(seed, index):
    cfg = data_lib.DataConfig(vocab_size=977, seq_len=16, global_batch=4,
                              seed=seed)
    s1, s2 = data_lib.TokenStream(cfg), data_lib.TokenStream(cfg)
    b1, b2 = s1.batch(index), s2.batch(index)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"], b2["labels"])
    # labels are next-token shifted
    full1 = np.concatenate([b1["tokens"], b1["labels"][:, -1:]], 1)
    assert np.array_equal(full1[:, 1:], b1["labels"])


def test_host_sharding_partitions_batch():
    cfg = data_lib.DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    b = data_lib.TokenStream(cfg).batch(0)
    parts = [data_lib.shard_for_host(b, i, 4)["tokens"] for i in range(4)]
    assert np.array_equal(np.concatenate(parts), b["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 5, tree)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = ckpt.restore(str(tmp_path), 5, like)
    assert np.array_equal(out["a"], tree["a"])
    assert np.array_equal(np.asarray(out["b"]["c"], np.float32),
                          np.asarray(tree["b"]["c"], np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_train_restart_resumes(tmp_path):
    """Kill-and-restart: same final loss as an uninterrupted run."""
    cfg = get_config("tinyllama-1.1b").reduced()
    tcfg = TrainConfig(steps=6, seq_len=16, global_batch=2,
                       ckpt_dir=str(tmp_path / "a"), ckpt_every=3,
                       log_every=0)
    final = train(cfg, tcfg)

    # Interrupted run: first 3 steps, then restart from the checkpoint.
    tcfg_b = TrainConfig(steps=3, seq_len=16, global_batch=2,
                         ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
                         log_every=0)
    train(cfg, tcfg_b)
    tcfg_b2 = TrainConfig(steps=6, seq_len=16, global_batch=2,
                          ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
                          log_every=0)
    resumed = train(cfg, tcfg_b2)

    for a, b in zip(jax.tree.leaves(final.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt_lib.init(params)
    cfg = opt_lib.AdamWConfig(lr=0.3, warmup_steps=1, total_steps=200,
                              weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_lib.update(cfg, grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clipping():
    params = {"w": jnp.zeros((3,))}
    state = opt_lib.init(params)
    cfg = opt_lib.AdamWConfig(clip_norm=1.0)
    _, _, metrics = opt_lib.update(cfg, {"w": jnp.full((3,), 1e6)}, state,
                                   params)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=2.0)
    import time
    for _ in range(10):
        mon.step_start()
        time.sleep(0.001)
        assert mon.step_end() is None or True
    mon.step_start()
    time.sleep(0.05)
    assert mon.step_end() is not None


def test_elastic_plan_shapes():
    plan = ElasticPlan(pods_total=2)
    assert plan.mesh_shape(2)[0] == (2, 16, 16)
    assert plan.mesh_shape(1)[0] == (16, 16)
    assert plan.global_batch_scale(1) == 0.5
