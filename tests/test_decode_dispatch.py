"""Unified decode-attention dispatch: the engine's paged hot path runs the
Pallas flash-decode kernel (interpret mode on CPU) and the jnp gather
reference interchangeably — greedy outputs are bit-identical across the
dense/moe/vlm × prefix on/off × preemption × decode_steps matrix, and the
kernel path provably never materializes the dense per-lane KV copy (jaxpr
regression).  Also pins the preempt-policy satellite and the vlm
patch-digest prefix-cache soundness fix.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine

MAX_LEN = 32


def _make(arch, **over):
    cfg = get_config(arch).reduced()
    if over:
        cfg = dataclasses.replace(cfg, **over)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def tiny():
    return _make("tinyllama-1.1b")


# ---------------------------------------------------------------------------
# jaxpr regression: the paged decode step must not gather a dense KV copy
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            yield from _iter_param_eqns(v)


def _iter_param_eqns(v):
    if hasattr(v, "jaxpr"):  # ClosedJaxpr
        yield from _iter_eqns(v.jaxpr)
    elif hasattr(v, "eqns"):  # Jaxpr
        yield from _iter_eqns(v)
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _iter_param_eqns(x)


def _max_gather_elems(jaxpr):
    best = 0
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name == "gather":
            for out in eqn.outvars:
                best = max(best, int(np.prod(out.aval.shape)))
    return best


def _paged_decode_jaxpr(cfg, params, B, bs, T, N):
    cache = jax.eval_shape(lambda: M.init_paged_cache(cfg, N + 1, bs))
    return jax.make_jaxpr(
        lambda p, c, t, pos, bt: M.decode_step(cfg, p, c, t, pos,
                                               block_tables=bt)
    )(params, cache,
      jax.ShapeDtypeStruct((B, 1), jnp.int32),
      jax.ShapeDtypeStruct((B,), jnp.int32),
      jax.ShapeDtypeStruct((B, T), jnp.int32)).jaxpr


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
def test_paged_decode_step_has_no_dense_kv_gather(arch):
    """On the kernel path no gather in the whole jitted step reaches the
    (B, T*bs, Hk, D) dense per-lane copy; on the reference path one does
    (positive control — the regression this test pins)."""
    B, bs, T, N = 4, 4, MAX_LEN // 4, 16
    cfg, params = _make(arch)
    dense_copy = B * T * bs * cfg.num_kv_heads * cfg.head_dim
    on = _paged_decode_jaxpr(
        dataclasses.replace(cfg, attn_kernel="on"), params, B, bs, T, N)
    assert _max_gather_elems(on) < dense_copy, (
        "kernel-path decode step still materializes a dense per-lane KV "
        "copy")
    off = _paged_decode_jaxpr(
        dataclasses.replace(cfg, attn_kernel="off"), params, B, bs, T, N)
    assert _max_gather_elems(off) >= dense_copy, (
        "positive control lost: the reference path should gather")


# ---------------------------------------------------------------------------
# engine matrix: the serving machinery is bit-transparent UNDER the kernel
# ---------------------------------------------------------------------------
# Kernel-vs-reference agreement is a TOLERANCE property (pinned per-kernel
# in test_kernels.py): the kernel's one-pass online softmax accumulates in
# fp32 while the reference rounds scores/probs through bf16 two-pass
# softmax, so their logits differ in low bits and a near-tie greedy argmax
# can legitimately flip.  What IS exact — and what these tests pin — is
# that with the kernel ON, every serving-layer mechanism (prefix sharing,
# chunked prefill, multi-step decode windows, preemption recompute) leaves
# greedy outputs bit-identical, exactly as the reference-path matrix in
# test_continuous_batching.py pins for the gather fallback.

def _run_engine(cfg, params, reqs, **kwargs):
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1, **kwargs)
    uids = [eng.submit(p, max_new_tokens=m) for p, m in reqs]
    out = eng.run()
    return eng, [out[u] for u in uids]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
def test_engine_kernel_on_scheduling_invariance(arch):
    """attn_kernel="on" (interpret mode on CPU): greedy outputs are
    bit-identical across prefix cache on/off, chunked vs whole-prompt
    prefill, and decode_steps 1 vs 2, on shared-prefix traffic."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(31)
    shared = rng.integers(1, cfg.vocab_size, size=9)
    reqs = [(np.concatenate([shared,
                             rng.integers(1, cfg.vocab_size, size=n)]), m)
            for n, m in ((3, 4), (5, 3), (2, 4))]
    kw = dict(max_batch=2, block_size=4, attn_kernel="on")
    eng, base = _run_engine(cfg, params, reqs, prefill_chunk=8,
                            prefix_cache=True, **kw)
    assert eng.stats.cached_prompt_tokens > 0  # sharing really happened
    _, no_prefix = _run_engine(cfg, params, reqs, prefill_chunk=8,
                               prefix_cache=False, **kw)
    _, whole = _run_engine(cfg, params, reqs, prefill_chunk=None,
                           prefix_cache=True, **kw)
    _, multi = _run_engine(cfg, params, reqs, prefill_chunk=8,
                           prefix_cache=True, decode_steps=2, **kw)
    assert no_prefix == base
    assert whole == base
    assert multi == base


def test_engine_kernel_on_preemption_bit_identical(tiny):
    """Pool pressure + preemption recompute with the kernel path on: the
    over-committed pool reproduces the ample pool's outputs exactly."""
    cfg, params = tiny
    rng = np.random.default_rng(37)
    reqs = [(rng.integers(1, cfg.vocab_size, size=5), 12) for _ in range(3)]
    kw = dict(max_batch=3, block_size=4, attn_kernel="on")
    _, ref = _run_engine(cfg, params, reqs, num_blocks=24, **kw)
    eng, out = _run_engine(cfg, params, reqs, num_blocks=9, **kw)
    assert eng.stats.preemptions >= 1
    assert out == ref


# ---------------------------------------------------------------------------
# preemption policies
# ---------------------------------------------------------------------------

def _spy_preemptions(eng):
    victims = []
    orig = eng._preempt

    def spy(victim):
        kind, v = victim
        victims.append((eng._slot_req[v] if kind == "lane" else v.req).uid)
        orig(victim)

    eng._preempt = spy
    return victims


def _policy_run(cfg, params, policy, deadlines=(None, None)):
    """A big old request + a smaller young one, both still growing when a
    7-block pool runs dry (big needs 6 blocks worst-case, small 4);
    returns (preempted uids, outputs, uids)."""
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN, eos_id=-1,
                        block_size=4, num_blocks=7, prefill_chunk=None,
                        preempt_policy=policy)
    victims = _spy_preemptions(eng)
    big = eng.submit(np.arange(1, 12), max_new_tokens=10,
                     deadline=deadlines[0])
    small = eng.submit(np.arange(2, 6), max_new_tokens=12,
                       deadline=deadlines[1])
    out = eng.run()
    return victims, out, (big, small)


def test_preempt_policy_youngest_default(tiny):
    cfg, params = tiny
    victims, out, (big, small) = _policy_run(cfg, params, "youngest")
    assert victims and set(victims) == {small}
    eng_solo = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                             eos_id=-1, block_size=4)
    u = eng_solo.submit(np.arange(2, 6), max_new_tokens=12)
    assert out[small] == eng_solo.run()[u]  # recompute is invisible


def test_preempt_policy_largest_evicts_block_hog(tiny):
    """"largest" frees the most memory per eviction: the big old request
    is preempted even though it is not the youngest."""
    cfg, params = tiny
    victims, out, (big, small) = _policy_run(cfg, params, "largest")
    assert victims and victims[0] == big
    # Both still complete, and the preempted request's recompute matches
    # its unpressured run.
    _, ref = _run_engine(cfg, params, [(np.arange(1, 12), 10)],
                         max_batch=1, block_size=4)
    assert out[big] == ref[0]


def test_preempt_policy_deadline(tiny):
    """"deadline" evicts the most-slack (latest-deadline) request: here
    the OLD request has the late deadline, so it is chosen over the
    younger tight-deadline one."""
    cfg, params = tiny
    victims, out, (big, small) = _policy_run(cfg, params, "deadline",
                                             deadlines=(100.0, 1.0))
    assert victims and victims[0] == big
    # A deadline-less request is considered infinitely late: evicted first.
    victims2, _, (big2, small2) = _policy_run(cfg, params, "deadline",
                                              deadlines=(None, 1.0))
    assert victims2 and victims2[0] == big2


def test_preempt_policy_deadline_strict_order(tiny):
    """The documented total order of ``preempt_policy="deadline"`` (see
    the engine module docstring): eviction strictly follows
    ``submit(deadline=)`` — the LATEST deadline goes first, and a
    ``deadline=None`` request is infinitely late, evicted before ANY
    request that has a deadline.  Submission age must not leak in: the
    deadline-less request is submitted FIRST (oldest), so the default
    youngest-first order would pick a different victim — if this test
    sees the old deadline-less request evicted, ordering really came
    from deadlines.  The tight-deadline request (evicted last in the
    order) must never be preempted, and every output still matches the
    unpressured run (preemption stays invisible in outputs)."""
    cfg, params = tiny
    prompts = [np.arange(1, 8), np.arange(3, 10), np.arange(5, 12)]
    budget = 8
    eng = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                        eos_id=-1, block_size=4, num_blocks=9,
                        prefill_chunk=None, preempt_policy="deadline")
    victims = _spy_preemptions(eng)
    u_none = eng.submit(prompts[0], max_new_tokens=budget, deadline=None)
    u_late = eng.submit(prompts[1], max_new_tokens=budget, deadline=10.0)
    u_tight = eng.submit(prompts[2], max_new_tokens=budget, deadline=1.0)
    out = eng.run()
    assert victims, "9-block pool under 3 growing requests must preempt"
    assert victims[0] == u_none, (
        f"first victim must be the deadline-less request (None = "
        f"infinitely late), got {victims[0]}")
    # Strict order all the way down: only the None and latest-deadline
    # requests are ever evicted; the tight deadline survives every round.
    assert set(victims) <= {u_none, u_late}
    assert u_tight not in victims
    # Recompute is invisible: every request matches its unpressured run.
    ref = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                        eos_id=-1, block_size=4, num_blocks=24,
                        prefill_chunk=None)
    ref_uids = [ref.submit(p, max_new_tokens=budget) for p in prompts]
    ref_out = ref.run()
    assert [out[u] for u in (u_none, u_late, u_tight)] \
        == [ref_out[u] for u in ref_uids]


def test_preempt_policy_validated(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="preempt_policy"):
        ServingEngine(cfg, params, preempt_policy="oldest")
    with pytest.raises(ValueError, match="attn_kernel"):
        ServingEngine(cfg, params, attn_kernel="maybe")
    # The deprecated spelling still validates (through the shim).
    with pytest.warns(DeprecationWarning), \
            pytest.raises(ValueError, match="decode_kernel"):
        ServingEngine(cfg, params, decode_kernel="maybe")


# ---------------------------------------------------------------------------
# vlm prefix-cache soundness: patch digest seeds the hash chain
# ---------------------------------------------------------------------------

def _solo_vlm_greedy(cfg, params, prompt, pe, max_new):
    batch = {"tokens": jnp.asarray(np.asarray(prompt)[None], jnp.int32),
             "patch_embeds": jnp.asarray(pe[None]).astype(jnp.bfloat16)}
    logits, cache = M.prefill(cfg, params, batch, max_len=MAX_LEN)
    toks, pos = [], len(prompt)
    for _ in range(max_new):
        t = int(jnp.argmax(logits.reshape(-1)))
        toks.append(t)
        logits, cache = M.decode_step(
            cfg, params, cache, jnp.full((1, 1), t, jnp.int32),
            jnp.int32(pos))
        logits = logits[:, 0]
        pos += 1
    return toks


def test_vlm_patch_digest_prevents_false_sharing():
    """Two vlm requests with IDENTICAL token ids but different images must
    not share prefix blocks (the image changes the cached patch K/V); the
    same image must still share."""
    cfg, params = _make("internvl2-26b")
    rng = np.random.default_rng(43)
    prompt = rng.integers(1, cfg.vocab_size, size=12)
    pe_a = rng.normal(size=(cfg.num_patches, cfg.d_model)).astype(np.float32)
    pe_b = rng.normal(size=(cfg.num_patches, cfg.d_model)).astype(np.float32)

    # Pool big enough that request B never LRU-evicts A's retired blocks
    # (this test pins digest separation, not eviction).
    eng = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN, eos_id=-1,
                        block_size=4, num_blocks=16, prefill_chunk=None)
    u_a = eng.submit(prompt, max_new_tokens=4, patch_embeds=pe_a)
    out = eng.run()
    hits_after_a = eng._alloc.hit_blocks

    # Different image, same tokens: NO hit — and the output matches the
    # solo run with image B (false sharing would replay image A's KV).
    u_b = eng.submit(prompt, max_new_tokens=4, patch_embeds=pe_b)
    out.update(eng.run())
    assert eng._alloc.hit_blocks == hits_after_a
    assert out[u_b] == _solo_vlm_greedy(cfg, params, prompt, pe_b, 4)
    assert out[u_a] == _solo_vlm_greedy(cfg, params, prompt, pe_a, 4)

    # Same image as A: the retired donor's blocks ARE matched again.
    u_c = eng.submit(prompt, max_new_tokens=4, patch_embeds=pe_a)
    out.update(eng.run())
    assert eng._alloc.hit_blocks > hits_after_a
    assert out[u_c] == out[u_a]
    eng._alloc.check_invariants()


def test_vlm_patch_embeds_rejected_for_non_vlm(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN, eos_id=-1)
    with pytest.raises(ValueError, match="vlm-only"):
        eng.submit(np.arange(1, 5), max_new_tokens=2,
                   patch_embeds=np.zeros((4, cfg.d_model), np.float32))
