"""Suite-wide hygiene shared by every test module.

The tier-1 suite compiles thousands of XLA executables in ONE process
(nearly every test builds fresh ServingEngines, and each compiled
executable pins several JIT code mappings).  Left alone, the process's
memory-map count grows past ``vm.max_map_count`` (65530 by default)
about two-thirds of the way through the suite, at which point mmap
starts failing inside LLVM's JIT memory manager and XLA's
``backend_compile`` segfaults — deterministically, at whichever test
happens to cross the threshold (observed ~50k live mappings, dying in
``test_spec_decode`` with the crash point shifting as the suite grows).

Dropping compiled executables BETWEEN modules bounds the live set to
one module's worth (a few thousand mappings), at the cost of
recompilation across module boundaries — which the suite pays anyway,
since engines and their jitted steps are built per-test.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables_between_modules():
    yield
    jax.clear_caches()
