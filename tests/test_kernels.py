"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles.

All Pallas kernels run in interpret mode (CPU executes the kernel body), as
specified for this CPU-only container; the BlockSpecs/grids are the TPU
deployment artifacts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.flash_decode import flash_decode
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.sclad_matmul.sclad_matmul import (
    block_compress, decompress, sclad_matmul)
from repro.kernels.sclad_matmul.ref import sclad_matmul_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,Hk,D,causal", [
    (2, 256, 256, 4, 2, 64, True),
    (1, 128, 384, 8, 8, 128, False),
    (2, 256, 256, 4, 1, 64, True),   # MQA
    (1, 512, 512, 2, 2, 128, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, Sk, H, Hk, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hk, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hk,D,S", [
    (2, 8, 2, 64, 512), (1, 4, 4, 128, 256), (3, 8, 1, 64, 384)])
@pytest.mark.parametrize("length", [1, 129, None])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, H, Hk, D, S, length, dtype):
    length = S if length is None else min(length, S)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, Hk, D)).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, Hk, D)).astype(dtype)
    out = flash_decode(q, kc, vc, jnp.int32(length), interpret=True)
    ref = decode_ref(q, kc, vc, jnp.int32(length))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


# ---------------------------------------------------------------------------
# SCLD matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,C", [
    (128, 256, 128, 6), (256, 128, 256, 16), (128, 384, 256, 4),
    (384, 128, 128, 1)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_sclad_matmul(M, K, N, C, dtype):
    rng = np.random.default_rng(0)
    w = rng.standard_normal((K, N)).astype(np.float32)
    vals, rows = block_compress(w, C)
    x = jnp.asarray(rng.standard_normal((M, K))).astype(dtype)
    y = sclad_matmul(x, jnp.asarray(vals).astype(dtype),
                     jnp.asarray(rows), interpret=True)
    yr = sclad_matmul_ref(x, np.asarray(vals, np.float32), rows)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32),
        atol=1e-1 if dtype == jnp.bfloat16 else 1e-4,
        rtol=5e-2 if dtype == jnp.bfloat16 else 2e-2)


def test_block_compress_roundtrip_full_capacity():
    rng = np.random.default_rng(1)
    w = rng.standard_normal((256, 256)).astype(np.float32)
    vals, rows = block_compress(w, 16)
    assert np.allclose(decompress(vals, rows), w)


def test_block_compress_keeps_largest_units():
    w = np.zeros((128, 128), np.float32)
    w[0:8] = 100.0  # unit 0 is the largest
    w[64:72] = 50.0  # unit 8 second
    vals, rows = block_compress(w, 2)
    assert set(rows[0, 0].tolist()) == {0, 8}
    assert np.allclose(decompress(vals, rows), w)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,S,P,N,chunk", [
    (4, 256, 64, 32, 64), (2, 128, 32, 16, 128), (1, 512, 64, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(BH, S, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xdt = (jax.random.normal(ks[0], (BH, S, P)) * 0.1).astype(dtype)
    a = (-jnp.abs(jax.random.normal(ks[1], (BH, S))) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[2], (BH, S, N)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[3], (BH, S, N)) * 0.3).astype(dtype)
    y, st = ssd_scan(xdt, a, b, c, chunk=chunk, interpret=True)
    yr, str_ = ssd_scan_ref(xdt, a, b, c)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32),
        atol=tol(dtype) * 5, rtol=tol(dtype) * 5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=tol(dtype) * 5, rtol=tol(dtype) * 5)
