"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles.

All Pallas kernels run in interpret mode (CPU executes the kernel body), as
specified for this CPU-only container; the BlockSpecs/grids are the TPU
deployment artifacts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.flash_decode import (flash_decode,
                                                    paged_flash_decode)
from repro.kernels.flash_decode.ref import decode_ref, paged_decode_ref
from repro.kernels.sclad_matmul.sclad_matmul import (
    block_compress, decompress, sclad_matmul)
from repro.kernels.sclad_matmul.ref import sclad_matmul_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,Hk,D,causal", [
    (2, 256, 256, 4, 2, 64, True),
    (1, 128, 384, 8, 8, 128, False),
    (2, 256, 256, 4, 1, 64, True),   # MQA
    (1, 512, 512, 2, 2, 128, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, Sk, H, Hk, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hk, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hk,D,S", [
    (2, 8, 2, 64, 512), (1, 4, 4, 128, 256), (3, 8, 1, 64, 384)])
@pytest.mark.parametrize("length", [1, 129, None])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, H, Hk, D, S, length, dtype):
    length = S if length is None else min(length, S)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, Hk, D)).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, Hk, D)).astype(dtype)
    out = flash_decode(q, kc, vc, jnp.int32(length), interpret=True)
    ref = decode_ref(q, kc, vc, jnp.int32(length))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


def test_flash_decode_per_row_lengths():
    """Rows of a continuous batch sit at different offsets: a (B,) lengths
    vector must reproduce per-row scalar-length runs exactly."""
    B, H, Hk, D, S = 4, 8, 2, 64, 256
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, S, Hk, D))
    vc = jax.random.normal(ks[2], (B, S, Hk, D))
    lengths = jnp.asarray([1, 127, 128, 256], jnp.int32)
    out = flash_decode(q, kc, vc, lengths, interpret=True)
    ref = decode_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # Row b of the batched run == a solo run at that row's scalar length.
    for b in range(B):
        solo = flash_decode(q[b:b + 1], kc[b:b + 1], vc[b:b + 1],
                            lengths[b], interpret=True)
        np.testing.assert_array_equal(np.asarray(out[b]),
                                      np.asarray(solo[0]))


# ---------------------------------------------------------------------------
# paged flash decode (block-pool layout, tables via scalar prefetch)
# ---------------------------------------------------------------------------

def _build_pool(rng_seed, B, Hk, D, bs, T, lengths, dtype,
                dead_lanes=()):
    """A shared pool + per-row tables: unique blocks per live row in random
    pool order, TRASH (0) for unallocated tails and for dead lanes."""
    n_blocks = 1 + sum(-(-int(l) // bs) for l in lengths)
    N = n_blocks + 2  # a couple of never-referenced blocks
    ks = jax.random.split(jax.random.PRNGKey(rng_seed), 3)
    k_pool = jax.random.normal(ks[0], (N, bs, Hk, D)).astype(dtype)
    v_pool = jax.random.normal(ks[1], (N, bs, Hk, D)).astype(dtype)
    rng = np.random.default_rng(rng_seed)
    free = list(rng.permutation(np.arange(1, N)))
    tables = np.zeros((B, T), np.int32)
    for b in range(B):
        if b in dead_lanes:
            continue
        for j in range(-(-int(lengths[b]) // bs)):
            tables[b, j] = free.pop()
    return k_pool, v_pool, jnp.asarray(tables)


@pytest.mark.parametrize("B,H,Hk,D,bs,T", [
    (3, 8, 2, 64, 8, 4),    # GQA rep=4
    (2, 4, 4, 32, 4, 6),    # MHA, small blocks
    (4, 8, 1, 64, 16, 2),   # MQA, bigger blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode(B, H, Hk, D, bs, T, dtype):
    """Uneven per-row lengths (full table, single token, mid-block)
    against the dense-gather oracle."""
    rng = np.random.default_rng(5)
    lengths = np.asarray(
        [T * bs, 1] + [int(rng.integers(2, T * bs)) for _ in range(B - 2)],
        np.int32)[:B]
    k_pool, v_pool, tables = _build_pool(7, B, Hk, D, bs, T, lengths, dtype)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H, D)).astype(dtype)
    out = paged_flash_decode(q, k_pool, v_pool, jnp.asarray(lengths), tables,
                             interpret=True)
    ref = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(lengths), tables)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("block_k", [3, 4, 128])
def test_paged_flash_decode_block_k_mismatch(block_k):
    """The kernel's inner tile need not match the pool block size: any
    requested block_k (even the dense kernel's 128, or a non-divisor) is
    rounded to a divisor of bs without changing results."""
    B, H, Hk, D, bs, T = 2, 4, 2, 32, 8, 3
    lengths = np.asarray([T * bs, 11], np.int32)
    k_pool, v_pool, tables = _build_pool(11, B, Hk, D, bs, T, lengths,
                                         jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(13), (B, H, D))
    ref = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(lengths), tables)
    out = paged_flash_decode(q, k_pool, v_pool, jnp.asarray(lengths), tables,
                             block_k=block_k, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_flash_decode_trash_lanes():
    """Dead lanes (all-trash tables — retired/preempted slots in the
    engine) walk only the trash block; live lanes are unaffected and the
    dead lanes' outputs equal the oracle's on the same masked garbage."""
    B, H, Hk, D, bs, T = 3, 4, 2, 32, 4, 4
    lengths = np.asarray([13, 1, 6], np.int32)  # row 1 is dead
    k_pool, v_pool, tables = _build_pool(17, B, Hk, D, bs, T, lengths,
                                         jnp.float32, dead_lanes=(1,))
    q = jax.random.normal(jax.random.PRNGKey(19), (B, H, D))
    out = paged_flash_decode(q, k_pool, v_pool, jnp.asarray(lengths), tables,
                             interpret=True)
    ref = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(lengths), tables)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_paged_flash_decode_shared_blocks():
    """Two lanes whose tables name the SAME pool blocks (prefix sharing)
    read them concurrently without interference."""
    B, H, Hk, D, bs, T = 2, 4, 2, 32, 4, 3
    lengths = np.asarray([9, 6], np.int32)
    k_pool, v_pool, tables = _build_pool(23, B, Hk, D, bs, T, lengths,
                                         jnp.float32)
    tables = np.asarray(tables).copy()
    tables[1, 0] = tables[0, 0]  # shared prefix block
    tables = jnp.asarray(tables)
    q = jax.random.normal(jax.random.PRNGKey(29), (B, H, D))
    out = paged_flash_decode(q, k_pool, v_pool, jnp.asarray(lengths), tables,
                             interpret=True)
    ref = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(lengths), tables)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SCLD matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,C", [
    (128, 256, 128, 6), (256, 128, 256, 16), (128, 384, 256, 4),
    (384, 128, 128, 1)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_sclad_matmul(M, K, N, C, dtype):
    rng = np.random.default_rng(0)
    w = rng.standard_normal((K, N)).astype(np.float32)
    vals, rows = block_compress(w, C)
    x = jnp.asarray(rng.standard_normal((M, K))).astype(dtype)
    y = sclad_matmul(x, jnp.asarray(vals).astype(dtype),
                     jnp.asarray(rows), interpret=True)
    yr = sclad_matmul_ref(x, np.asarray(vals, np.float32), rows)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32),
        atol=1e-1 if dtype == jnp.bfloat16 else 1e-4,
        rtol=5e-2 if dtype == jnp.bfloat16 else 2e-2)


def test_block_compress_roundtrip_full_capacity():
    rng = np.random.default_rng(1)
    w = rng.standard_normal((256, 256)).astype(np.float32)
    vals, rows = block_compress(w, 16)
    assert np.allclose(decompress(vals, rows), w)


def test_block_compress_keeps_largest_units():
    w = np.zeros((128, 128), np.float32)
    w[0:8] = 100.0  # unit 0 is the largest
    w[64:72] = 50.0  # unit 8 second
    vals, rows = block_compress(w, 2)
    assert set(rows[0, 0].tolist()) == {0, 8}
    assert np.allclose(decompress(vals, rows), w)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,S,P,N,chunk", [
    (4, 256, 64, 32, 64), (2, 128, 32, 16, 128), (1, 512, 64, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(BH, S, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xdt = (jax.random.normal(ks[0], (BH, S, P)) * 0.1).astype(dtype)
    a = (-jnp.abs(jax.random.normal(ks[1], (BH, S))) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[2], (BH, S, N)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[3], (BH, S, N)) * 0.3).astype(dtype)
    y, st = ssd_scan(xdt, a, b, c, chunk=chunk, interpret=True)
    yr, str_ = ssd_scan_ref(xdt, a, b, c)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32),
        atol=tol(dtype) * 5, rtol=tol(dtype) * 5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=tol(dtype) * 5, rtol=tol(dtype) * 5)
