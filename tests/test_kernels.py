"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles.

All Pallas kernels run in interpret mode (CPU executes the kernel body), as
specified for this CPU-only container; the BlockSpecs/grids are the TPU
deployment artifacts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.flash_decode import (flash_decode,
                                                    paged_flash_decode)
from repro.kernels.flash_decode.ref import decode_ref, paged_decode_ref
from repro.kernels.flash_prefill.flash_prefill import paged_flash_prefill
from repro.kernels.flash_prefill.ref import prefill_attention_ref
from repro.kernels.sclad_matmul.sclad_matmul import (
    block_compress, decompress, sclad_matmul)
from repro.kernels.sclad_matmul.ref import sclad_matmul_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,Hk,D,causal", [
    (2, 256, 256, 4, 2, 64, True),
    (1, 128, 384, 8, 8, 128, False),
    (2, 256, 256, 4, 1, 64, True),   # MQA
    (1, 512, 512, 2, 2, 128, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, Sk, H, Hk, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hk, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hk,D,S", [
    (2, 8, 2, 64, 512), (1, 4, 4, 128, 256), (3, 8, 1, 64, 384)])
@pytest.mark.parametrize("length", [1, 129, None])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, H, Hk, D, S, length, dtype):
    length = S if length is None else min(length, S)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, Hk, D)).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, Hk, D)).astype(dtype)
    out = flash_decode(q, kc, vc, jnp.int32(length), interpret=True)
    ref = decode_ref(q, kc, vc, jnp.int32(length))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


def test_flash_decode_per_row_lengths():
    """Rows of a continuous batch sit at different offsets: a (B,) lengths
    vector must reproduce per-row scalar-length runs exactly."""
    B, H, Hk, D, S = 4, 8, 2, 64, 256
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, S, Hk, D))
    vc = jax.random.normal(ks[2], (B, S, Hk, D))
    lengths = jnp.asarray([1, 127, 128, 256], jnp.int32)
    out = flash_decode(q, kc, vc, lengths, interpret=True)
    ref = decode_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # Row b of the batched run == a solo run at that row's scalar length.
    for b in range(B):
        solo = flash_decode(q[b:b + 1], kc[b:b + 1], vc[b:b + 1],
                            lengths[b], interpret=True)
        np.testing.assert_array_equal(np.asarray(out[b]),
                                      np.asarray(solo[0]))


# ---------------------------------------------------------------------------
# paged flash decode (block-pool layout, tables via scalar prefetch)
# ---------------------------------------------------------------------------

def _build_pool(rng_seed, B, Hk, D, bs, T, lengths, dtype,
                dead_lanes=()):
    """A shared pool + per-row tables: unique blocks per live row in random
    pool order, TRASH (0) for unallocated tails and for dead lanes."""
    n_blocks = 1 + sum(-(-int(l) // bs) for l in lengths)
    N = n_blocks + 2  # a couple of never-referenced blocks
    ks = jax.random.split(jax.random.PRNGKey(rng_seed), 3)
    k_pool = jax.random.normal(ks[0], (N, bs, Hk, D)).astype(dtype)
    v_pool = jax.random.normal(ks[1], (N, bs, Hk, D)).astype(dtype)
    rng = np.random.default_rng(rng_seed)
    free = list(rng.permutation(np.arange(1, N)))
    tables = np.zeros((B, T), np.int32)
    for b in range(B):
        if b in dead_lanes:
            continue
        for j in range(-(-int(lengths[b]) // bs)):
            tables[b, j] = free.pop()
    return k_pool, v_pool, jnp.asarray(tables)


@pytest.mark.parametrize("B,H,Hk,D,bs,T", [
    (3, 8, 2, 64, 8, 4),    # GQA rep=4
    (2, 4, 4, 32, 4, 6),    # MHA, small blocks
    (4, 8, 1, 64, 16, 2),   # MQA, bigger blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode(B, H, Hk, D, bs, T, dtype):
    """Uneven per-row lengths (full table, single token, mid-block)
    against the dense-gather oracle."""
    rng = np.random.default_rng(5)
    lengths = np.asarray(
        [T * bs, 1] + [int(rng.integers(2, T * bs)) for _ in range(B - 2)],
        np.int32)[:B]
    k_pool, v_pool, tables = _build_pool(7, B, Hk, D, bs, T, lengths, dtype)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H, D)).astype(dtype)
    out = paged_flash_decode(q, k_pool, v_pool, jnp.asarray(lengths), tables,
                             interpret=True)
    ref = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(lengths), tables)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("block_k", [3, 4, 128])
def test_paged_flash_decode_block_k_mismatch(block_k):
    """The kernel's inner tile need not match the pool block size: any
    requested block_k (even the dense kernel's 128, or a non-divisor) is
    rounded to a divisor of bs without changing results."""
    B, H, Hk, D, bs, T = 2, 4, 2, 32, 8, 3
    lengths = np.asarray([T * bs, 11], np.int32)
    k_pool, v_pool, tables = _build_pool(11, B, Hk, D, bs, T, lengths,
                                         jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(13), (B, H, D))
    ref = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(lengths), tables)
    out = paged_flash_decode(q, k_pool, v_pool, jnp.asarray(lengths), tables,
                             block_k=block_k, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_flash_decode_trash_lanes():
    """Dead lanes (all-trash tables — retired/preempted slots in the
    engine) walk only the trash block; live lanes are unaffected and the
    dead lanes' outputs equal the oracle's on the same masked garbage."""
    B, H, Hk, D, bs, T = 3, 4, 2, 32, 4, 4
    lengths = np.asarray([13, 1, 6], np.int32)  # row 1 is dead
    k_pool, v_pool, tables = _build_pool(17, B, Hk, D, bs, T, lengths,
                                         jnp.float32, dead_lanes=(1,))
    q = jax.random.normal(jax.random.PRNGKey(19), (B, H, D))
    out = paged_flash_decode(q, k_pool, v_pool, jnp.asarray(lengths), tables,
                             interpret=True)
    ref = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(lengths), tables)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_paged_flash_decode_shared_blocks():
    """Two lanes whose tables name the SAME pool blocks (prefix sharing)
    read them concurrently without interference."""
    B, H, Hk, D, bs, T = 2, 4, 2, 32, 4, 3
    lengths = np.asarray([9, 6], np.int32)
    k_pool, v_pool, tables = _build_pool(23, B, Hk, D, bs, T, lengths,
                                         jnp.float32)
    tables = np.asarray(tables).copy()
    tables[1, 0] = tables[0, 0]  # shared prefix block
    tables = jnp.asarray(tables)
    q = jax.random.normal(jax.random.PRNGKey(29), (B, H, D))
    out = paged_flash_decode(q, k_pool, v_pool, jnp.asarray(lengths), tables,
                             interpret=True)
    ref = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(lengths), tables)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged flash prefill (table-walked context + fused K/V scatter)
# ---------------------------------------------------------------------------

def _build_prefill_case(seed, B, H, Hk, D, bs, T, prefix, P, starts, lengths,
                        dtype, share_ctx_block=False):
    """Chunk tensors + a shared pool + per-row tables covering each row's
    cached context and write span (unique blocks in random pool order)."""
    S = prefix + P
    sv = np.zeros(B, np.int64) if starts is None else np.asarray(starts)
    first_extra = prefix if starts is None else 0
    need = [-(-(int(sv[b]) + first_extra + int(lengths[b])) // bs)
            for b in range(B)]
    N = 1 + sum(need) + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    kn = jax.random.normal(ks[1], (B, S, Hk, D)).astype(dtype)
    vn = jax.random.normal(ks[2], (B, S, Hk, D)).astype(dtype)
    kp = jax.random.normal(ks[3], (N, bs, Hk, D)).astype(dtype)
    vp = jax.random.normal(ks[4], (N, bs, Hk, D)).astype(dtype)
    rng = np.random.default_rng(seed)
    free = list(rng.permutation(np.arange(1, N)))
    tables = np.zeros((B, T), np.int32)
    for b in range(B):
        for j in range(need[b]):
            tables[b, j] = free.pop()
    if share_ctx_block and B >= 2:
        tables[1, 0] = tables[0, 0]  # read-only shared prefix block
    st = None if starts is None else jnp.asarray(starts, jnp.int32)
    return (q, kn, vn, kp, vp, jnp.asarray(lengths, jnp.int32),
            jnp.asarray(tables), st)


def _check_prefill_parity(case, prefix, dtype):
    q, kn, vn, kp, vp, lengths, tables, st = case
    B = q.shape[0]
    ro, rk, rv = prefill_attention_ref(q, kn, vn, kp, vp, lengths, tables,
                                       start=st, prefix=prefix)
    sv = jnp.zeros((B,), jnp.int32) if st is None else st
    ko, kk, kv = paged_flash_prefill(q, kn, vn, kp, vp, lengths, tables, sv,
                                     prefix=prefix, has_ctx=st is not None,
                                     interpret=True)
    np.testing.assert_allclose(
        np.asarray(ko, np.float32), np.asarray(ro, np.float32),
        atol=tol(dtype), rtol=tol(dtype))
    # The fused scatter is EXACT (one-hot fp32 placement + the same cast
    # chain as the host path): pools must match the reference bitwise —
    # including untouched blocks, which the aliasing must leave alone.
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))


@pytest.mark.parametrize("B,H,Hk,D,bs,T", [
    (3, 8, 2, 64, 8, 4),    # GQA rep=4
    (2, 4, 4, 32, 4, 6),    # MHA, small blocks
    (4, 8, 1, 64, 16, 2),   # MQA, bigger blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_prefill_continuation(B, H, Hk, D, bs, T, dtype):
    """Continuation chunks (the prefix-cache-hit / chunked / preemption-
    recompute path): uneven starts (mid-block and on-boundary) and uneven
    left-padded lengths vs the dense gather+scatter oracle."""
    P = 8
    rng = np.random.default_rng(3)
    cap = (T - 1) * bs  # leave room for the chunk's writes in the table
    starts = [1 + int(rng.integers(0, max(cap - P, 1))) for _ in range(B)]
    starts[0] = bs  # exactly on a block boundary
    lengths = [P] + [int(rng.integers(1, P + 1)) for _ in range(B - 1)]
    case = _build_prefill_case(11, B, H, Hk, D, bs, T, 0, P, starts,
                               lengths, dtype)
    _check_prefill_parity(case, 0, dtype)


@pytest.mark.parametrize("prefix", [0, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_prefill_first_chunk(prefix, dtype):
    """First chunks (start=None): no context phase; a vlm patch prefix is
    written along with the left-compacted prompt tokens."""
    B, H, Hk, D, bs, T, P = 3, 4, 2, 32, 4, 6, 8
    lengths = [8, 3, 5]
    case = _build_prefill_case(13, B, H, Hk, D, bs, T, prefix, P, None,
                               lengths, dtype)
    _check_prefill_parity(case, prefix, dtype)


def test_paged_flash_prefill_shared_context_block():
    """Two lanes whose tables name the SAME cached context block (prefix
    sharing) read it concurrently; neither lane's (exclusive) write span
    disturbs it."""
    B, H, Hk, D, bs, T, P = 2, 4, 2, 32, 4, 6, 4
    case = _build_prefill_case(17, B, H, Hk, D, bs, T, 0, P, [4, 4], [4, 2],
                               jnp.float32, share_ctx_block=True)
    _check_prefill_parity(case, 0, jnp.float32)


def test_paged_flash_prefill_single_token_continuation():
    """The smallest continuation (one uncached token — a maximal prefix
    hit) still walks the whole cached context correctly."""
    B, H, Hk, D, bs, T, P = 2, 4, 1, 16, 4, 5, 4
    case = _build_prefill_case(19, B, H, Hk, D, bs, T, 0, P, [13, 7], [1, 1],
                               jnp.float32)
    _check_prefill_parity(case, 0, jnp.float32)


@pytest.mark.slow
@pytest.mark.parametrize("bs", [2, 4, 8, 16])
@pytest.mark.parametrize("P", [2, 4, 8, 16, 32])
def test_paged_flash_prefill_chunk_sweep(bs, P):
    """Heavyweight (bs, chunk) sweep across start offsets — every
    block-boundary alignment of the write span (nightly tier)."""
    B, H, Hk, D = 3, 4, 2, 32
    rng = np.random.default_rng(bs * 100 + P)
    for trial, start0 in enumerate([1, bs - 1, bs, bs + 1, 2 * bs]):
        T = -(-(start0 + 2 * bs + P) // bs) + 2
        starts = [start0] + [1 + int(rng.integers(0, start0 + bs))
                             for _ in range(B - 1)]
        lengths = [P] + [int(rng.integers(1, P + 1)) for _ in range(B - 1)]
        case = _build_prefill_case(23 + trial, B, H, Hk, D, bs, T, 0, P,
                                   starts, lengths, jnp.float32)
        _check_prefill_parity(case, 0, jnp.float32)


# ---------------------------------------------------------------------------
# SCLAD quantized KV pools (int8/fp8 payload + per-position fp32 scales)
# ---------------------------------------------------------------------------

def _quantize_pool(kp, vp, kv_dtype):
    """Compress a dense (N, bs, Hk, D) pool the way the engine stores it:
    per-position-per-head payload + fp32 scales (``models.kv_quant``)."""
    from repro.models import kv_quant
    kq, ks = kv_quant.quantize(kp, kv_dtype)
    vq, vs = kv_quant.quantize(vp, kv_dtype)
    return kq, vq, ks, vs


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode_quantized(kv_dtype, dtype):
    """The fused dequant (payload * scale streamed through the table walk)
    against the gather-then-dequantize oracle."""
    B, H, Hk, D, bs, T = 3, 8, 2, 64, 8, 4
    lengths = np.asarray([T * bs, 1, 13], np.int32)
    k_pool, v_pool, tables = _build_pool(31, B, Hk, D, bs, T, lengths,
                                         jnp.float32)
    kq, vq, ks, vs = _quantize_pool(k_pool, v_pool, kv_dtype)
    q = jax.random.normal(jax.random.PRNGKey(37), (B, H, D)).astype(dtype)
    out = paged_flash_decode(q, kq, vq, jnp.asarray(lengths), tables,
                             kv_scales=(ks, vs), interpret=True)
    ref = paged_decode_ref(q, kq, vq, jnp.asarray(lengths), tables,
                           kv_scales=(ks, vs))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


def _check_prefill_parity_quantized(case, prefix, dtype, kv_dtype):
    """Kernel-vs-reference on a SCLAD pool: attention within tolerance,
    payload POOLS AND SCALES bitwise equal (the in-kernel quantize must
    reproduce ``kv_quant.quantize`` operation-for-operation, and aliasing
    must leave unwritten blocks' payload/scales untouched)."""
    q, kn, vn, kp, vp, lengths, tables, st = case
    B = q.shape[0]
    kq, vq, ks, vs = _quantize_pool(kp.astype(jnp.float32),
                                    vp.astype(jnp.float32), kv_dtype)
    ro, rk, rv, rks, rvs = prefill_attention_ref(
        q, kn, vn, kq, vq, lengths, tables, start=st, prefix=prefix,
        kv_scales=(ks, vs), kv_dtype=kv_dtype)
    sv = jnp.zeros((B,), jnp.int32) if st is None else st
    ko, kk, kv, kks, kvs = paged_flash_prefill(
        q, kn, vn, kq, vq, lengths, tables, sv, prefix=prefix,
        has_ctx=st is not None, interpret=True, kv_scales=(ks, vs),
        kv_dtype=kv_dtype)
    np.testing.assert_allclose(
        np.asarray(ko, np.float32), np.asarray(ro, np.float32),
        atol=tol(dtype), rtol=tol(dtype))
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(kks), np.asarray(rks))
    np.testing.assert_array_equal(np.asarray(kvs), np.asarray(rvs))


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_prefill_quantized_continuation(kv_dtype, dtype):
    """Continuation chunks on a quantized pool: fused context dequant +
    in-kernel quantized scatter vs the host-side reference."""
    B, H, Hk, D, bs, T, P = 3, 8, 2, 64, 8, 4, 8
    rng = np.random.default_rng(41)
    cap = (T - 1) * bs
    starts = [bs] + [1 + int(rng.integers(0, max(cap - P, 1)))
                     for _ in range(B - 1)]
    lengths = [P] + [int(rng.integers(1, P + 1)) for _ in range(B - 1)]
    case = _build_prefill_case(43, B, H, Hk, D, bs, T, 0, P, starts,
                               lengths, dtype)
    _check_prefill_parity_quantized(case, 0, dtype, kv_dtype)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("prefix", [0, 4])
def test_paged_flash_prefill_quantized_first_chunk(kv_dtype, prefix):
    """First chunks (vlm patch prefix included) quantize every written
    position; untouched blocks keep their (garbage) payload and scales."""
    B, H, Hk, D, bs, T, P = 3, 4, 2, 32, 4, 6, 8
    case = _build_prefill_case(47, B, H, Hk, D, bs, T, prefix, P, None,
                               [8, 3, 5], jnp.bfloat16)
    _check_prefill_parity_quantized(case, prefix, jnp.bfloat16, kv_dtype)


def test_quantized_scatter_path_independent():
    """The SAME tokens written as one 8-token chunk or as two 4-token
    chunks leave BITWISE identical payload and scales in the pool — the
    property that makes the hash chain a sound content address for
    compressed blocks (and preemption recompute safe)."""
    B, H, Hk, D, bs, T, P = 1, 4, 2, 32, 4, 4, 8
    case = _build_prefill_case(53, B, H, Hk, D, bs, T, 0, P, [4],
                               [P], jnp.bfloat16)
    q, kn, vn, kp, vp, lengths, tables, st = case
    kq, vq, ks, vs = _quantize_pool(kp.astype(jnp.float32),
                                    vp.astype(jnp.float32), "int8")
    _, k1, v1, ks1, vs1 = prefill_attention_ref(
        q, kn, vn, kq, vq, lengths, tables, start=st,
        kv_scales=(ks, vs), kv_dtype="int8")
    # Same tokens, two half chunks (left-padded to the same width P).
    half = P // 2
    pools = (kq, vq, ks, vs)
    for c in range(2):
        pad = jnp.zeros((B, half) + kn.shape[2:], kn.dtype)
        sl = slice(c * half, (c + 1) * half)
        qc = jnp.concatenate(
            [jnp.zeros((B, half) + q.shape[2:], q.dtype), q[:, sl]], axis=1)
        knc = jnp.concatenate([pad, kn[:, sl]], axis=1)
        vnc = jnp.concatenate([pad, vn[:, sl]], axis=1)
        _, *pools = prefill_attention_ref(
            qc, knc, vnc, pools[0], pools[1],
            jnp.full((B,), half, jnp.int32), tables,
            start=st + c * half, kv_scales=(pools[2], pools[3]),
            kv_dtype="int8")
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(pools[0]))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(pools[1]))
    np.testing.assert_array_equal(np.asarray(ks1), np.asarray(pools[2]))
    np.testing.assert_array_equal(np.asarray(vs1), np.asarray(pools[3]))


# ---------------------------------------------------------------------------
# SCLD matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,C", [
    (128, 256, 128, 6), (256, 128, 256, 16), (128, 384, 256, 4),
    (384, 128, 128, 1)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_sclad_matmul(M, K, N, C, dtype):
    rng = np.random.default_rng(0)
    w = rng.standard_normal((K, N)).astype(np.float32)
    vals, rows = block_compress(w, C)
    x = jnp.asarray(rng.standard_normal((M, K))).astype(dtype)
    y = sclad_matmul(x, jnp.asarray(vals).astype(dtype),
                     jnp.asarray(rows), interpret=True)
    yr = sclad_matmul_ref(x, np.asarray(vals, np.float32), rows)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32),
        atol=1e-1 if dtype == jnp.bfloat16 else 1e-4,
        rtol=5e-2 if dtype == jnp.bfloat16 else 2e-2)


def test_block_compress_roundtrip_full_capacity():
    rng = np.random.default_rng(1)
    w = rng.standard_normal((256, 256)).astype(np.float32)
    vals, rows = block_compress(w, 16)
    assert np.allclose(decompress(vals, rows), w)


def test_block_compress_keeps_largest_units():
    w = np.zeros((128, 128), np.float32)
    w[0:8] = 100.0  # unit 0 is the largest
    w[64:72] = 50.0  # unit 8 second
    vals, rows = block_compress(w, 2)
    assert set(rows[0, 0].tolist()) == {0, 8}
    assert np.allclose(decompress(vals, rows), w)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,S,P,N,chunk", [
    (4, 256, 64, 32, 64), (2, 128, 32, 16, 128), (1, 512, 64, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(BH, S, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xdt = (jax.random.normal(ks[0], (BH, S, P)) * 0.1).astype(dtype)
    a = (-jnp.abs(jax.random.normal(ks[1], (BH, S))) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[2], (BH, S, N)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[3], (BH, S, N)) * 0.3).astype(dtype)
    y, st = ssd_scan(xdt, a, b, c, chunk=chunk, interpret=True)
    yr, str_ = ssd_scan_ref(xdt, a, b, c)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32),
        atol=tol(dtype) * 5, rtol=tol(dtype) * 5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=tol(dtype) * 5, rtol=tol(dtype) * 5)
