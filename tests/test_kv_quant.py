"""SCLAD KV quantization: codec properties + the serving quality gate.

Two layers of pinning for ``models.kv_quant`` (int8/fp8 paged pools):

  * codec unit properties — round-trip error bounds, bit-determinism
    across tracing contexts (the jit-vs-eager constant-multiply pin),
    per-row path independence, payload range safety;
  * the engine quality gate — under quantization the serving engine's
    greedy bit-identity matrix (prefix cache on/off, chunk sizes,
    preemption recompute, kernel on/off) must hold WITHIN an encoding,
    and outputs must stay within a max-logit-error tolerance of the
    fp-exact pool across the dense/moe/vlm families.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import kv_quant
from repro.models import model as M
from repro.serving.engine import ServingEngine

MAX_LEN = 32

#: Quality gate: fp-vs-quantized max abs logit error after a chunked
#: prefill of a 13-token prompt on the reduced configs (logit span ~3).
#: Measured: int8 <= 0.065, fp8 <= 0.172 across all three families —
#: the bounds below carry ~2x margin.
LOGIT_ERR_GATE = {"int8": 0.15, "fp8": 0.35}


# ---------------------------------------------------------------------------
# codec unit properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_roundtrip_error_bound(kv_dtype):
    """Symmetric per-row quantization: reconstruction error is bounded by
    half a quantization step (int8: scale/2; fp8 e4m3: half an ulp at the
    top binade — 16*scale, plus a little double-rounding slack from the
    backend's staged f32 -> e4m3 cast, observed 16.08)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 8, 32),
                          jnp.float32) * 7.0
    payload, scale = kv_quant.quantize(x, kv_dtype)
    assert payload.dtype == kv_quant.payload_dtype(kv_dtype)
    assert scale.dtype == jnp.float32
    assert scale.shape == x.shape[:-1]
    dq = kv_quant.dequantize(payload, scale)
    step = 0.5 if kv_dtype == "int8" else 17.0
    err = jnp.abs(x - dq)
    assert bool(jnp.all(err <= scale[..., None] * step))


def test_zero_rows_roundtrip_exactly():
    """All-zero rows get scale 1.0 and reconstruct exactly (no 0/0)."""
    x = jnp.zeros((4, 2, 16), jnp.float32)
    for kd in kv_quant.QUANTIZED_KV_DTYPES:
        payload, scale = kv_quant.quantize(x, kd)
        assert bool(jnp.all(scale == 1.0))
        assert bool(jnp.all(kv_quant.dequantize(payload, scale) == 0.0))


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantize_bitwise_identical_jit_vs_eager(kv_dtype):
    """Regression pin for the scale arithmetic: XLA rewrites division by a
    constant into reciprocal multiplication under jit but not eagerly, so
    a ``amax / qmax`` scale would drift 1 ulp between the engine's jitted
    writers and eagerly-built test pools.  ``kv_quant`` uses an explicit
    constant multiply — jit and eager must agree BITWISE."""
    x = jax.random.normal(jax.random.PRNGKey(3), (512, 4, 64),
                          jnp.bfloat16)
    pe, se = kv_quant.quantize(x, kv_dtype)
    pj, sj = jax.jit(kv_quant.quantize, static_argnums=1)(x, kv_dtype)
    np.testing.assert_array_equal(np.asarray(pe), np.asarray(pj))
    np.testing.assert_array_equal(
        np.asarray(se).view(np.uint32), np.asarray(sj).view(np.uint32))


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantize_is_per_row_pure(kv_dtype):
    """Each row's (payload, scale) is a pure function of that row alone —
    quantizing a batch equals quantizing rows separately, bitwise.  This
    is the path-independence that makes the hash chain a sound content
    address for compressed blocks."""
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 2, 32), jnp.bfloat16)
    pb, sb = kv_quant.quantize(x, kv_dtype)
    for i in range(x.shape[0]):
        pi, si = kv_quant.quantize(x[i], kv_dtype)
        np.testing.assert_array_equal(np.asarray(pb[i]), np.asarray(pi))
        np.testing.assert_array_equal(np.asarray(sb[i]), np.asarray(si))


def test_int8_payload_never_overflows():
    """round(x/scale) sits in [-127, 127] by construction (127.00002
    rounds to 127): adversarial magnitudes must not wrap the int8 cast."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(np.concatenate([
        rng.standard_normal((128, 16)) * 1e6,
        rng.standard_normal((128, 16)) * 1e-6,
        np.full((1, 16), 3.0),
    ]), jnp.float32)
    payload, _ = kv_quant.quantize(x, "int8")
    p = np.asarray(payload, np.int32)
    assert p.max() <= 127 and p.min() >= -127
    f8, _ = kv_quant.quantize(x, "fp8")
    assert bool(jnp.all(jnp.isfinite(f8.astype(jnp.float32))))


def test_fake_quant_is_the_readers_view():
    """fake_quant(x) == dequantize(quantize(x)) in x's dtype, bitwise —
    what the prefill paths attend to in-chunk must be exactly what a pool
    reader later observes."""
    x = jax.random.normal(jax.random.PRNGKey(11), (16, 2, 32), jnp.bfloat16)
    for kd in kv_quant.QUANTIZED_KV_DTYPES:
        fq = kv_quant.fake_quant(x, kd)
        assert fq.dtype == x.dtype
        p, s = kv_quant.quantize(x, kd)
        np.testing.assert_array_equal(
            np.asarray(fq, np.float32),
            np.asarray(kv_quant.dequantize(p, s, x.dtype), np.float32))


def test_unknown_kv_dtype_rejected():
    with pytest.raises(ValueError):
        kv_quant.is_quantized("int4")
    with pytest.raises(ValueError):
        kv_quant.payload_dtype("fp")
    with pytest.raises(ValueError):
        kv_quant.qmax("bf16")
    assert not kv_quant.is_quantized("fp")
    assert kv_quant.is_quantized("int8") and kv_quant.is_quantized("fp8")


# ---------------------------------------------------------------------------
# engine quality gate: the greedy matrix under quantization
# ---------------------------------------------------------------------------

def _make(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, prompts, budgets, **kw):
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                        eos_id=-1, block_size=4, **kw)
    uids = [eng.submit(p, max_new_tokens=m)
            for p, m in zip(prompts, budgets)]
    out = eng.run()
    return eng, [out[u] for u in uids]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
def test_quantized_greedy_matrix_bit_identical(arch):
    """WITHIN kv_dtype="int8" the engine's full greedy bit-identity matrix
    holds: prefix cache on/off, chunk sizes, and preemption recompute all
    produce the same tokens — every reader observes each token through its
    quantized form, so scheduling history cannot leak into outputs."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(13)
    shared = rng.integers(1, cfg.vocab_size, size=13)
    prompts = [np.concatenate([shared,
                               rng.integers(1, cfg.vocab_size, size=n)])
               for n in (3, 5, 2)]
    budgets = (6, 5, 7)

    base = _run(cfg, params, prompts, budgets, kv_dtype="int8",
                prefill_chunk=8)[1]
    eng_nopc, out = _run(cfg, params, prompts, budgets, kv_dtype="int8",
                         prefill_chunk=8, prefix_cache=False)
    assert out == base
    assert eng_nopc.stats.cached_prompt_tokens == 0
    eng_pc, out = _run(cfg, params, prompts, budgets, kv_dtype="int8",
                       prefill_chunk=4)
    assert out == base
    assert eng_pc.stats.cached_prompt_tokens > 0  # the cache really fired
    # Pool pressure: force preemption + recompute (quantize-on-rewrite must
    # land bitwise-identical blocks, or outputs would drift).
    eng_small, out = _run(cfg, params, prompts, budgets, kv_dtype="int8",
                          prefill_chunk=8, num_blocks=9)
    assert out == base
    assert eng_small.stats.preemptions >= 1
    eng_small._alloc.check_invariants()


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_kernel_scheduler_bit_transparent(kv_dtype):
    """Quantized pools with the Pallas kernels ON (interpret mode): the
    scheduler stays bit-transparent — prefix cache on/off and chunk size
    produce identical greedy tokens.  Every comparison here is WITHIN one
    (encoding, kernel) pair, so near-tie argmax cannot flip anything and
    fp8 is safe to pin exactly like int8.  Kernel-vs-reference greedy is
    a TOLERANCE property (one-pass fp32 online softmax vs the two-pass
    reference can flip near-tie argmax, exactly as on fp pools) and is
    deliberately NOT asserted here — the pools-bitwise hard gate lives in
    test_quantized_pool_bitwise_kernel_vs_ref below."""
    cfg, params = _make("tinyllama-1.1b")
    rng = np.random.default_rng(17)
    system = rng.integers(1, cfg.vocab_size, size=8)
    prompts = [np.concatenate([system,
                               rng.integers(1, cfg.vocab_size, size=n)])
               for n in (5, 13, 9)]
    budgets = (6, 4, 5)
    eng_pc, base = _run(cfg, params, prompts, budgets, kv_dtype=kv_dtype,
                        prefill_chunk=8, attn_kernel="on")
    assert eng_pc.stats.cached_prompt_tokens > 0  # sharing really fired
    assert _run(cfg, params, prompts, budgets, kv_dtype=kv_dtype,
                prefill_chunk=8, attn_kernel="on",
                prefix_cache=False)[1] == base
    assert _run(cfg, params, prompts, budgets, kv_dtype=kv_dtype,
                prefill_chunk=4, attn_kernel="on")[1] == base


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_pool_bitwise_determinism_and_greedy_gate(kv_dtype):
    """The DERANDOMIZED kernel-vs-reference gate for quantized pools.

    History: the nightly used to flap on fp8 because kernel-vs-reference
    was gated on GREEDY TOKENS over unpinned traces — the one-pass fp32
    online softmax and the two-pass bf16 reference land logits an ulp
    apart, and an fp8 pool's coarser dequant occasionally turns that ulp
    into a near-tie argmax flip on some draws (rng seed 23 reproduces one
    deterministically on this config: request 1 of that trace flips).
    Nothing bitwise relates kernel and reference pools at the ENGINE
    level either: layer l>0's K/V projections consume layer l-1's
    attention output, so one ulp upstream re-quantizes downstream blocks
    differently.  (Same-input kernel-vs-ref bitwise parity — payload AND
    scales — is pinned where it is true, in tests/test_kernels.py.)

    The hard gate that must never move is therefore DETERMINISM of the
    pool bytes: the same pinned trace through the same configuration
    writes bitwise-identical payload and scales every run, both parked
    mid-prefill (prompt-only content) and after the full run — flap is
    impossible unless real nondeterminism appears, which is exactly what
    this test exists to catch."""
    cfg, params = _make("tinyllama-1.1b")
    rng = np.random.default_rng(17)  # pinned: no near-tie on this trace
    prompt = rng.integers(1, cfg.vocab_size, size=16)

    def park(kernel):
        # Chunk 8 of a 16-token prompt: the first step() consumes one
        # chunk and parks BEFORE decode — only prompt content (no
        # sampled token) is in the pool.
        eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                            eos_id=-1, block_size=4, prefill_chunk=8,
                            kv_dtype=kv_dtype, attn_kernel=kernel)
        eng.submit(prompt, max_new_tokens=4)
        eng.step()
        assert eng._prefilling and not eng._host_active.any(), (
            "test premise broken: prefill should be parked mid-prompt")
        return eng

    a, b = park("on"), park("on")
    assert set(a._cache) == {"k", "v", "k_scale", "v_scale"}
    for name in a._cache:
        np.testing.assert_array_equal(
            np.asarray(a._cache[name]), np.asarray(b._cache[name]),
            err_msg=f"{kv_dtype}/{name}: quantized prefill writes are "
                    f"not run-to-run deterministic (parked mid-prefill)")
    out_a, out_b = a.run(), b.run()
    assert out_a == out_b
    for name in a._cache:
        np.testing.assert_array_equal(
            np.asarray(a._cache[name]), np.asarray(b._cache[name]),
            err_msg=f"{kv_dtype}/{name}: pool bytes diverged across "
                    f"identical full runs")
    # Pinned-seed soft gate: on THIS trace the greedy outputs also agree
    # between kernel and reference (seed 17 was chosen because it has no
    # near-tie; seed 23 demonstrably flips under fp8).  In-process
    # bit-determinism (asserted above) makes this stable run-to-run; if
    # a future jax bump shifts an ulp and a near-tie appears here,
    # re-pin the seed — the determinism assertions are the hard gate.
    eng_off = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                            eos_id=-1, block_size=4, prefill_chunk=8,
                            kv_dtype=kv_dtype, attn_kernel="off")
    eng_off.submit(prompt, max_new_tokens=4)
    assert list(out_a.values()) == list(eng_off.run().values())


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_logits_within_gate_of_fp(arch, kv_dtype):
    """The vs-fp-exact half of the quality gate: last-token logits after a
    chunked prefill stay within LOGIT_ERR_GATE of the fp pool's."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=13)
    logits = {}
    for mode in ("fp", kv_dtype):
        from dataclasses import replace as dc_replace
        c = dc_replace(cfg, kv_dtype=mode)
        cache = M.init_paged_cache(c, 9, 4)
        kw = {}
        if c.family == "vlm":
            kw["patch_embeds"] = jnp.zeros(
                (1, c.num_patches, c.d_model), jnp.bfloat16)
        lg, _ = M.prefill_slots(
            c, params, cache, jnp.asarray(prompt[None], jnp.int32),
            jnp.asarray([13], jnp.int32),
            jnp.asarray(np.arange(1, 5)[None], jnp.int32), **kw)
        logits[mode] = np.asarray(lg[0], np.float32)
    err = np.abs(logits["fp"] - logits[kv_dtype]).max()
    assert err <= LOGIT_ERR_GATE[kv_dtype], (
        f"{arch}/{kv_dtype}: max logit error {err} above gate")


def test_quantized_pool_leaves_and_bytes():
    """init_paged_cache carries payload + scale leaves for quantized
    kv_dtype, copy_cache_block copies them together, and the engine's
    kv_block_bytes prices the TRUE compressed layout (payload + scales),
    coming out smaller than the fp pool's."""
    cfg, params = _make("tinyllama-1.1b")
    from dataclasses import replace as dc_replace
    c8 = dc_replace(cfg, kv_dtype="int8")
    cache = M.init_paged_cache(c8, 5, 4)
    assert set(cache) == {"k", "v", "k_scale", "v_scale"}
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].dtype == jnp.float32
    assert cache["k_scale"].shape == cache["k"].shape[:-1]
    # copy_cache_block moves payload AND scales.
    cache = {k: (v + 1 if v.dtype != jnp.int8 else v + jnp.int8(1))
             for k, v in cache.items()}
    out = M.copy_cache_block(cache, 1, 3)
    for name in cache:
        np.testing.assert_array_equal(np.asarray(out[name][:, 3]),
                                      np.asarray(cache[name][:, 1]))
    # Engine-visible byte pricing: compressed < fp, and equal to the sum
    # over every leaf of the real device buffers.
    e_fp = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                         block_size=4, kv_dtype="fp")
    e_i8 = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                         block_size=4, kv_dtype="int8")
    assert e_i8.kv_block_bytes < e_fp.kv_block_bytes
    want = sum(int(np.prod(x.shape)) // x.shape[1] * x.dtype.itemsize
               for x in e_i8._cache.values())
    assert e_i8.kv_block_bytes == want
    e_i8.submit(np.arange(1, 6), max_new_tokens=2)
    e_i8.run()
    assert e_i8.stats.peak_pool_bytes \
        == e_i8.stats.peak_live_blocks * e_i8.kv_block_bytes
