"""Co-design engine tests: hardware model invariants, TCO calibration
against the paper's Table 2, mapping-search properties, SCLD codec
(hypothesis property tests on the system's invariants).
"""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import explore, hardware, perf, sparsity, tco
from repro.core.workloads import PAPER_MODELS, LLMWorkload


# ---------------------------------------------------------------------------
# Hardware model
# ---------------------------------------------------------------------------

@given(st.floats(20.0, 800.0))
def test_yield_in_unit_interval(die):
    c = hardware.ChipConfig(die_mm2=die, sram_mb=10, tflops=1)
    assert 0.0 < c.die_yield() <= 1.0


@given(st.floats(20.0, 400.0), st.floats(20.1, 400.0))
def test_bigger_die_costs_more_per_area(a, b):
    small, big = sorted([a, b])
    if big - small < 1:
        return
    # Silicon $/mm^2 (excluding the fixed per-die test cost) grows with die
    # size: yield drops + rectangular packing loss — the paper's Fig 7 lever.
    def si_cost_per_mm2(die):
        c = hardware.ChipConfig(die_mm2=die, sram_mb=1, tflops=1)
        return (hardware.WAFER_COST / c.dies_per_wafer()) / c.die_yield() / die

    assert si_cost_per_mm2(big) >= si_cost_per_mm2(small) * 0.999


def test_dies_per_wafer_sane():
    c = hardware.ChipConfig(die_mm2=100, sram_mb=1, tflops=1)
    # 300mm wafer area ~70,685 mm^2; with edge loss expect ~600 dies.
    assert 450 <= c.dies_per_wafer() <= 707


def test_sweep_respects_constraints():
    servers = hardware.sweep_servers()
    assert len(servers) > 500  # "tens of thousands" scaled to test time
    for s in servers[::37]:
        assert s.feasible()
        assert s.power_per_lane <= hardware.MAX_POWER_PER_LANE_W
        assert s.silicon_per_lane <= hardware.MAX_SILICON_PER_LANE_MM2
        assert s.chip.used_area <= s.chip.die_mm2 + 1e-9


# ---------------------------------------------------------------------------
# TCO + calibration vs Table 2
# ---------------------------------------------------------------------------

def test_capex_dominates_tco():
    """Paper §5.2: CapEx exceeds 80% of TCO for most designs."""
    servers = hardware.sweep_servers()
    fracs = [tco.server_tco(s).capex_fraction for s in servers[::17]]
    assert np.median(fracs) > 0.6


@pytest.mark.slow
def test_gpt3_calibration_vs_table2():
    servers = explore.phase1_servers()
    res = explore.explore(PAPER_MODELS["gpt3-175b"], ctx=2048,
                          servers=servers, keep_all=False)
    row = res.best.table_row()
    # Paper Table 2: die 140 mm^2, 225.8 MB, 5.50 TF, $0.161/1M tokens.
    assert 60 <= row["die_mm2"] <= 300
    assert 100 <= row["mb_per_chip"] <= 500
    assert row["tco_per_mtoken"] < 0.161 * 3.0
    assert row["tco_per_mtoken"] > 0.161 / 3.0


def test_mapping_obeys_capacity():
    wl = PAPER_MODELS["gpt2-1.5b"]
    chip = hardware.ChipConfig(die_mm2=60, sram_mb=33, tflops=5.6)
    server = hardware.ServerConfig(chip=chip, chips_per_lane=16)
    dp = perf.best_mapping(server, wl, ctx=1024)
    assert dp is not None
    assert dp.perf.mem_per_chip_mb <= chip.sram_mb * 0.9 + 1e-6


def test_pipeline_schedule_formula():
    """l_token = max(l_mb, n*l_s): more microbatches only helps until n=p."""
    wl = PAPER_MODELS["megatron-8.3b"]
    chip = hardware.ChipConfig(die_mm2=40, sram_mb=27, tflops=2.87)
    server = hardware.ServerConfig(chip=chip, chips_per_lane=18)
    lat = {}
    for n in (1, 2, 4, 8):
        r = perf.evaluate(server, wl, 1024,
                          perf.Mapping(tp=server.num_chips, pp=8, batch=8,
                                       microbatches=n))
        if r:
            lat[n] = r.latency_per_token
    assert lat, "no feasible mapping"
    best_n = min(lat, key=lat.get)
    assert best_n >= 4  # n close to p is optimal (paper Fig 9)


# ---------------------------------------------------------------------------
# SCLD sparsity model + codec
# ---------------------------------------------------------------------------

@given(st.floats(0.0, 0.95))
def test_storage_factor_bounds(s):
    f = sparsity.storage_factor(s)
    assert 0.0 < f <= 1.0


def test_storage_factor_sweet_spot():
    # Below 1/3 sparsity the sparse encoding is bigger -> store dense (1.0).
    assert sparsity.storage_factor(0.2) == 1.0
    # 60% sparsity: 0.4*24/16 = 0.6 + index overhead.
    assert 0.55 < sparsity.storage_factor(0.6) < 0.65
    # Paper Fig 13: 1.7x larger model at 60% sparsity.
    assert 1.55 < sparsity.max_model_scale(0.6) < 1.75


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3),
       st.floats(0.0, 0.9), st.integers(0, 2 ** 31 - 1))
def test_tile_csr_roundtrip(tr, tc, s, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((32 * tr, 8 * tc)).astype(np.float32)
    w = sparsity.sparsify(w, s, seed)
    t = sparsity.encode(w)
    assert np.allclose(sparsity.decode(t), w)
    # Stored bits never exceed dense-equivalent by more than index overhead.
    dense_bits = w.size * 16
    if s >= 0.5:
        assert t.stored_bits() < dense_bits


def test_sparse_tco_improvement():
    """Paper Fig 13: ~7.4% TCO/token gain at 60% sparsity for OPT-175B."""
    wl = PAPER_MODELS["gpt3-175b"]  # same shape as OPT-175B
    chip = hardware.ChipConfig(die_mm2=140, sram_mb=226, tflops=5.5)
    server = hardware.ServerConfig(chip=chip, chips_per_lane=17)
    dense = perf.best_mapping(server, wl, ctx=2048)
    import dataclasses
    wl_sparse = dataclasses.replace(
        wl, weight_storage_factor=sparsity.storage_factor(0.6))
    sparse = perf.best_mapping(server, wl_sparse, ctx=2048)
    assert dense and sparse
    assert sparse.tco_per_mtoken < dense.tco_per_mtoken
