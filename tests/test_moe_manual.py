"""Manual-collective MoE (shard_map all-to-all) vs the auto-partitioned
path, on 8 forced host devices in a subprocess.

MoE outputs can differ at individual tokens under ANY parallelism change
(router logit ties flip expert choice), so the check is: >=99% of tokens
match tightly and the aux loss agrees.
"""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config
    from repro.models import moe as moe_lib
    from repro.parallel import sharding as sh
    from repro.launch import mesh as mesh_lib

    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(0))
    x = (jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                           jnp.float32) * 0.5).astype(jnp.bfloat16)

    class FM0:
        axis_names = ()
        devices = np.zeros((1,))
    sh.set_mesh_axis_sizes(FM0())
    ref, aux_ref = moe_lib.apply_moe(cfg, p, x)
    ref = np.asarray(ref, np.float32)

    mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
    sh.set_mesh_axis_sizes(mesh)
    assert moe_lib.manual_path_available(cfg, 4 * 32)
    with sh.mesh_context(mesh):
        out, aux = jax.jit(
            lambda p_, x_: moe_lib.apply_moe_manual(cfg, p_, x_))(p, x)
    out = np.asarray(out, np.float32)
    scale = np.abs(ref).max() + 1e-9
    tok_err = np.abs(out - ref).max(axis=-1) / scale
    frac_ok = (tok_err < 0.02).mean()
    assert frac_ok >= 0.99, frac_ok
    assert abs(float(aux) - float(aux_ref)) < 0.05
    print("MOE_MANUAL_OK", frac_ok)
""")


@pytest.mark.slow  # forced-8-device subprocess: multi-minute XLA compile
def test_moe_manual_matches_auto():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests", 1)[0], timeout=600)
    assert "MOE_MANUAL_OK" in r.stdout, r.stdout + r.stderr
