"""Speculative multi-token decoding: draft -> one-pass verify -> rollback.

The engine contract under test (``ServingEngine(spec_decode="ngram")``):

  * emitted tokens are BIT-IDENTICAL to ``spec_decode="off"`` on the jnp
    reference attention path, for greedy AND stochastic sampling, across
    the serving matrix — dense/moe/vlm, prefix cache on/off, chunked
    prefill, preemption under a tight pool, quantized (int8 SCLAD) pools,
    ``decode_steps > 1`` on the plain engine, and every ``spec_k``;
  * the PRNG fast-forward rule: a request's position advances only by
    ACCEPTED tokens and every verify position re-samples with its
    positional key (``sampler.positional_keys``), so rejected drafts
    never consume or skip randomness;
  * rejected drafts roll their optimistically-written K/V back through
    ``BlockStore.truncate`` — pool invariants must hold after every run;
  * under ``attn_kernel="on"`` decode-position scoring moves from the
    flash-decode kernel to the flash-prefill kernel, whose online-softmax
    tiling differs — spec-vs-off there is a CROSS-KERNEL comparison and
    (like kernel-vs-reference) is a tolerance property, not a bitwise
    one; what must still hold bitwise are the scheduling invariants
    WITHIN the speculative configuration (pinned below).

The (spec_k x chunk-size x preemption) sweep is ``slow``-marked for the
nightly tier; the fast tier pins one representative of each axis.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.sampler import SamplerConfig
from repro.serving.spec import NgramProposer, make_proposer

MAX_LEN = 32


def _make(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def tiny():
    return _make("tinyllama-1.1b")


def _requests(cfg, n=3, seed=0, budgets=(6, 8, 5)):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 12))),
             budgets[i % len(budgets)]) for i in range(n)]


def _run(cfg, params, reqs, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("eos_id", -1)
    eng = ServingEngine(cfg, params, **kw)
    uids = [eng.submit(p, max_new_tokens=m) for p, m in reqs]
    out = eng.run()
    eng._alloc.check_invariants()
    return [out[u] for u in uids], eng.stats


# -- the draft proposer alone --------------------------------------------


def test_ngram_proposer_suffix_match():
    """The proposer continues the RIGHTMOST earlier occurrence of the
    longest matching suffix n-gram (n from max_n down to min_n),
    preferring occurrences with a full k tokens of continuation."""
    p = NgramProposer(max_n=3, min_n=1)
    #           0  1  2  3  4  5  6  7
    history = [5, 6, 7, 9, 5, 6, 7, 2]
    # suffix (6, 7, 2): no earlier occurrence; (7, 2): none; (2,): none.
    assert p.propose(history, 4) == []
    # suffix (5, 6, 7) at the end matches positions 0-2 -> continues [9, ...]
    assert p.propose([5, 6, 7, 9, 5, 6, 7], 3) == [9, 5, 6]
    # k caps the continuation
    assert p.propose([5, 6, 7, 9, 5, 6, 7], 1) == [9]
    # rightmost match wins: ... 1 2 [8] ... 1 2 [4] | 1 2 -> 4, not 8
    assert p.propose([1, 2, 8, 1, 2, 4, 1, 2], 1) == [4]
    # unigram fallback (min_n=1): last token seen before -> its successor
    assert p.propose([3, 9, 3], 2) == [9, 3]
    # with-room preference: on a period-1 cycle the match flush against
    # the end offers a 1-token draft; an occurrence k earlier replays a
    # full k tokens of the same cycle.
    assert p.propose([7] * 6, 3) == [7, 7, 7]
    # ...but a short continuation is still better than none (fallback).
    assert p.propose([5, 6, 2, 5, 6], 4) == [2, 5, 6]
    # degenerate histories
    assert p.propose([], 4) == []
    assert p.propose([7], 4) == []
    assert p.propose([7, 7], 0) == []


def test_make_proposer():
    assert make_proposer("off") is None
    assert isinstance(make_proposer("ngram"), NgramProposer)
    with pytest.raises(ValueError):
        make_proposer("oracle")


def test_spec_constructor_validation(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, spec_decode="oracle")
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, spec_decode="ngram", spec_k=0)
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, mode="wave", spec_decode="ngram")


# -- bit-identity on the reference path ----------------------------------


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
def test_spec_bit_identical_across_archs(arch):
    """dense / moe / vlm: greedy outputs must not move, and the verify
    pass must actually batch tokens (fewer host-synced decode steps than
    tokens generated)."""
    cfg, params = _make(arch)
    reqs = _requests(cfg)
    off, s_off = _run(cfg, params, reqs)
    on, s_on = _run(cfg, params, reqs, spec_decode="ngram", spec_k=4)
    assert on == off
    assert s_on.generated_tokens == s_off.generated_tokens
    assert s_on.spec_passes > 0
    assert 0.0 <= s_on.spec_acceptance_rate <= 1.0
    # Each verify pass emits >= 1 token per live lane, so spec never needs
    # MORE host-synced steps than plain decode (strictly fewer once the
    # critical-path lane accepts a draft).
    assert s_on.decode_steps <= s_off.decode_steps


@pytest.mark.parametrize("knobs", [
    {"prefix_cache": False},
    {"prefill_chunk": 4, "block_size": 4},
    {"kv_dtype": "int8"},
    {"num_blocks": 8, "block_size": 4},  # tight pool: preemption + spec
    {"sampler": SamplerConfig(temperature=0.8, top_k=10)},
    {"spec_k": 1},
    {"spec_k": 2},
], ids=["prefix_off", "chunked", "int8", "preempt", "stochastic",
        "spec_k1", "spec_k2"])
def test_spec_bit_identical_knob_matrix(tiny, knobs):
    """Every scheduling/sampling knob crossed with speculation on the
    reference path.  The stochastic case is the PRNG fast-forward pin:
    temperature sampling accepts ~no drafts, yet outputs stay identical
    because positions only advance by accepted tokens."""
    cfg, params = tiny
    knobs = dict(knobs)
    spec_k = knobs.pop("spec_k", 4)
    reqs = _requests(cfg, seed=3)
    off, s_off = _run(cfg, params, reqs, **knobs)
    on, s_on = _run(cfg, params, reqs, spec_decode="ngram", spec_k=spec_k,
                    **knobs)
    assert on == off
    if "num_blocks" in knobs:
        assert s_on.preemptions >= 1, "tight pool should preempt under spec"
    if "sampler" in knobs:
        # Random samples essentially never equal a history-matched draft.
        assert s_on.spec_acceptance_rate <= 0.2


def test_spec_bit_identical_vs_decode_steps_window(tiny):
    """Plain decode with ``decode_steps > 1`` (the windowed host-sync
    amortization) and speculative decode must agree token-for-token —
    both are multi-token-per-sync schedules over the same sampling rule."""
    cfg, params = tiny
    reqs = _requests(cfg, seed=5)
    off, _ = _run(cfg, params, reqs, decode_steps=3)
    on, _ = _run(cfg, params, reqs, spec_decode="ngram", spec_k=4)
    assert on == off


def test_spec_budget_edges_and_eos_inside_draft(tiny):
    """A lane's chunk clamps to its remaining budget (max_new=1 admits no
    drafts at all), and an EOS landing INSIDE an accepted draft prefix
    retires the request exactly where plain decode would."""
    cfg, params = tiny
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab_size, size=n) for n in (5, 8)]
    for budget in (1, 2):
        reqs = [(p, budget) for p in prompts]
        off, _ = _run(cfg, params, reqs)
        on, _ = _run(cfg, params, reqs, spec_decode="ngram", spec_k=4)
        assert on == off
        assert all(len(t) == budget for t in on)
    # Pick the token plain decode emits mid-stream as EOS and rerun: both
    # paths must stop at its first occurrence.
    reqs = [(p, 8) for p in prompts]
    off, _ = _run(cfg, params, reqs)
    eos = off[0][3]
    off_eos, _ = _run(cfg, params, reqs, eos_id=eos)
    on_eos, _ = _run(cfg, params, reqs, eos_id=eos, spec_decode="ngram",
                     spec_k=4)
    assert on_eos == off_eos
    assert off_eos[0][-1] == eos
    assert len(off_eos[0]) == off[0].index(eos) + 1


def test_spec_stats_accounting(tiny):
    """Counter relations: one verify pass per step, acceptance bounded by
    proposals, and rejected drafts prove the truncate rollback ran."""
    cfg, params = tiny
    reqs = _requests(cfg, seed=7, budgets=(8, 8, 8))
    on, s = _run(cfg, params, reqs, spec_decode="ngram", spec_k=4)
    assert s.spec_passes == s.decode_steps > 0
    assert 0 <= s.spec_accepted <= s.spec_proposed
    assert s.generated_tokens == sum(len(t) for t in on)
    # Random-prompt greedy rejects some drafts -> the rollback path ran
    # (and _run's check_invariants already held after it).
    assert s.spec_proposed > s.spec_accepted


# -- kernel path: scheduling invariants within the spec configuration ----


def test_spec_kernel_scheduling_invariants(tiny):
    """Under ``attn_kernel="on"`` spec-vs-off is a cross-kernel tolerance
    property (see module docstring) — what must stay BITWISE is the
    scheduler under speculation: prefix sharing on vs off cannot move a
    token when both runs speculate through the kernels."""
    cfg, params = tiny
    rng = np.random.default_rng(11)
    system = rng.integers(1, cfg.vocab_size, size=8)
    reqs = [(np.concatenate([system,
                             rng.integers(1, cfg.vocab_size, size=4)]), 6)
            for _ in range(3)]
    # max_batch=2 staggers the third request behind a retirement, so its
    # admission revives the donor's pooled prefix blocks (a guaranteed
    # cache hit — concurrent same-round admissions may not see one).
    kw = dict(attn_kernel="on", spec_decode="ngram", spec_k=4,
              block_size=4, prefill_chunk=8, max_batch=2)
    on_cache, s_cache = _run(cfg, params, reqs, **kw)
    no_cache, _ = _run(cfg, params, reqs, prefix_cache=False, **kw)
    assert on_cache == no_cache
    assert s_cache.prefix_hit_rate > 0
    assert s_cache.spec_passes > 0


# -- nightly sweep -------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b"])
@pytest.mark.parametrize("spec_k", [1, 2, 4])
@pytest.mark.parametrize("block_size,chunk", [(4, 4), (8, 16)])
@pytest.mark.parametrize("pool", ["ample", "tight"])
def test_spec_matrix_sweep(arch, spec_k, block_size, chunk, pool):
    """(spec_k x chunk-size x preemption) x {dense, moe}: the full
    reference-path bit-identity sweep."""
    cfg, params = _make(arch)
    reqs = _requests(cfg, seed=13)
    kw = dict(block_size=block_size, prefill_chunk=chunk)
    if pool == "tight":
        kw["num_blocks"] = 10 if block_size == 4 else 6
    off, _ = _run(cfg, params, reqs, **kw)
    on, s_on = _run(cfg, params, reqs, spec_decode="ngram", spec_k=spec_k,
                    **kw)
    assert on == off
    if pool == "tight":
        assert s_on.preemptions >= 1
