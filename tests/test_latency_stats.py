"""Latency-distribution stats + the bench artifact's structural gate.

Three layers, all deterministic (no engine runs, no wall clocks):

  * ``EngineStats.percentile`` / the ``p50_/p99_ttft_s`` and
    ``p50_/p99_itl_s`` accessors — unit pins on hand-built histories
    (nearest-rank semantics: the ceil(q/100*n)-th order statistic, so a
    pinned history has ONE right answer, no interpolation ambiguity);
  * ``ServingEngine._note_tokens`` — the per-host-sync recording rule
    that feeds those histories (first observation is the TTFT sample and
    contributes no ITL; later windows spread the observed gap over the
    tokens that arrived in them), pinned on hand-fed timestamps;
  * ``benchmarks.serving_bench.validate_bench`` — the schema gate run
    before ``BENCH_serving.json`` is written: a malformed artifact must
    fail the bench step in CI, not upload silently.
"""
import math

import numpy as np
import pytest

from benchmarks.serving_bench import BENCH_SCHEMA, validate_bench
from repro.serving.engine import EngineStats, ServingEngine


# ---------------------------------------------------------------------------
# percentile accessors
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank_pins():
    """Nearest-rank on a pinned history: p50 of 4 samples is the 2nd
    order statistic, p99 the 4th; order of insertion is irrelevant."""
    h = [4.0, 1.0, 3.0, 2.0]
    assert EngineStats.percentile(h, 50.0) == 2.0
    assert EngineStats.percentile(h, 75.0) == 3.0
    assert EngineStats.percentile(h, 99.0) == 4.0
    assert EngineStats.percentile(h, 100.0) == 4.0
    # 25% of 4 -> ceil(1.0) = 1st order statistic.
    assert EngineStats.percentile(h, 25.0) == 1.0
    # A tiny q still returns the minimum, never an index-out-of-range.
    assert EngineStats.percentile(h, 0.5) == 1.0
    assert EngineStats.percentile([7.25], 50.0) == 7.25
    assert EngineStats.percentile([7.25], 99.0) == 7.25


def test_percentile_empty_and_invalid_q():
    assert EngineStats.percentile([], 50.0) == 0.0
    assert EngineStats.percentile([], 99.0) == 0.0
    for q in (0.0, -1.0, 101.0):
        with pytest.raises(ValueError, match="percentile"):
            EngineStats.percentile([1.0], q)


def test_percentile_large_history_matches_rank_formula():
    rng = np.random.default_rng(3)
    h = rng.exponential(1.0, size=137).tolist()
    xs = sorted(h)
    for q in (50.0, 90.0, 99.0):
        want = xs[math.ceil(q / 100.0 * len(xs)) - 1]
        assert EngineStats.percentile(h, q) == want


def test_stats_properties_read_the_histories():
    s = EngineStats()
    s.ttft_history = [0.5, 0.1, 0.9, 0.3]
    s.itl_history = [0.01, 0.05, 0.02, 0.04, 0.03]
    assert s.p50_ttft_s == 0.3
    assert s.p99_ttft_s == 0.9
    assert s.p50_itl_s == 0.03
    assert s.p99_itl_s == 0.05
    empty = EngineStats()
    assert empty.p50_ttft_s == 0.0 and empty.p99_itl_s == 0.0


# ---------------------------------------------------------------------------
# _note_tokens: the recording rule behind the histories
# ---------------------------------------------------------------------------

def _bare_engine():
    """An engine skeleton with exactly the state _note_tokens touches —
    no model, no jit, so the timestamps are fully hand-controlled."""
    eng = ServingEngine.__new__(ServingEngine)
    eng.stats = EngineStats()
    eng._submit_t = {}
    eng._last_obs_t = {}
    return eng


def test_note_tokens_first_window_is_ttft_only():
    """The first observed window yields ONE TTFT sample and no ITL —
    even when decode_steps > 1 delivered several tokens at that first
    host sync (they share the sync; there is no measurable gap)."""
    eng = _bare_engine()
    eng._submit_t[7] = 10.0
    eng._note_tokens(7, 3, 10.5)
    assert eng.stats.ttft_history == [0.5]
    assert eng.stats.itl_history == []
    assert eng.stats.ttft_count == 1
    assert eng.stats.ttft_s_sum == 0.5
    assert eng._last_obs_t[7] == 10.5
    assert 7 not in eng._submit_t  # consumed: preemption cannot re-TTFT


def test_note_tokens_spreads_window_gap_over_tokens():
    """Observation granularity: a later host sync that released m tokens
    records m ITL samples of gap/m each — with decode_steps=1 every
    sample is a real host-sync gap, with K>1 the window mean."""
    eng = _bare_engine()
    eng._submit_t[1] = 0.0
    eng._note_tokens(1, 1, 1.0)   # TTFT 1.0
    eng._note_tokens(1, 1, 1.25)  # one token, gap 0.25
    eng._note_tokens(1, 4, 2.25)  # four tokens share a 1.0s window
    assert eng.stats.ttft_history == [1.0]
    assert eng.stats.itl_history == [0.25, 0.25, 0.25, 0.25, 0.25]
    assert eng.stats.p99_itl_s == 0.25


def test_note_tokens_zero_tokens_is_a_no_op():
    eng = _bare_engine()
    eng._submit_t[2] = 5.0
    eng._note_tokens(2, 0, 6.0)
    assert eng.stats.ttft_history == [] and eng.stats.itl_history == []
    assert 2 in eng._submit_t  # still waiting for its first token


def test_note_tokens_interleaved_requests_do_not_cross():
    """Per-uid last-observation clocks: interleaved requests' gaps never
    contaminate each other's histories."""
    eng = _bare_engine()
    eng._submit_t.update({1: 0.0, 2: 0.5})
    eng._note_tokens(1, 1, 1.0)
    eng._note_tokens(2, 1, 1.0)
    eng._note_tokens(1, 1, 3.0)  # uid 1 gap: 2.0
    eng._note_tokens(2, 1, 1.5)  # uid 2 gap: 0.5
    assert eng.stats.ttft_history == [1.0, 0.5]
    assert sorted(eng.stats.itl_history) == [0.5, 2.0]


# ---------------------------------------------------------------------------
# BENCH_serving.json schema gate
# ---------------------------------------------------------------------------

def _valid_bench():
    """Build the minimal dict satisfying every BENCH_SCHEMA path, typed
    from the schema itself — so the fixture can never drift from it."""
    bench: dict = {}
    dummies = {bool: True, int: 3, str: "x", dict: {"k": 1.0}, list: []}
    for path, typ in BENCH_SCHEMA:
        node = bench
        keys = path.split(".")
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = dummies.get(typ, 0.25)
    return bench


def test_bench_schema_accepts_valid():
    validate_bench(_valid_bench())  # must not raise


def test_bench_schema_rejects_missing_path():
    bench = _valid_bench()
    del bench["open_loop"]["moderate"]["client_p99_ttft_s"]
    with pytest.raises(ValueError, match="client_p99_ttft_s"):
        validate_bench(bench)
    bench = _valid_bench()
    del bench["open_loop"]
    with pytest.raises(ValueError, match="open_loop"):
        validate_bench(bench)


def test_bench_schema_rejects_wrong_types():
    bench = _valid_bench()
    bench["open_loop"]["saturating"]["breaker"]["opens"] = "3"
    with pytest.raises(ValueError, match="wrong type"):
        validate_bench(bench)
    # bool is not an acceptable int/float (it would mean a counter got
    # replaced by a flag somewhere upstream).
    bench = _valid_bench()
    bench["open_loop"]["moderate"]["completed"] = True
    with pytest.raises(ValueError, match="wrong type"):
        validate_bench(bench)


def test_bench_schema_rejects_nonfinite_and_negative():
    bench = _valid_bench()
    bench["open_loop"]["moderate"]["client_p50_ttft_s"] = float("nan")
    with pytest.raises(ValueError, match="non-finite"):
        validate_bench(bench)
    bench = _valid_bench()
    bench["open_loop"]["moderate"]["goodput"]["goodput_req_s"] = -1.0
    with pytest.raises(ValueError, match="negative"):
        validate_bench(bench)


def test_bench_schema_reports_all_problems_at_once():
    bench = _valid_bench()
    del bench["sclad"]
    bench["arch"] = 7
    bench["open_loop"]["moderate"]["client_p99_itl_s"] = float("inf")
    with pytest.raises(ValueError) as e:
        validate_bench(bench)
    msg = str(e.value)
    assert "sclad" in msg and "arch" in msg and "client_p99_itl_s" in msg


def test_note_tokens_speculative_window_counts_accepted_only():
    """The speculative verify pass reports the ACCEPTED token count
    (anchor + accepted drafts) per host sync — never the proposed count —
    so each ITL window spreads the sync gap over tokens the client
    actually received.  A fully-accepted k=4 pass therefore records five
    gap/5 samples, and a fully-rejected pass one full-gap sample."""
    eng = _bare_engine()
    eng._submit_t[3] = 0.0
    eng._note_tokens(3, 3, 2.0)   # first verify pass: TTFT only
    eng._note_tokens(3, 5, 3.0)   # anchor + 4 accepted: 5 x 0.2
    eng._note_tokens(3, 1, 3.5)   # all drafts rejected: 1 x 0.5
    assert eng.stats.ttft_history == [2.0]
    assert eng.stats.itl_history == [0.2] * 5 + [0.5]
    # Had the rejected pass reported PROPOSED (5), the tail would have
    # been five phantom 0.1s samples — p99 would lie low.
    assert eng.stats.p99_itl_s == 0.5


def test_bench_schema_rejects_acceptance_rate_above_one():
    bench = _valid_bench()
    bench["spec_decode"]["repetitive"]["acceptance_rate"] = 1.5
    with pytest.raises(ValueError, match="rate > 1"):
        validate_bench(bench)
    bench = _valid_bench()
    bench["spec_decode"]["random"]["acceptance_rate"] = 1.0
    validate_bench(bench)  # inclusive upper bound: exactly 1 is legal
