"""Fault injection, replica health, and bit-identical failover (PR 10).

Three layers, pinned bottom-up:

  * ``serving.faults`` — ``FaultPlan`` schedules are immutable, seeded
    plans replay identically, and ``FaultyEngine`` injects each kind at
    the engine-step boundary with the documented semantics (crash is
    forever, hang is one stalled step with a virtual cost, raise is
    transient, slow skips beats) while delegating everything else.
  * health — ``ReplicaHealth`` walks healthy -> suspect -> dead exactly
    as documented (watchdog trips suspect, only CONSECUTIVE errors kill,
    probes revive), the engine's poisoned-step contract refuses work
    after an inconsistent failure, and per-request wall-clock timeouts
    surface as ``RejectedError(kind="timeout")`` from the stream.
  * failover — a dead replica's in-flight requests are re-homed with
    their emitted prefix deduped, so the client stream completes
    BIT-IDENTICAL to a failure-free run (the headline), and router
    teardown (``aclose``) leaves zero live KV blocks fleet-wide.

Router tests drive the frontends manually (``fe._dispatch(fe._tick())``,
the pump never starts) so every schedule is deterministic; the headline
chaos test runs the real open-loop driver end to end.
"""
import asyncio
import time

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.faults import (FAULT_KINDS, FaultEvent, FaultPlan,
                                  FaultyEngine, InjectedFault,
                                  ReplicaCrashed)
from repro.serving.frontend import (AsyncFrontend, CircuitBreaker,
                                    RejectedError)
from repro.serving.openloop import TraceItem
from repro.serving.router import (HEALTH_STATES, ReplicaHealth,
                                  ReplicaRouter, run_open_loop_router)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(tiny, **over):
    cfg, params = tiny
    kw = dict(max_batch=3, max_len=32, mode="continuous", block_size=8,
              num_blocks=24, prefill_chunk=8, prefix_cache=True,
              eos_id=-1)
    kw.update(over)
    return ServingEngine(cfg, params, **kw)


def _never_trips():
    return CircuitBreaker(window=4096, trip_pressure=4096,
                          sat_threshold=2.0)


def _wire(fe):
    """Manual-stepping setup: what ``start()`` would do, minus the pump."""
    fe.engine.on_token = fe._on_token
    return fe


def _step_until(fe, pred, limit=120):
    for _ in range(limit):
        fe._dispatch(fe._tick())
        if pred():
            return
    raise AssertionError(f"condition not reached in {limit} ticks")


# ---------------------------------------------------------------------------
# FaultPlan: schedules are validated, seeded, immutable
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError, match="not in"):
        FaultEvent("explode", 0)
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent("crash", -1)
    with pytest.raises(ValueError, match=">= 1"):
        FaultEvent("hang", 0, duration=0)
    with pytest.raises(ValueError, match=">= 1"):
        FaultEvent("slow", 0, factor=0)
    assert set(FAULT_KINDS) == {"crash", "hang", "raise", "slow"}


def test_fault_plan_queries_and_composition():
    p = FaultPlan.crash_at(9) + FaultPlan.hang_at(3, 5) \
        + FaultPlan.raise_at(4) + FaultPlan.slow_from(2, 3, 4)
    assert p.crash_tick() == 9
    assert p.hang_at_tick(3).duration == 5
    assert p.hang_at_tick(2) is None
    assert p.raises_at(4) and not p.raises_at(5)
    # slow window is [tick, tick + duration)
    assert p.slow_at(2) is not None and p.slow_at(5) is not None
    assert p.slow_at(6) is None and p.slow_at(1) is None
    assert len(p) == 4
    assert "crash@9" in p.describe() and "slow@2 x4 /3" in p.describe()
    assert FaultPlan().describe() == "no faults"
    assert FaultPlan().crash_tick() is None


def test_seeded_plans_replay_identically():
    a = FaultPlan.seeded(7, crash_p=0.5)
    b = FaultPlan.seeded(7, crash_p=0.5)
    assert a.events == b.events
    # A plan with crash_p=1.0 places exactly ONE crash.
    c = FaultPlan.seeded(3, crash_p=1.0)
    assert sum(1 for e in c.events if e.kind == "crash") == 1
    # Some seed in a small pool must differ from seed 7 (schedules are
    # actually random, not constant).
    assert any(FaultPlan.seeded(s, crash_p=0.5).events != a.events
               for s in range(8))


# ---------------------------------------------------------------------------
# FaultyEngine: injection semantics at the step boundary (stub inner
# engine — the real-engine integration is the failover tests below)
# ---------------------------------------------------------------------------

class _StubEngine:
    """Counts real step() calls; everything FaultyEngine must delegate."""

    def __init__(self):
        self.steps = 0
        self.on_token = None
        self.eos_id = -1
        self.max_len = 32

    def step(self):
        self.steps += 1
        return [(self.steps, [1, 2, 3])]


def test_crash_is_forever():
    fx = FaultyEngine(_StubEngine(), FaultPlan.crash_at(2))
    assert fx.step() and fx.step()
    for _ in range(3):  # at and past the crash tick: dead stays dead
        with pytest.raises(ReplicaCrashed):
            fx.step()
    assert fx.crashed and fx.engine.steps == 2
    assert fx.injected == 1  # one crash event, not one per raise


def test_hang_is_one_stalled_step_with_virtual_cost():
    fx = FaultyEngine(_StubEngine(), FaultPlan.hang_at(1, duration=40))
    fx.step()
    assert fx.last_step_cost == 1
    assert fx.step() == []          # the hung step makes no progress
    assert fx.last_step_cost == 40  # ...and reports its stall length
    fx.step()
    assert fx.last_step_cost == 1   # recovered
    assert fx.engine.steps == 2     # the hang never reached the engine


def test_transient_raise_recovers():
    fx = FaultyEngine(_StubEngine(), FaultPlan.raise_at(0))
    with pytest.raises(InjectedFault):
        fx.step()
    assert not fx.crashed
    assert fx.step()                # next call proceeds normally
    assert fx.engine.steps == 1


def test_slow_skips_beats():
    fx = FaultyEngine(_StubEngine(), FaultPlan.slow_from(0, 2, 4))
    progress = [bool(fx.step()) for _ in range(6)]
    # window covers ticks 0..3 at factor 2: every other step is a
    # skipped beat; past the window all steps progress.
    assert progress == [True, False, True, False, True, True]
    assert fx.engine.steps == 4


def test_faulty_engine_delegates_everything_else():
    inner = _StubEngine()
    fx = FaultyEngine(inner, FaultPlan())
    assert fx.eos_id == -1 and fx.max_len == 32  # __getattr__ passthrough
    hook = lambda uid, tok: None
    fx.on_token = hook
    assert inner.on_token is hook                # setter reaches the engine
    assert fx.engine is inner
    assert fx.step() and fx.ticks == 1 and fx.injected == 0


# ---------------------------------------------------------------------------
# ReplicaHealth: the healthy -> suspect -> dead walk
# ---------------------------------------------------------------------------

def test_health_validation():
    with pytest.raises(ValueError, match=">= 1"):
        ReplicaHealth(deadline_ticks=0)
    with pytest.raises(ValueError, match=">= 1"):
        ReplicaHealth(crash_threshold=0)
    assert HEALTH_STATES == ("healthy", "suspect", "dead")


def test_watchdog_trip_marks_suspect():
    h = ReplicaHealth(deadline_ticks=16)
    assert h.record_step(cost_ticks=16) is None   # at the deadline: fine
    assert h.record_step(cost_ticks=17) == "watchdog"
    assert h.state == "suspect" and h.watchdog_trips == 1


def test_only_consecutive_errors_kill():
    h = ReplicaHealth(crash_threshold=3)
    boom = RuntimeError("x")
    assert h.record_step(error=boom) == "error"
    assert h.record_step(error=boom) == "error"
    assert h.state == "suspect"
    h.record_step()                               # clean tick resets
    assert h.consecutive_errors == 0 and h.state == "suspect"
    assert h.record_step(error=boom) == "error"
    assert h.record_step(error=boom) == "error"
    assert h.record_step(error=boom) == "died"
    assert h.state == "dead"
    assert h.record_step() is None                # dead ignores everything
    assert h.transitions == [("healthy", "suspect"), ("suspect", "dead")]


def test_suspect_takes_probes_and_revives():
    h = ReplicaHealth(probes=1)
    h.record_step(cost_ticks=99)                  # -> suspect
    assert h.can_place()
    assert h.note_placed() is True                # this one is a probe
    assert not h.can_place()                      # probe slot taken
    h.record_probe_end(None)                      # cancelled: no judgement
    assert h.state == "suspect" and h.can_place()
    h.note_placed()
    h.record_probe_end(True)                      # clean completion revives
    assert h.state == "healthy"
    assert h.note_placed() is False               # healthy placements aren't probes


def test_draining_blocks_placement_only():
    h = ReplicaHealth()
    h.draining = True
    assert not h.can_place()
    assert h.state == "healthy"                   # drain is not a health state
    h.draining = False
    assert h.can_place()


# ---------------------------------------------------------------------------
# Engine: the poisoned-step contract
# ---------------------------------------------------------------------------

def test_poisoned_engine_contract(tiny):
    eng = _engine(tiny)
    eng.submit(np.arange(1, 9), max_new_tokens=2)

    def boom():
        raise RuntimeError("device exploded")

    eng._step = boom
    # A failing step whose BlockStore still passes its invariants is
    # recoverable: the error propagates, the engine is NOT poisoned.
    with pytest.raises(RuntimeError, match="device exploded"):
        eng.step()
    assert not eng.poisoned

    def corrupt():
        raise AssertionError("refcount mismatch")

    eng._alloc.check_invariants = corrupt
    with pytest.raises(RuntimeError, match="device exploded"):
        eng.step()
    assert eng.poisoned
    # Poisoned engines refuse all further work, loudly.
    with pytest.raises(RuntimeError, match="poisoned"):
        eng.step()
    with pytest.raises(RuntimeError, match="poisoned"):
        eng.submit(np.arange(1, 9))


# ---------------------------------------------------------------------------
# Frontend: per-request wall-clock timeouts
# ---------------------------------------------------------------------------

def test_timeout_surfaces_from_stream_and_releases_blocks(tiny):
    eng = _engine(tiny)
    fe = _wire(AsyncFrontend(eng, breaker=_never_trips()))
    with pytest.raises(ValueError, match="timeout_s"):
        asyncio.run(fe.submit(np.arange(1, 9), timeout_s=0.0))
    s = asyncio.run(fe.submit(np.arange(1, 9), max_new_tokens=20,
                              timeout_s=0.005))
    slow = asyncio.run(fe.submit(np.arange(2, 10), max_new_tokens=4))
    time.sleep(0.01)  # expire the first request's wall-clock budget
    _step_until(fe, lambda: s._ticket.cancelled)
    with pytest.raises(RejectedError, match="wall-clock timeout") as ei:
        asyncio.run(s.collect())
    assert ei.value.kind == "timeout"
    assert fe.stats.timeouts == 1
    # The untimed request is unaffected and the pool drains clean.
    _step_until(fe, lambda: not fe._inflight and not fe._has_engine_work())
    assert asyncio.run(slow.collect()) == slow._ticket.result
    assert eng.live_blocks == 0
    eng.on_token = None


def test_solo_frontend_fails_inflight_on_dead_engine(tiny):
    """Without a router (no tick_observer), max_step_errors consecutive
    step failures must fail the in-flight streams rather than hang their
    consumers forever."""
    fx = FaultyEngine(_engine(tiny), FaultPlan.crash_at(0))
    fe = _wire(AsyncFrontend(fx, max_step_errors=2,
                             breaker=_never_trips()))
    s = asyncio.run(fe.submit(np.arange(1, 9), max_new_tokens=4))
    _step_until(fe, lambda: fe._engine_dead, limit=4)
    assert fe.stats.step_errors == 2
    with pytest.raises(RuntimeError, match="engine unresponsive"):
        asyncio.run(s.collect())
    assert not fe._has_engine_work()  # a dead engine is never re-stepped
    fx.on_token = None


# ---------------------------------------------------------------------------
# Router: watchdog -> suspect -> probe revival, drain, failover
# ---------------------------------------------------------------------------

def test_drain_excludes_replica_until_undrained(tiny):
    r = ReplicaRouter([_engine(tiny) for _ in range(2)],
                      policy="round_robin")
    prompt = np.arange(1, 9)
    r.drain(0)
    r.drain(0)  # idempotent
    assert r.stats.drained_replicas == 1
    assert all(order == [1] for order in
               [r._order(prompt, None) for _ in range(3)])
    r.undrain(0)
    assert r.stats.drained_replicas == 0
    assert set(r._order(prompt, None)) == {0, 1}


def test_hang_trips_watchdog_then_probe_revives(tiny):
    """A hung step marks the replica suspect; with every peer drained it
    takes exactly one probe placement, and the probe's clean completion
    revives it to healthy."""
    fx = FaultyEngine(_engine(tiny), FaultPlan.hang_at(0, duration=64))
    r = ReplicaRouter(
        [fx, _engine(tiny)], policy="round_robin",
        health_factory=lambda: ReplicaHealth(deadline_ticks=16, probes=1))
    fe0 = _wire(r.frontends[0])
    s1 = asyncio.run(r.submit(np.arange(1, 9), max_new_tokens=3))
    fe0._dispatch(fe0._tick())  # the hung step: cost 64 > deadline 16
    assert r.health[0].state == "suspect"
    assert r.stats.watchdog_trips == 1
    r.drain(1)  # force the next placement onto the suspect replica
    s2 = asyncio.run(r.submit(np.arange(2, 10), max_new_tokens=3))
    assert r.stats.per_replica == [2, 0]
    # Probe slot taken + peer draining: the fleet refuses placements.
    with pytest.raises(RejectedError, match="no replica accepts") as ei:
        asyncio.run(r.submit(np.arange(3, 11), max_new_tokens=1))
    assert ei.value.kind == "breaker"
    _step_until(fe0, lambda: not fe0._inflight
                and not fe0._has_engine_work())
    assert asyncio.run(s2.collect()) == s2._ticket.result
    assert r.health[0].state == "healthy"  # the probe revived it
    assert asyncio.run(s1.collect()) == s1._ticket.result
    r.undrain(1)
    asyncio.run(r.aclose())


def test_failover_resumes_midstream_bit_identically(tiny):
    """Kill a replica after it has streamed part of a request: the
    request is re-homed as prompt + emitted tokens, the client stream
    continues in place, and the full output equals the solo-engine run
    (never a duplicated or missing token)."""
    prompt, budget = np.arange(1, 9), 6
    ref = _engine(tiny)
    ref_uid = ref.submit(prompt, max_new_tokens=budget)
    ref_out = ref.run()[ref_uid]

    # Tick 0 prefills, then a few decode ticks emit tokens; the crash at
    # tick 3 lands mid-decode with part of the stream already delivered.
    fx = FaultyEngine(_engine(tiny), FaultPlan.crash_at(3))
    r = ReplicaRouter([fx, _engine(tiny)], policy="round_robin",
                      health_factory=lambda: ReplicaHealth(
                          crash_threshold=2))
    fe0, fe1 = (_wire(fe) for fe in r.frontends)
    s = asyncio.run(r.submit(prompt, max_new_tokens=budget))
    for _ in range(6):  # 3 real steps, then crashing ones
        fe0._dispatch(fe0._tick())
    assert r.health[0].state == "dead"
    emitted_before = list(s._ticket.emitted)
    assert 0 < len(emitted_before) < budget, \
        "crash must land mid-decode for this test to mean anything"
    assert r._dead_pending == [0]  # no loop ran: failover is ours to run
    assert asyncio.run(r.fail_over_dead()) == 1
    assert r.stats.failovers == 1 and r.stats.replica_deaths == 1
    assert fx.engine.live_blocks == 0  # dead replica's KV released
    assert s._ticket.successor is not None
    _step_until(fe1, lambda: s.done)
    assert s.uid is not None  # resolves through the live incarnation
    assert asyncio.run(s.collect()) == ref_out
    assert r.fault_report()["health"] == ["dead", "healthy"]
    asyncio.run(r.aclose())


def test_retry_budget_exhaustion_surfaces_timeout(tiny):
    """With zero retry budget a victim request is not re-homed — its
    stream ends with RejectedError(kind='timeout') instead of hanging."""
    fx = FaultyEngine(_engine(tiny), FaultPlan.crash_at(1))
    r = ReplicaRouter([fx, _engine(tiny)], policy="round_robin",
                      health_factory=lambda: ReplicaHealth(
                          crash_threshold=2),
                      retry_budget=0)
    fe0 = _wire(r.frontends[0])
    s = asyncio.run(r.submit(np.arange(1, 9), max_new_tokens=4))
    for _ in range(4):
        fe0._dispatch(fe0._tick())
    assert r.health[0].state == "dead"
    asyncio.run(r.fail_over_dead())
    assert r.stats.failovers == 0
    with pytest.raises(RejectedError, match="retry budget") as ei:
        asyncio.run(s.collect())
    assert ei.value.kind == "timeout"
    asyncio.run(r.aclose())


def test_aclose_cancels_inflight_and_releases_all_blocks(tiny):
    """Teardown with streams still open: every replica ends with zero
    live blocks (the stream-leak fix this PR pins)."""
    r = ReplicaRouter([_engine(tiny) for _ in range(2)],
                      policy="round_robin")
    for fe in r.frontends:
        _wire(fe)
    streams = [asyncio.run(r.submit(np.arange(1 + k, 9 + k),
                                    max_new_tokens=20))
               for k in range(3)]
    for fe in r.frontends:  # start work, never finish it
        fe._dispatch(fe._tick())
        fe._dispatch(fe._tick())
    assert any(fe.engine.live_blocks > 0 for fe in r.frontends)
    asyncio.run(r.aclose())
    assert all(fe.engine.live_blocks == 0 for fe in r.frontends)
    for s in streams:  # ended, not hung: a prefix, then termination
        toks = asyncio.run(s.collect())
        assert len(toks) <= 20


# ---------------------------------------------------------------------------
# Headline: chaos run through the real open-loop driver
# ---------------------------------------------------------------------------

def test_crash_one_replica_chaos_run_is_bit_identical(tiny):
    """3 replicas, a seeded crash-one-replica-mid-decode fault plan:
    every request completes via failover, availability stays 1.0, and
    each stream is bit-identical to the failure-free run."""
    cfg, _ = tiny
    rng = np.random.default_rng(7)
    trace = [TraceItem(
        arrival_s=0.01 * i,
        prompt=rng.integers(1, cfg.vocab_size, size=8).astype(np.int32),
        max_new_tokens=10) for i in range(6)]

    clean_rep, _ = run_open_loop_router(
        [_engine(tiny) for _ in range(3)], trace, policy="round_robin")
    assert all(rec.status == "completed" for rec in clean_rep.records)

    engines = [FaultyEngine(_engine(tiny), FaultPlan.crash_at(6)),
               _engine(tiny), _engine(tiny)]
    chaos_rep, router = run_open_loop_router(
        engines, trace, policy="round_robin")

    assert engines[0].crashed
    assert [rec.status for rec in chaos_rep.records] == ["completed"] * 6
    assert [rec.tokens for rec in chaos_rep.records] \
        == [rec.tokens for rec in clean_rep.records], \
        "failover must not change a single token"
    assert chaos_rep.availability == 1.0
    summary = chaos_rep.summary(slo_ttft_s=10.0)
    ft = summary["fault_tolerance"]
    assert ft["replica_deaths"] == 1
    assert ft["failovers"] >= 1
    assert ft["health"] == ["dead", "healthy", "healthy"]
    if router.failover_ttft_s:
        assert ft["failover_p99_ttft_s"] > 0.0
