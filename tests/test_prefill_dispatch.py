"""Unified prefill-attention dispatch: the engine's chunked-prefill hot
path runs the Pallas paged flash-prefill kernel (interpret mode on CPU)
and the jnp gather+scatter reference interchangeably — and the kernel path
provably materializes neither the dense per-lane context copy NOR the
dense (Bn, S, S) causal/pad mask (jaxpr regression, with the reference
path as positive control).  Also pins the attn_kernel deprecation shim
(``decode_kernel=`` keyword, ``--decode-kernel`` flag, ``cfg.decode_kernel``
property) and the TTFT / prefill-throughput EngineStats satellites.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.serve import resolve_attn_kernel_arg
from repro.models import model as M
from repro.serving.engine import ServingEngine

MAX_LEN = 32


def _make(arch, **over):
    cfg = get_config(arch).reduced()
    if over:
        cfg = dataclasses.replace(cfg, **over)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def tiny():
    return _make("tinyllama-1.1b")


# ---------------------------------------------------------------------------
# jaxpr regression: the chunked-prefill continuation step must not gather a
# dense per-lane context copy, nor build a dense (Bn, S, S) mask
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            yield from _iter_param_eqns(v)


def _iter_param_eqns(v):
    if hasattr(v, "jaxpr"):  # ClosedJaxpr
        yield from _iter_eqns(v.jaxpr)
    elif hasattr(v, "eqns"):  # Jaxpr
        yield from _iter_eqns(v)
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _iter_param_eqns(x)


def _max_gather_elems(jaxpr):
    best = 0
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name == "gather":
            for out in eqn.outvars:
                best = max(best, int(np.prod(out.aval.shape)))
    return best


def _max_bool_elems(jaxpr, lead):
    """Largest per-lane boolean array (ndim >= 3 with leading dim ``lead``
    — the dense attention mask's signature; MoE expert-routing one-hots
    carry other leading dims and must not trip the check)."""
    best = 0
    for eqn in _iter_eqns(jaxpr):
        for out in eqn.outvars:
            shape = getattr(out.aval, "shape", ())
            if getattr(out.aval, "dtype", None) == jnp.bool_ and \
                    len(shape) >= 3 and shape[0] == lead:
                best = max(best, int(np.prod(shape)))
    return best


def _prefill_cont_jaxpr(cfg, params, Bn, P, bs, T, N):
    """Continuation-chunk prefill_slots (start given) as a jaxpr."""
    cache = jax.eval_shape(lambda: M.init_paged_cache(cfg, N + 1, bs))
    return jax.make_jaxpr(
        lambda p, c, t, ln, bt, st: M.prefill_slots(cfg, p, c, t, ln, bt,
                                                    start=st)
    )(params, cache,
      jax.ShapeDtypeStruct((Bn, P), jnp.int32),
      jax.ShapeDtypeStruct((Bn,), jnp.int32),
      jax.ShapeDtypeStruct((Bn, T), jnp.int32),
      jax.ShapeDtypeStruct((Bn,), jnp.int32)).jaxpr


# The moe case walks a WIDER table so the context-copy tripwire sits above
# the (family-inherent, KV-independent) MoE expert-dispatch gathers —
# those scale with Bn*P*d_model, not with the cached-context size.
@pytest.mark.parametrize("arch,T", [("tinyllama-1.1b", 8),
                                    ("qwen2-moe-a2.7b", 64),
                                    ("internvl2-26b", 8)])
def test_prefill_slots_kernel_path_no_dense_gather_or_mask(arch, T):
    """On the kernel path no gather in the whole prefill step reaches the
    (Bn, T*bs, Hk, D) dense per-lane context copy and no bool reaches the
    (Bn, S, S) dense mask; on the reference path both do (positive
    control — the regressions this test pins)."""
    Bn, P, bs, N = 4, 8, 4, 16
    cfg, params = _make(arch)
    S = P  # continuation chunks never carry the vlm patch prefix
    dense_copy = Bn * T * bs * cfg.num_kv_heads * cfg.head_dim
    dense_mask = Bn * S * S
    # Embedding lookups must sit below the gather tripwire for the bound
    # to bite.
    assert Bn * P * cfg.d_model < dense_copy

    on = _prefill_cont_jaxpr(
        dataclasses.replace(cfg, attn_kernel="on"), params, Bn, P, bs, T, N)
    assert _max_gather_elems(on) < dense_copy, (
        "kernel-path prefill_slots still materializes a dense per-lane "
        "context copy")
    assert _max_bool_elems(on, Bn) < dense_mask, (
        "kernel-path prefill_slots still materializes a dense (Bn, S, S) "
        "mask")
    off = _prefill_cont_jaxpr(
        dataclasses.replace(cfg, attn_kernel="off"), params, Bn, P, bs, T, N)
    assert _max_gather_elems(off) >= dense_copy, (
        "positive control lost: the reference path should gather")
    assert _max_bool_elems(off, Bn) >= dense_mask, (
        "positive control lost: the reference path should build the dense "
        "mask")


def test_prefill_slots_kernel_path_first_chunk_no_dense_mask(tiny):
    """First chunks (start=None) take the kernel too: no dense causal/pad
    mask is built there either."""
    Bn, P, bs, T, N = 4, 8, 4, MAX_LEN // 4, 16
    cfg, params = tiny
    cache = jax.eval_shape(lambda: M.init_paged_cache(
        dataclasses.replace(cfg, attn_kernel="on"), N + 1, bs))
    jaxpr = jax.make_jaxpr(
        lambda p, c, t, ln, bt: M.prefill_slots(
            dataclasses.replace(cfg, attn_kernel="on"), p, c, t, ln, bt)
    )(params, cache,
      jax.ShapeDtypeStruct((Bn, P), jnp.int32),
      jax.ShapeDtypeStruct((Bn,), jnp.int32),
      jax.ShapeDtypeStruct((Bn, T), jnp.int32)).jaxpr
    assert _max_bool_elems(jaxpr, Bn) < Bn * P * P


# ---------------------------------------------------------------------------
# engine matrix: serving machinery is bit-transparent UNDER the prefill
# kernel (kernel-vs-reference agreement itself is the tolerance property
# owned by test_kernels.py — see test_decode_dispatch.py for the rationale)
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, reqs, **kwargs):
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, eos_id=-1, **kwargs)
    uids = [eng.submit(p, max_new_tokens=m) for p, m in reqs]
    out = eng.run()
    return eng, [out[u] for u in uids]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "internvl2-26b"])
def test_engine_prefill_kernel_chunking_invariance(arch):
    """attn_kernel="on": greedy outputs are bit-identical across prefill
    chunk sizes (every chunk boundary shifts which continuation calls the
    kernel sees) and prefix cache on/off, on shared-prefix traffic."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(41)
    shared = rng.integers(1, cfg.vocab_size, size=9)
    reqs = [(np.concatenate([shared,
                             rng.integers(1, cfg.vocab_size, size=n)]), m)
            for n, m in ((3, 4), (6, 3), (2, 4))]
    kw = dict(max_batch=2, block_size=4, attn_kernel="on")
    eng, base = _run_engine(cfg, params, reqs, prefill_chunk=4,
                            prefix_cache=True, **kw)
    assert eng.stats.cached_prompt_tokens > 0
    assert eng.stats.prefill_chunks > len(reqs)  # chunking really happened
    _, chunk8 = _run_engine(cfg, params, reqs, prefill_chunk=8,
                            prefix_cache=True, **kw)
    _, whole = _run_engine(cfg, params, reqs, prefill_chunk=None,
                           prefix_cache=True, **kw)
    _, no_prefix = _run_engine(cfg, params, reqs, prefill_chunk=4,
                               prefix_cache=False, **kw)
    assert chunk8 == base
    assert whole == base
    assert no_prefix == base


def test_engine_prefill_kernel_preemption_bit_identical(tiny):
    """Preemption recompute re-enters prefill as a continuation (usually a
    prefix hit): under the kernel the over-committed pool reproduces the
    ample pool's outputs exactly."""
    cfg, params = tiny
    rng = np.random.default_rng(43)
    reqs = [(rng.integers(1, cfg.vocab_size, size=7), 10) for _ in range(3)]
    kw = dict(max_batch=3, block_size=4, prefill_chunk=4, attn_kernel="on")
    _, ref = _run_engine(cfg, params, reqs, num_blocks=24, **kw)
    eng, out = _run_engine(cfg, params, reqs, num_blocks=9, **kw)
    assert eng.stats.preemptions >= 1
    assert out == ref


def test_engine_prefill_kernel_decode_steps_invariance(tiny):
    """Multi-step decode windows compose with kernel-path prefill."""
    cfg, params = tiny
    rng = np.random.default_rng(47)
    reqs = [(rng.integers(1, cfg.vocab_size, size=9), 6) for _ in range(3)]
    kw = dict(max_batch=2, block_size=4, prefill_chunk=4, attn_kernel="on")
    _, one = _run_engine(cfg, params, reqs, decode_steps=1, **kw)
    _, multi = _run_engine(cfg, params, reqs, decode_steps=3, **kw)
    assert multi == one


# ---------------------------------------------------------------------------
# deprecation shim: decode_kernel spellings map onto attn_kernel
# ---------------------------------------------------------------------------

def test_engine_decode_kernel_kwarg_deprecated(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(53)
    reqs = [(rng.integers(1, cfg.vocab_size, size=5), 4)]
    with pytest.warns(DeprecationWarning, match="attn_kernel"):
        eng, out_dep = _run_engine(cfg, params, reqs, max_batch=1,
                                   block_size=4, decode_kernel="on")
    assert eng.cfg.attn_kernel == "on"
    _, out_new = _run_engine(cfg, params, reqs, max_batch=1, block_size=4,
                             attn_kernel="on")
    assert out_dep == out_new  # the alias selects the same implementation
    with pytest.raises(ValueError, match="conflicting"), \
            pytest.warns(DeprecationWarning):
        ServingEngine(cfg, params, attn_kernel="on", decode_kernel="off")


def test_serve_flag_decode_kernel_deprecated():
    with pytest.warns(DeprecationWarning, match="attn-kernel"):
        assert resolve_attn_kernel_arg(None, "off") == "off"
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no warning on the new spelling
        assert resolve_attn_kernel_arg("on", None) == "on"
        assert resolve_attn_kernel_arg(None, None) == "auto"
    with pytest.warns(DeprecationWarning):
        with pytest.raises(SystemExit):
            resolve_attn_kernel_arg("on", "off")


def test_config_decode_kernel_property_alias():
    cfg = get_config("tinyllama-1.1b").reduced()
    cfg = dataclasses.replace(cfg, attn_kernel="off")
    assert cfg.decode_kernel == "off"  # read-only back-compat alias


# ---------------------------------------------------------------------------
# EngineStats satellites: TTFT + prefill throughput
# ---------------------------------------------------------------------------

def test_engine_stats_ttft_and_prefill_throughput(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(59)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN, eos_id=-1,
                        block_size=4, prefill_chunk=4)
    for _ in range(3):
        eng.submit(rng.integers(1, cfg.vocab_size, size=7),
                   max_new_tokens=4)
    zero = eng.submit(rng.integers(1, cfg.vocab_size, size=4),
                      max_new_tokens=0)  # no tokens -> no TTFT sample
    out = eng.run()
    assert out[zero] == []
    s = eng.stats
    assert s.ttft_count == 3
    assert s.ttft_s_sum > 0 and s.mean_ttft_s > 0
    assert s.prefill_tokens_per_s > 0
    # Every request's first token arrives after its prefill completed, so
    # the mean TTFT can never undercut a single chunk's wall time share.
    assert s.mean_ttft_s < s.prefill_s + s.decode_s + 1.0


@pytest.mark.slow
def test_engine_prefill_kernel_chunk_sweep(tiny):
    """Heavyweight chunk sweep under the kernel (nightly tier): every
    prefill_chunk in 2..MAX_LEN reproduces the whole-prompt run."""
    cfg, params = tiny
    rng = np.random.default_rng(61)
    reqs = [(rng.integers(1, cfg.vocab_size, size=int(n)), int(m))
            for n, m in zip(rng.integers(5, 20, size=4),
                            rng.integers(3, 8, size=4))]
    kw = dict(max_batch=2, block_size=4, attn_kernel="on")
    _, whole = _run_engine(cfg, params, reqs, prefill_chunk=None, **kw)
    for chunk in (2, 3, 4, 6, 8, 16):
        _, out = _run_engine(cfg, params, reqs, prefill_chunk=chunk, **kw)
        assert out == whole, f"prefill_chunk={chunk} changed greedy outputs"
