"""CC-MEM property sweeps (needs hypothesis; deterministic pins stay in
test_ccmem.py so they run everywhere)."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ccmem import AccessStream, CCMEMConfig, simulate


@settings(max_examples=30, deadline=None)
@given(st.lists(st.builds(
    AccessStream,
    words=st.integers(1, 5000),
    kind=st.sampled_from(["burst", "strided", "random"]),
    burst_len=st.integers(1, 2048),
    sparsity=st.sampled_from([0.0, 0.2, 0.6, 0.9])),
    min_size=1, max_size=6),
    st.integers(0, 10_000))
def test_served_words_never_exceed_total(streams, seed):
    """Property form of the served_words regression: for ANY stream mix,
    words served is positive and bounded by the words that exist."""
    r = simulate(streams, CCMEMConfig(num_bank_groups=4), seed=seed)
    total = sum(s.words for s in streams)
    assert 0 < r["served_words"] <= total


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(0, 10_000))
def test_cycles_monotone_in_streams(n_streams, seed):
    cfg = CCMEMConfig(num_bank_groups=8)
    streams = [AccessStream(words=1 << 12, kind="burst")
               for _ in range(n_streams)]
    r = simulate(streams, cfg, seed=seed)
    assert r["cycles"] >= r["peak_cycles"] * 0.99
    assert 0.0 < r["achieved_fraction"] <= 1.0
