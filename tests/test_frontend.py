"""AsyncFrontend: streaming, cancellation, backpressure, circuit breaker.

Acceptance (ISSUE 7): streamed tokens are bit-identical to the same trace
through the in-process ``engine.run()`` path across families and prefix-
cache settings; closing a stream mid-flight cancels the request and
releases its KV blocks (no ``BlockStore`` leak); ``submit`` rejects at
EXACTLY ``max_queue_depth``; and under scripted overload the breaker walks
the full closed -> open -> half_open -> closed cycle, shedding while open
and recovering through a probe.

The breaker itself counts scheduler ticks, not wall time, so its walk is
unit-tested with hand-scripted ticks; the overload integration test then
drives the real pump against a deliberately tiny block pool.
"""
import asyncio
import time

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import EngineStats, ServingEngine
from repro.serving.frontend import (AsyncFrontend, CircuitBreaker,
                                    RejectedError)

MAX_LEN = 32


def _make(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def tiny():
    return _make("tinyllama-1.1b")


def _engine(cfg, params, **kw):
    base = dict(max_batch=3, max_len=MAX_LEN, eos_id=-1, block_size=4,
                prefill_chunk=8)
    base.update(kw)
    return ServingEngine(cfg, params, **base)


async def _wait_for(pred, timeout_s, what):
    t0 = time.perf_counter()
    while not pred():
        assert time.perf_counter() - t0 < timeout_s, f"timed out: {what}"
        await asyncio.sleep(0.002)


# ---------------------------------------------------------------------------
# streaming bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,prefix_cache", [
    ("tinyllama-1.1b", True),
    ("tinyllama-1.1b", False),
    ("qwen2-moe-a2.7b", True),
    ("internvl2-26b", True),
])
def test_stream_bit_identical_to_run(arch, prefix_cache):
    """The frontend adds admission control, never arithmetic: the streamed
    tokens for each request equal the closed-loop ``run()`` output for the
    same trace on the same engine (which also serves as the jit warmup, so
    the async path is measured on compiled code)."""
    cfg, params = _make(arch)
    rng = np.random.default_rng(5)
    shared = rng.integers(1, cfg.vocab_size, size=8)
    tails = [rng.integers(1, cfg.vocab_size, size=n) for n in (3, 7, 5)]
    prompts = [np.concatenate([shared, t]) for t in tails]
    budgets = (4, 6, 3)
    eng = _engine(cfg, params, prefix_cache=prefix_cache)

    ref_uids = [eng.submit(p, max_new_tokens=m)
                for p, m in zip(prompts, budgets)]
    expected = eng.run()

    async def main():
        async with AsyncFrontend(eng, max_queue_depth=8) as fe:
            streams = [await fe.submit(p, max_new_tokens=m)
                       for p, m in zip(prompts, budgets)]
            outs = [await s.collect() for s in streams]
            return fe.stats, streams, outs

    stats, streams, outs = asyncio.run(main())
    for s, ref_uid in zip(streams, ref_uids):
        assert s.done
        assert s.tokens == expected[ref_uid]
    assert outs == [expected[u] for u in ref_uids]
    # uids were assigned by the pump and are unique.
    uids = [s.uid for s in streams]
    assert None not in uids and len(set(uids)) == 3
    assert stats.accepted == 3 and stats.completed == 3
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0


# ---------------------------------------------------------------------------
# cancellation releases blocks
# ---------------------------------------------------------------------------

def test_cancel_mid_stream_releases_blocks(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, max_batch=2, num_blocks=24)
    pa, pb = np.arange(1, 10), np.arange(2, 8)
    ra = eng.submit(pa, max_new_tokens=16)
    rb = eng.submit(pb, max_new_tokens=6)
    expected = eng.run()  # reference + warmup

    async def main():
        async with AsyncFrontend(eng, max_queue_depth=4) as fe:
            a = await fe.submit(pa, max_new_tokens=16)
            b = await fe.submit(pb, max_new_tokens=6)
            got = []
            async for tok in a:
                got.append(tok)
                if len(got) == 3:
                    break
            await a.aclose()
            out_b = await b.collect()
            return fe.stats, got, out_b

    stats, got, out_b = asyncio.run(main())
    # The cancelled stream saw a prefix of the greedy output; the survivor
    # is untouched by its neighbour's cancellation.
    assert got == expected[ra][:3]
    assert out_b == expected[rb]
    assert stats.cancelled == 1 and stats.completed == 1
    assert eng.stats.cancellations == 1
    # No BlockStore leak: every block the cancelled request held is back.
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0


def test_stop_without_drain_cancels_inflight(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params)
    eng.submit(np.arange(1, 9), max_new_tokens=2)
    eng.run()  # warmup

    async def main():
        fe = AsyncFrontend(eng, max_queue_depth=4)
        await fe.start()
        a = await fe.submit(np.arange(1, 9), max_new_tokens=20)
        b = await fe.submit(np.arange(3, 9), max_new_tokens=20)
        await a.__anext__()  # ensure the pump is actually decoding
        await fe.stop(drain=False)
        # Both streams terminate (no hung consumer), neither completed.
        await a.collect()
        await b.collect()
        return fe.stats, a, b

    stats, a, b = asyncio.run(main())
    assert stats.cancelled == 2 and stats.completed == 0
    assert not a.done and not b.done
    assert len(a.tokens) < 20 and len(b.tokens) < 20
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_backpressure_rejects_at_exact_depth(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params)
    eng.submit(np.arange(1, 8), max_new_tokens=2)
    eng.run()  # warmup
    prompt = np.arange(1, 8)

    async def main():
        fe = AsyncFrontend(eng, max_queue_depth=3)
        # Submit before start(): depth fills deterministically, no race
        # against the pump draining it.
        streams = [await fe.submit(prompt, max_new_tokens=2)
                   for _ in range(3)]
        assert fe.queue_depth == 3
        with pytest.raises(RejectedError) as ei:
            await fe.submit(prompt, max_new_tokens=2)
        assert ei.value.kind == "backpressure"
        assert fe.stats.rejected_backpressure == 1
        assert fe.queue_depth == 3  # the reject consumed no depth
        await fe.start()
        outs = [await s.collect() for s in streams]
        assert fe.queue_depth == 0
        # Depth freed: the same submit is now admitted.
        late = await fe.submit(prompt, max_new_tokens=2)
        out_late = await late.collect()
        await fe.stop()
        return outs, out_late

    outs, out_late = asyncio.run(main())
    assert outs[0] == outs[1] == outs[2] == out_late  # same greedy trace
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0


# ---------------------------------------------------------------------------
# deadline / priority mapping
# ---------------------------------------------------------------------------

def test_effective_deadline_mapping():
    f = AsyncFrontend._effective_deadline
    assert f(None, 0) is None
    assert f(None, -3) is None          # non-positive priority: best effort
    assert f(None, 2) == -2.0           # priority -> synthetic deadline
    assert f(3.5, 5) == 3.5             # explicit deadline wins
    assert f(0.0, 2) == 0.0             # deadline 0.0 is explicit, not falsy


def test_submit_forwards_deadline_to_engine(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, preempt_policy="deadline")
    eng.submit(np.arange(1, 8), max_new_tokens=2)
    eng.run()  # warmup

    seen = []
    orig = eng.submit

    def spy(prompt, **kw):
        seen.append(kw.get("deadline"))
        return orig(prompt, **kw)

    eng.submit = spy

    async def main():
        async with AsyncFrontend(eng, max_queue_depth=8) as fe:
            s1 = await fe.submit(np.arange(1, 8), max_new_tokens=2,
                                 priority=2)
            s2 = await fe.submit(np.arange(1, 8), max_new_tokens=2,
                                 deadline=1.5, priority=9)
            s3 = await fe.submit(np.arange(1, 8), max_new_tokens=2)
            for s in (s1, s2, s3):
                await s.collect()

    asyncio.run(main())
    eng.submit = orig
    assert seen == [-2.0, 1.5, None]


# ---------------------------------------------------------------------------
# circuit breaker: scripted unit walk
# ---------------------------------------------------------------------------

def test_breaker_walks_closed_open_half_open_closed():
    """The full cycle on hand-scripted ticks — no engine, no clock."""
    br = CircuitBreaker(window=4, trip_pressure=2, sat_threshold=1.0,
                        cooldown_ticks=3, probes=2)
    assert br.state == "closed"
    assert br.allow() == (True, False)
    br.record_tick(0, 0.0)
    assert br.state == "closed"
    br.record_tick(1, 0.0)           # pressure: preemptions
    br.record_tick(0, 1.0)           # pressure: saturation
    assert br.state == "open"
    assert br.opens == 1
    # Open sheds everything.
    assert br.allow() == (False, False)
    assert br.shed == 1
    # Cooldown runs on ticks (idle ticks count too).
    br.record_tick(0, 0.0)
    br.record_tick(0, 0.0)
    assert br.state == "open"
    br.record_tick(0, 0.0)
    assert br.state == "half_open"
    # Half-open admits exactly ``probes`` probes, sheds the rest.
    assert br.allow() == (True, True)
    assert br.allow() == (True, True)
    assert br.allow() == (False, False)
    assert br.shed == 2
    # First clean probe keeps probing; the second closes.
    br.record_probe_end(ok=True)
    assert br.state == "half_open"
    br.record_probe_end(ok=True)
    assert br.state == "closed"
    assert br.transitions == [("closed", "open"), ("open", "half_open"),
                              ("half_open", "closed")]
    # Closing cleared the pressure window: one more pressure tick does
    # not immediately re-trip.
    br.record_tick(1, 0.0)
    assert br.state == "closed"


def test_breaker_reopens_on_pressure_or_failed_probe():
    br = CircuitBreaker(window=4, trip_pressure=1, cooldown_ticks=1,
                        probes=1)
    br.record_tick(1, 0.0)
    assert br.state == "open"
    br.record_tick(0, 0.0)
    assert br.state == "half_open"
    # Pressure while probing reopens.
    br.record_tick(2, 0.0)
    assert br.state == "open"
    br.record_tick(0, 0.0)
    assert br.state == "half_open"
    admit, probe = br.allow()
    assert admit and probe
    # A failed probe reopens too.
    br.record_probe_end(ok=False)
    assert br.state == "open"
    assert br.opens == 3
    # An abandoned (cancelled) probe frees its slot without judging.
    br.record_tick(0, 0.0)
    assert br.state == "half_open"
    assert br.allow() == (True, True)
    assert br.allow() == (False, False)
    br.abandon_probe()
    assert br.allow() == (True, True)
    assert br.state == "half_open"


def test_breaker_validates_knobs():
    with pytest.raises(ValueError, match="knobs"):
        CircuitBreaker(window=0)
    with pytest.raises(ValueError, match="knobs"):
        CircuitBreaker(probes=0)
    with pytest.raises(ValueError, match="never fire"):
        CircuitBreaker(window=4, trip_pressure=5)


# ---------------------------------------------------------------------------
# circuit breaker: real pump under scripted overload
# ---------------------------------------------------------------------------

def test_breaker_sheds_and_recovers_under_overload(tiny):
    """Six 4-block requests against a 6-block pool: sustained preemption
    churn trips the breaker open (sheds arrivals), the drain runs the
    cooldown down to half_open, and a completing probe closes it."""
    cfg, params = tiny
    eng = _engine(cfg, params, max_batch=3, num_blocks=6,
                  prefill_chunk=None, prefix_cache=False)
    prompt = np.arange(1, 9)
    # Warm every admission group size the overload can hit, plus the
    # reference outputs for both budgets used below.
    refs = {}
    for budget in (8, 2):
        uid = eng.submit(prompt, max_new_tokens=budget)
        refs[budget] = eng.run()[uid]
    for g in (2, 3):
        uids = [eng.submit(prompt, max_new_tokens=2) for _ in range(g)]
        eng.run()
    eng.stats = EngineStats()
    br = CircuitBreaker(window=4, trip_pressure=2, sat_threshold=0.95,
                        cooldown_ticks=5, probes=1)

    async def main():
        fe = AsyncFrontend(eng, max_queue_depth=64, breaker=br,
                           idle_sleep_s=0.0005)
        await fe.start()
        long_streams = [await fe.submit(prompt, max_new_tokens=8)
                        for _ in range(6)]
        await _wait_for(lambda: br.state != "closed", 60.0,
                        "breaker never tripped under overload")
        # Arrivals behind the open breaker are shed (at most ``probes``
        # may slip through a half-open flap as probe admissions).
        shed = 0
        extra = []
        for _ in range(400):
            await asyncio.sleep(0.002)
            try:
                extra.append(await fe.submit(prompt, max_new_tokens=2))
            except RejectedError as e:
                if e.kind == "breaker":
                    shed += 1
                    break
                # else: transient backpressure; keep probing
        assert shed >= 1, "open breaker never shed an arrival"
        long_outs = [await s.collect() for s in long_streams]
        extra_outs = [await s.collect() for s in extra]
        # Recovery: either an admitted probe already closed the breaker
        # during the drain, or the idle ticks run the cooldown down to
        # half_open and our explicit probe closes it.
        if br.state != "closed":
            await _wait_for(lambda: br.state == "half_open", 60.0,
                            "breaker never half-opened after the drain")
            probe = await fe.submit(prompt, max_new_tokens=2)
            extra_outs.append(await probe.collect())
            assert br.state == "closed", \
                "clean probe must close the breaker"
        await fe.stop()
        return fe.stats, long_outs, extra_outs

    stats, long_outs, extra_outs = asyncio.run(main())
    # Preemption churn never corrupted a stream: every admitted request
    # is greedy-bit-identical to its solo reference.
    assert all(out == refs[8] for out in long_outs)
    assert all(out == refs[2] for out in extra_outs)
    # The walk happened, in order, and ended recovered.
    tr = br.transitions
    assert tr[0] == ("closed", "open")
    assert ("open", "half_open") in tr
    assert ("half_open", "closed") in tr
    assert tr[-1][1] == "closed" and br.state == "closed"
    assert br.opens >= 1 and br.shed >= 1
    assert stats.shed_breaker >= 1
    assert eng.stats.preemptions >= 1  # the overload was real
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0


# ---------------------------------------------------------------------------
# construction / validation edges
# ---------------------------------------------------------------------------

def test_frontend_rejects_wave_engines(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                        eos_id=-1, mode="wave")
    with pytest.raises(ValueError, match="continuous"):
        AsyncFrontend(eng)


def test_engine_validation_error_surfaces_on_stream(tiny):
    """A prompt the engine rejects (too long for the cache) surfaces as
    the original ValueError out of the stream, not a hang or a crash of
    the pump; other in-flight requests are unaffected."""
    cfg, params = tiny
    eng = _engine(cfg, params)
    eng.submit(np.arange(1, 8), max_new_tokens=2)
    eng.run()  # warmup

    async def main():
        async with AsyncFrontend(eng, max_queue_depth=4) as fe:
            bad = await fe.submit(np.arange(MAX_LEN + 4), max_new_tokens=2)
            good = await fe.submit(np.arange(1, 8), max_new_tokens=2)
            with pytest.raises(ValueError, match="decode room"):
                await bad.__anext__()
            out = await good.collect()
            return fe.stats, out

    stats, out = asyncio.run(main())
    assert stats.errors == 1 and stats.completed == 1
    assert len(out) == 2
    eng._alloc.check_invariants()
    assert eng._alloc.live_blocks == 0
