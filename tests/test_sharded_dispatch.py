"""shard_map'd paged attention over the ``model`` mesh axis (PR 9 rung 1).

Parity contract: sharding the paged KV pool's kv-head axis (payload AND
SCLAD scale leaves) changes NO arithmetic — attention is independent per
KV head, so each shard reads its contiguous Hk/m pool slice with its
matching query head group and outputs concat back on the head axis.
Pinned here at three levels:

  * kernel level — ``decode_attention`` / ``prefill_attention`` with a
    (1, m) mesh vs meshless, fp and int8-SCLAD pools, kernel on and off:
    bitwise-equal outputs in float32, the shared ``tol(dtype)`` envelope
    for bf16, and bitwise-equal pool/scale write-back for prefill;
  * engine level — the full serving matrix (dense/moe/vlm x prefix
    on/off x chunked prefill x int8 SCLAD) under 2- and 4-way meshes:
    float32 params (bf16 TP psum reduction order flips greedy near-ties,
    see the probe docstrings), greedy tokens EXACT and scheduler
    invariants (preemptions, admissions, cached tokens) bitwise equal;
  * lowering level — the compiled sharded decode step never all-gathers
    the pool (``roofline.parse_collectives`` HLO regression), and
    ``cache_specs(paged=True)`` co-shards payload and scale leaves on
    the same head axis for EVERY kv_dtype (pure-spec, no devices), with
    ``copy_cache_block`` COW preserving placement.

Multi-device cases force host devices; under a stock single-device
session they SKIP (CI runs this file with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import get_config
from repro.core import roofline
from repro.kernels.flash_decode import ops as decode_ops
from repro.kernels.flash_prefill import ops as prefill_ops
from repro.models import kv_quant
from repro.models import model as M
from repro.parallel import sharding
from repro.serving.engine import ServingEngine

needs2 = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4)")
needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4)")


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


def _mesh(m):
    devs = np.array(jax.devices()[:m]).reshape(1, m)
    return Mesh(devs, ("data", "model"))


def _paged_inputs(rng_seed, B, H, Hk, D, N, bs, dtype=jnp.float32,
                  quantized=False):
    ks = jax.random.split(jax.random.PRNGKey(rng_seed), 5)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    if quantized:
        kc = jax.random.randint(ks[1], (N, bs, Hk, D), -127, 128, jnp.int8)
        vc = jax.random.randint(ks[2], (N, bs, Hk, D), -127, 128, jnp.int8)
        scales = (jax.random.uniform(ks[3], (N, bs, Hk), jnp.float32,
                                     0.01, 0.1),
                  jax.random.uniform(ks[4], (N, bs, Hk), jnp.float32,
                                     0.01, 0.1))
    else:
        kc = jax.random.normal(ks[1], (N, bs, Hk, D)).astype(dtype)
        vc = jax.random.normal(ks[2], (N, bs, Hk, D)).astype(dtype)
        scales = None
    # Non-overlapping per-row tables walking the whole pool.
    T = N // B
    tables = jnp.arange(B * T, dtype=jnp.int32).reshape(B, T)
    lengths = jnp.arange(1, B + 1, dtype=jnp.int32) * bs - 1
    return q, kc, vc, lengths, tables, scales


# ---------------------------------------------------------------------------
# Placement/dispatch gate
# ---------------------------------------------------------------------------

@needs2
def test_attn_shard_size_matches_sanitize_gate():
    """The dispatch gate and the placement sanitizer must agree: shard
    exactly when the mesh has a model axis >1 that divides Hk."""
    mesh = _mesh(2)
    assert sharding.attn_shard_size(None, 4) == 1
    assert sharding.attn_shard_size(mesh, 4) == 2
    assert sharding.attn_shard_size(mesh, 3) == 1  # 3 % 2 != 0 -> solo
    with sharding.use_axes(mesh):
        spec = sharding.sanitize_specs(
            P(None, None, None, "model", None),
            jax.ShapeDtypeStruct((2, 8, 8, 3, 16), jnp.float32))
    assert spec[3] is None  # sanitizer drops it for the same Hk


def test_paged_attn_specs_shapes():
    sp = sharding.paged_attn_specs()
    # Kernel-level pools are the 4-D (N, bs, Hk, D) slices (one layer);
    # the 5-D (L, ...) placement rule lives in cache_specs(paged=True).
    assert sp["pool"] == P(None, None, "model", None)
    assert sp["scale"] == P(None, None, "model")
    assert sp["q_decode"] == P(None, "model", None)
    assert sp["host"] == P()


# ---------------------------------------------------------------------------
# Kernel-level parity: decode
# ---------------------------------------------------------------------------

@needs2
@pytest.mark.parametrize("kernel", ["off", "on"])
@pytest.mark.parametrize("quantized", [False, True])
def test_sharded_decode_matches_single(kernel, quantized):
    q, kc, vc, lengths, tables, scales = _paged_inputs(
        0, B=2, H=8, Hk=4, D=16, N=8, bs=8, quantized=quantized)
    solo = decode_ops.decode_attention(
        q, kc, vc, lengths, block_tables=tables, kernel=kernel,
        kv_scales=scales, mesh=None)
    shard = decode_ops.decode_attention(
        q, kc, vc, lengths, block_tables=tables, kernel=kernel,
        kv_scales=scales, mesh=_mesh(2))
    # float32 per-head math is untouched by the split: bitwise equal.
    np.testing.assert_array_equal(np.asarray(solo), np.asarray(shard))


@needs2
def test_sharded_decode_bf16_within_kernel_tolerance():
    q, kc, vc, lengths, tables, _ = _paged_inputs(
        1, B=2, H=4, Hk=2, D=16, N=8, bs=8, dtype=jnp.bfloat16)
    solo = decode_ops.decode_attention(q, kc, vc, lengths,
                                       block_tables=tables, mesh=None)
    shard = decode_ops.decode_attention(q, kc, vc, lengths,
                                        block_tables=tables, mesh=_mesh(2))
    np.testing.assert_allclose(
        np.asarray(solo, np.float32), np.asarray(shard, np.float32),
        atol=tol(jnp.bfloat16), rtol=tol(jnp.bfloat16))


@needs2
def test_indivisible_heads_fall_back_to_single_path():
    """Hk=3 on a 2-way mesh: the gate must route to the plain path (and
    produce the same numbers), never crash inside shard_map."""
    q, kc, vc, lengths, tables, _ = _paged_inputs(
        2, B=1, H=3, Hk=3, D=8, N=4, bs=8)
    solo = decode_ops.decode_attention(q, kc, vc, lengths,
                                       block_tables=tables, mesh=None)
    shard = decode_ops.decode_attention(q, kc, vc, lengths,
                                        block_tables=tables, mesh=_mesh(2))
    np.testing.assert_array_equal(np.asarray(solo), np.asarray(shard))


# ---------------------------------------------------------------------------
# Kernel-level parity: chunked prefill (pools are inputs AND outputs)
# ---------------------------------------------------------------------------

@needs2
@pytest.mark.parametrize("kernel", ["off", "on"])
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("continuation", [False, True])
def test_sharded_prefill_matches_single(kernel, quantized, continuation):
    B, S, H, Hk, D, N, bs = 2, 8, 4, 2, 16, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k_new = jax.random.normal(ks[1], (B, S, Hk, D))
    v_new = jax.random.normal(ks[2], (B, S, Hk, D))
    _, kp, vp, _, tables, scales = _paged_inputs(
        4, B=B, H=H, Hk=Hk, D=D, N=N, bs=bs, quantized=quantized)
    lengths = jnp.array([S, S - 3], jnp.int32)
    start = jnp.array([bs, bs], jnp.int32) if continuation else None
    kv_dtype = "int8" if quantized else None
    kw = dict(start=start, kernel=kernel, kv_scales=scales,
              kv_dtype=kv_dtype)
    solo = prefill_ops.prefill_attention(
        q, k_new, v_new, kp, vp, lengths, tables, mesh=None, **kw)
    shard = prefill_ops.prefill_attention(
        q, k_new, v_new, kp, vp, lengths, tables, mesh=_mesh(2), **kw)
    assert len(solo) == len(shard) == (5 if quantized else 3)
    # Output AND every written-back pool/scale leaf: bitwise equal — each
    # shard scatters its own Hk/m slice and the stitch is the solo write.
    for a, b in zip(solo, shard):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Engine-level parity: the serving matrix under 2- and 4-way meshes
# ---------------------------------------------------------------------------

def _f32_params(cfg, seed=0):
    return jax.tree.map(lambda x: x.astype(jnp.float32),
                        M.init_params(cfg, jax.random.PRNGKey(seed)))


def _engine_run(cfg, params, mesh, reqs, prefix_cache):
    eng = ServingEngine(cfg, params, max_batch=3, max_len=32,
                        mode="continuous", mesh=mesh, block_size=8,
                        prefill_chunk=8, prefix_cache=prefix_cache,
                        eos_id=-1, seed=5)
    for p, m, pe in reqs:
        eng.submit(p, max_new_tokens=m, patch_embeds=pe)
    out = eng.run()
    s = eng.stats
    return out, (s.preemptions, s.admissions, s.cached_prompt_tokens,
                 s.prefill_tokens, s.generated_tokens,
                 s.prefix_hit_rate)


def _matrix_reqs(cfg, arch, n=4):
    rng = np.random.default_rng(17)
    system = rng.integers(1, cfg.vocab_size, size=9)
    pe = None
    if arch == "internvl2-26b":
        pe = rng.normal(size=(cfg.num_patches, cfg.d_model)) \
                .astype(np.float32)
    reqs = []
    for i in range(n):
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 8)))
        p = np.concatenate([system, tail]) if i % 2 == 0 else tail
        reqs.append((p, 3, pe))
    return reqs


@needs2
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "internvl2-26b"])
@pytest.mark.parametrize("kv_dtype", ["fp", "int8"])
@pytest.mark.parametrize("prefix_cache", [True, False])
def test_engine_sharded_matrix_2way(arch, kv_dtype, prefix_cache):
    cfg = get_config(arch).reduced()
    if kv_dtype != "fp":
        cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    params = _f32_params(cfg)
    reqs = _matrix_reqs(cfg, arch)
    solo_out, solo_sched = _engine_run(cfg, params, None, reqs,
                                       prefix_cache)
    shard_out, shard_sched = _engine_run(cfg, params, _mesh(2), reqs,
                                         prefix_cache)
    assert shard_out == solo_out, "sharded dispatch changed greedy tokens"
    assert shard_sched == solo_sched, (
        "sharded dispatch changed scheduling invariants")


@needs4
@pytest.mark.parametrize("kv_dtype", ["fp", "int8"])
def test_engine_sharded_4way(kv_dtype):
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              num_heads=4, num_kv_heads=4)
    if kv_dtype != "fp":
        cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    params = _f32_params(cfg)
    reqs = _matrix_reqs(cfg, "tinyllama-1.1b")
    solo_out, solo_sched = _engine_run(cfg, params, None, reqs, True)
    shard_out, shard_sched = _engine_run(cfg, params, _mesh(4), reqs, True)
    assert shard_out == solo_out
    assert shard_sched == solo_sched


# ---------------------------------------------------------------------------
# Lowering regression: the pool is never all-gathered on the hot path
# ---------------------------------------------------------------------------

@needs2
def test_sharded_decode_never_allgathers_pool():
    mesh = _mesh(2)
    q, kc, vc, lengths, tables, _ = _paged_inputs(
        6, B=2, H=8, Hk=4, D=32, N=16, bs=8)

    def step(q, kc, vc, lengths, tables):
        return decode_ops.decode_attention(q, kc, vc, lengths,
                                           block_tables=tables, mesh=mesh)

    hlo = jax.jit(step).lower(q, kc, vc, lengths, tables) \
        .compile().as_text()
    stats = roofline.parse_collectives(hlo, total_devices=2)
    pool_bytes = int(np.prod(kc.shape)) * kc.dtype.itemsize
    ag = stats.by_op.get("all-gather", [0, 0, 0])
    # The read path needs NO pool-sized collective: each shard owns its
    # head slice.  Anything all-gather-shaped must be far below one pool
    # leaf (e.g. the (B, H, D) output stitch, if XLA emits one at all).
    assert ag[1] < pool_bytes / 2, (
        f"sharded decode all-gathered ~pool bytes ({ag[1]} vs pool "
        f"{pool_bytes})")


# ---------------------------------------------------------------------------
# cache_specs(paged=True) co-sharding + COW placement (pure-spec + 2-dev)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", list(kv_quant.KV_DTYPES))
def test_cache_specs_cosharded_payload_and_scales(kv_dtype):
    """For every pool encoding, payload leaves shard the KV-head axis
    over ``model`` and (when present) scale leaves shard the SAME axis —
    so a shard always dequantizes locally.  No devices needed."""
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              kv_dtype=kv_dtype)
    cache = M.init_paged_cache(cfg, 2, 8)
    specs = sharding.cache_specs(cfg, cache, None, 1, paged=True)
    assert specs["k"] == P(None, None, None, "model", None)
    assert specs["v"] == specs["k"]
    if kv_quant.is_quantized(kv_dtype):
        assert set(cache) == {"k", "v", "k_scale", "v_scale"}
        assert specs["k_scale"] == P(None, None, None, "model")
        assert specs["v_scale"] == specs["k_scale"]
        # Head axis position: payload axis 3 == scale axis 3.
        assert specs["k"][3] == specs["k_scale"][3] == "model"
    else:
        assert set(cache) == {"k", "v"}


@needs2
@pytest.mark.parametrize("kv_dtype", ["fp", "int8"])
def test_copy_cache_block_preserves_sharding(kv_dtype):
    """COW (ensure_writable's device half) must keep every leaf — payload
    and scales — on its original sharding: a COW event that silently
    replicated the pool would wreck the next sharded step's placement."""
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              kv_dtype=kv_dtype)
    mesh = _mesh(2)
    cache = M.init_paged_cache(cfg, 4, 8, mesh=mesh)
    # Distinct payload per block so the copy is observable.
    cache = jax.tree.map(
        lambda x: (jnp.arange(x.size, dtype=jnp.float32)
                   .reshape(x.shape).astype(x.dtype)), cache)
    cache = jax.device_put(cache, jax.tree.map(
        lambda x: x.sharding, M.init_paged_cache(cfg, 4, 8, mesh=mesh)))
    before = jax.tree.map(lambda x: x.sharding, cache)
    out = M.copy_cache_block(cache, 2, 1)
    for key in cache:
        assert out[key].sharding.is_equivalent_to(
            before[key], out[key].ndim), f"{key} lost its sharding"
        np.testing.assert_array_equal(np.asarray(out[key][:, 1]),
                                      np.asarray(cache[key][:, 2]))
        np.testing.assert_array_equal(np.asarray(out[key][:, 3]),
                                      np.asarray(cache[key][:, 3]))
