"""CC-MEM behavioral model: bank-conflict, burst and SCLD decoder
behavior (paper §3.1/§3.2).  Deterministic pins only — the hypothesis
property sweeps live in test_ccmem_properties.py so these regressions run
even where hypothesis is not installed."""
import numpy as np

from repro.core import ccmem
from repro.core.ccmem import AccessStream, CCMEMConfig, simulate


def test_single_burst_stream_near_peak_of_one_group():
    cfg = CCMEMConfig()
    r = simulate([AccessStream(words=1 << 20, kind="burst")], cfg)
    # One stream can only use one group at a time: achieved fraction of the
    # FULL crossbar is ~1/num_groups (modulo burst overhead).
    assert r["achieved_fraction"] < 2.0 / cfg.num_bank_groups
    assert r["achieved_fraction"] > 0.5 / cfg.num_bank_groups


def test_many_burst_streams_saturate():
    cfg = CCMEMConfig(num_bank_groups=16)
    streams = [AccessStream(words=1 << 16, kind="burst") for _ in range(16)]
    r = simulate(streams, cfg)
    # Sequential interleaves from many ports keep most groups busy.
    assert r["achieved_fraction"] > 0.4


def test_random_access_worse_than_burst():
    cfg = CCMEMConfig(num_bank_groups=16)
    burst = simulate([AccessStream(words=1 << 16, kind="burst")
                      for _ in range(8)], cfg)
    rand = simulate([AccessStream(words=1 << 16, kind="random")
                     for _ in range(8)], cfg)
    assert rand["achieved_fraction"] < burst["achieved_fraction"]


def test_scld_bandwidth_semantics():
    """Paper §3.2: compressed data is never *faster* than dense (same banks,
    extra bits per word) — at 60% sparsity dense-rate is matched (decoder
    cap), below ~33% it is strictly slower. The win is capacity."""
    cfg = CCMEMConfig()
    dense = simulate([AccessStream(words=1 << 20, kind="burst")], cfg)
    s60 = simulate([AccessStream(words=1 << 20, kind="burst",
                                 sparsity=0.6)], cfg)
    s20 = simulate([AccessStream(words=1 << 20, kind="burst",
                                 sparsity=0.2)], cfg)
    assert s60["cycles"] <= dense["cycles"] * 1.01
    # Below ~33% the controller stores dense (storage_factor == 1), so the
    # read rate equals dense — never slower, never faster.
    assert abs(s20["cycles"] - dense["cycles"]) < dense["cycles"] * 0.01


def test_served_words_capped_at_total_words_edge():
    """Regression: the final burst of a stream is shorter than burst_len;
    crediting the full burst used to over-count served_words.  An
    adversarial mix of sub-burst streams on a tiny crossbar must never
    serve more words than exist."""
    streams = [
        AccessStream(words=3, kind="burst", burst_len=512),
        AccessStream(words=1, kind="random", burst_len=32),
        AccessStream(words=513, kind="burst", burst_len=512),  # 1-word tail
        AccessStream(words=700, kind="strided", burst_len=512),
    ]
    total = sum(s.words for s in streams)
    for seed in range(8):  # arbitration order must not matter
        r = simulate(streams, CCMEMConfig(num_bank_groups=2), seed=seed)
        assert 0 < r["served_words"] <= total
        assert 0.0 < r["achieved_fraction"] <= 1.0


def test_gemm_pattern_mostly_burst():
    streams = ccmem.gemm_streams(128, 4096, 4096)
    r = simulate(streams)
    assert r["achieved_fraction"] > 0.01
    # weight stream dominates words
    assert streams[0].words > streams[1].words


def test_decode_pattern_kv_dominated():
    streams = ccmem.attention_decode_streams(32768, 4096, 8, 128)
    assert streams[0].words > 100 * streams[1].words
