"""CC-MEM behavioral model: bank-conflict, burst and SCLD decoder
properties (paper §3.1/§3.2)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ccmem
from repro.core.ccmem import AccessStream, CCMEMConfig, simulate


def test_single_burst_stream_near_peak_of_one_group():
    cfg = CCMEMConfig()
    r = simulate([AccessStream(words=1 << 20, kind="burst")], cfg)
    # One stream can only use one group at a time: achieved fraction of the
    # FULL crossbar is ~1/num_groups (modulo burst overhead).
    assert r["achieved_fraction"] < 2.0 / cfg.num_bank_groups
    assert r["achieved_fraction"] > 0.5 / cfg.num_bank_groups


def test_many_burst_streams_saturate():
    cfg = CCMEMConfig(num_bank_groups=16)
    streams = [AccessStream(words=1 << 16, kind="burst") for _ in range(16)]
    r = simulate(streams, cfg)
    # Sequential interleaves from many ports keep most groups busy.
    assert r["achieved_fraction"] > 0.4


def test_random_access_worse_than_burst():
    cfg = CCMEMConfig(num_bank_groups=16)
    burst = simulate([AccessStream(words=1 << 16, kind="burst")
                      for _ in range(8)], cfg)
    rand = simulate([AccessStream(words=1 << 16, kind="random")
                     for _ in range(8)], cfg)
    assert rand["achieved_fraction"] < burst["achieved_fraction"]


def test_scld_bandwidth_semantics():
    """Paper §3.2: compressed data is never *faster* than dense (same banks,
    extra bits per word) — at 60% sparsity dense-rate is matched (decoder
    cap), below ~33% it is strictly slower. The win is capacity."""
    cfg = CCMEMConfig()
    dense = simulate([AccessStream(words=1 << 20, kind="burst")], cfg)
    s60 = simulate([AccessStream(words=1 << 20, kind="burst",
                                 sparsity=0.6)], cfg)
    s20 = simulate([AccessStream(words=1 << 20, kind="burst",
                                 sparsity=0.2)], cfg)
    assert s60["cycles"] <= dense["cycles"] * 1.01
    # Below ~33% the controller stores dense (storage_factor == 1), so the
    # read rate equals dense — never slower, never faster.
    assert abs(s20["cycles"] - dense["cycles"]) < dense["cycles"] * 0.01


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(0, 10_000))
def test_cycles_monotone_in_streams(n_streams, seed):
    cfg = CCMEMConfig(num_bank_groups=8)
    streams = [AccessStream(words=1 << 12, kind="burst")
               for _ in range(n_streams)]
    r = simulate(streams, cfg, seed=seed)
    assert r["cycles"] >= r["peak_cycles"] * 0.99
    assert 0.0 < r["achieved_fraction"] <= 1.0


def test_gemm_pattern_mostly_burst():
    streams = ccmem.gemm_streams(128, 4096, 4096)
    r = simulate(streams)
    assert r["achieved_fraction"] > 0.01
    # weight stream dominates words
    assert streams[0].words > streams[1].words


def test_decode_pattern_kv_dominated():
    streams = ccmem.attention_decode_streams(32768, 4096, 8, 128)
    assert streams[0].words > 100 * streams[1].words
