"""Serving engine: wave batching, determinism, samplers, MoE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models import model as M, moe as moe_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampler import SamplerConfig, sample


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_generates(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=2, max_len=32, eos_id=-1)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(1, cfg.vocab_size, size=8),
                       max_new_tokens=5) for _ in range(3)]
    out = eng.run()
    assert set(out) == set(uids)
    for toks in out.values():
        assert len(toks) == 5
        assert all(0 <= t < cfg.vocab_size for t in toks)
    assert eng.stats.generated_tokens == 15


def test_engine_greedy_matches_manual_decode(tiny):
    cfg, params = tiny
    prompt = np.arange(1, 9)
    eng = ServingEngine(cfg, params, max_batch=1, max_len=32, eos_id=-1)
    eng.submit(prompt, max_new_tokens=4)
    out = list(eng.run().values())[0]

    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    logits, cache = M.prefill(cfg, params, batch, max_len=32)
    manual = []
    pos = len(prompt)
    for _ in range(4):
        t = int(jnp.argmax(logits.reshape(-1)))
        manual.append(t)
        logits, cache = M.decode_step(
            cfg, params, cache, jnp.full((1, 1), t, jnp.int32),
            jnp.int32(pos))
        logits = logits[:, 0]
        pos += 1
    assert out == manual


def test_engine_waves_bucket_by_length(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=4, max_len=32, eos_id=-1)
    rng = np.random.default_rng(1)
    for ln in (4, 4, 7, 7, 7, 12):
        eng.submit(rng.integers(1, cfg.vocab_size, size=ln),
                   max_new_tokens=2)
    out = eng.run()
    assert len(out) == 6


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "mamba2-1.3b",
                                  "zamba2-7b"])
def test_engine_generates_other_families(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=2, max_len=24, eos_id=-1)
    eng.submit(np.arange(1, 9), max_new_tokens=3)
    out = eng.run()
    (toks,) = out.values()
    assert len(toks) == 3
    assert all(0 <= t < cfg.vocab_size for t in toks)


def test_sampler_greedy_vs_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(SamplerConfig(), logits, jax.random.PRNGKey(0))[0]) == 1
    s = sample(SamplerConfig(temperature=1.0, top_k=2), logits,
               jax.random.PRNGKey(0))
    assert int(s[0]) in (1, 2)


# ---------------------------------------------------------------------------
# MoE dispatch properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000))
def test_moe_capacity_drops_are_bounded(seed):
    """With capacity_factor >= 1 and balanced-ish routing, most tokens get
    served; dropped tokens produce zero expert output (not NaN)."""
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model)
                          ).astype(jnp.bfloat16)
    out, aux = moe_lib.apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    assert float(aux) >= 0.99  # >= 1 for any distribution (Switch aux loss)


def test_moe_identical_tokens_identical_outputs():
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(1), (1, 1, cfg.d_model)),
        (1, 8, cfg.d_model)).astype(jnp.bfloat16)
    out, _ = moe_lib.apply_moe(cfg, p, x)
    out = np.asarray(out, np.float32)
    # All-but-dropped identical tokens produce identical outputs; with
    # capacity >= 8 nothing is dropped here.
    for i in range(1, 8):
        served = np.abs(out[0, i]).sum() > 0
        if served:
            np.testing.assert_allclose(out[0, i], out[0, 0], atol=1e-5)
