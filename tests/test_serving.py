"""Serving engine: scheduling across modes, determinism, samplers.

MoE dispatch property tests moved to ``test_moe_properties.py`` (they need
hypothesis, which is optional).  Continuous-batching bit-identity tests
live in ``test_continuous_batching.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.sampler import SamplerConfig, sample


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_generates(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=2, max_len=32, eos_id=-1)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(1, cfg.vocab_size, size=8),
                       max_new_tokens=5) for _ in range(3)]
    out = eng.run()
    assert set(out) == set(uids)
    for toks in out.values():
        assert len(toks) == 5
        assert all(0 <= t < cfg.vocab_size for t in toks)
    assert eng.stats.generated_tokens == 15


def test_engine_greedy_matches_manual_decode(tiny):
    cfg, params = tiny
    prompt = np.arange(1, 9)
    eng = ServingEngine(cfg, params, max_batch=1, max_len=32, eos_id=-1)
    eng.submit(prompt, max_new_tokens=4)
    out = list(eng.run().values())[0]

    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    logits, cache = M.prefill(cfg, params, batch, max_len=32)
    manual = []
    pos = len(prompt)
    for _ in range(4):
        t = int(jnp.argmax(logits.reshape(-1)))
        manual.append(t)
        logits, cache = M.decode_step(
            cfg, params, cache, jnp.full((1, 1), t, jnp.int32),
            jnp.int32(pos))
        logits = logits[:, 0]
        pos += 1
    assert out == manual


def test_engine_mixed_prompt_lengths_one_batch(tiny):
    """The continuous engine admits mixed lengths into one batch — no
    bucket-by-exact-length restriction (the seed wave engine's limit)."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=4, max_len=32, eos_id=-1)
    rng = np.random.default_rng(1)
    for ln in (4, 4, 7, 7, 7, 12):
        eng.submit(rng.integers(1, cfg.vocab_size, size=ln),
                   max_new_tokens=2)
    out = eng.run()
    assert len(out) == 6
    assert all(len(t) == 2 for t in out.values())
    # First four (mixed 4/4/7/7) go in one admission group; with budget 2
    # the whole trace drains in a handful of shared steps.
    assert eng.stats.decode_steps <= 4


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "mamba2-1.3b",
                                  "zamba2-7b"])
def test_engine_generates_other_families(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=2, max_len=24, eos_id=-1)
    eng.submit(np.arange(1, 9), max_new_tokens=3)
    out = eng.run()
    (toks,) = out.values()
    assert len(toks) == 3
    assert all(0 <= t < cfg.vocab_size for t in toks)


def test_wave_mode_forced_matches_continuous_greedy(tiny):
    """mode='wave' (the benchmark baseline) agrees with continuous."""
    cfg, params = tiny
    prompt = np.arange(1, 9)
    outs = {}
    for mode in ("continuous", "wave"):
        eng = ServingEngine(cfg, params, max_batch=2, max_len=32, eos_id=-1,
                            mode=mode)
        eng.submit(prompt, max_new_tokens=4)
        outs[mode] = list(eng.run().values())[0]
        assert eng.mode == mode
    assert outs["continuous"] == outs["wave"]


def test_continuous_mode_rejects_recurrent_families():
    cfg = get_config("mamba2-1.3b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="block-addressable"):
        ServingEngine(cfg, params, mode="continuous")


def test_stochastic_sampling_reproducible_per_request(tiny):
    """Sampling keys are folded per request uid, so a request's stochastic
    output does not depend on which co-tenants share its decode batch."""
    cfg, params = tiny
    prompt = np.arange(1, 9)
    sam = SamplerConfig(temperature=0.8, top_k=20)
    solo = ServingEngine(cfg, params, max_batch=2, max_len=32, eos_id=-1,
                         sampler=sam, seed=7)
    u = solo.submit(prompt, max_new_tokens=6)
    alone = solo.run()[u]

    shared = ServingEngine(cfg, params, max_batch=2, max_len=32, eos_id=-1,
                           sampler=sam, seed=7)
    u1 = shared.submit(prompt, max_new_tokens=6)  # same uid (first submit)
    shared.submit(np.arange(3, 10), max_new_tokens=6)
    assert shared.run()[u1] == alone


def test_submit_rejects_overlong_prompt(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_batch=1, max_len=16, eos_id=-1)
    with pytest.raises(ValueError, match="no decode room"):
        eng.submit(np.arange(1, 18), max_new_tokens=2)


def test_zero_budget_retires_instantly_in_both_modes(tiny):
    """Both modes complete max_new_tokens < 1 immediately with an empty
    output (identical semantics; no admission, no KV blocks — they used to
    diverge, then both rejected)."""
    cfg, params = tiny
    for mode in ("continuous", "wave"):
        eng = ServingEngine(cfg, params, max_batch=1, max_len=16,
                            eos_id=-1, mode=mode)
        uid = eng.submit(np.arange(1, 5), max_new_tokens=0)
        assert eng.run() == {uid: []}
        assert eng.stats.admissions == 0
        assert eng.stats.generated_tokens == 0


def test_sampler_greedy_vs_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(SamplerConfig(), logits, jax.random.PRNGKey(0))[0]) == 1
    s = sample(SamplerConfig(temperature=1.0, top_k=2), logits,
               jax.random.PRNGKey(0))
    assert int(s[0]) in (1, 2)


def test_sampler_active_mask_is_noop_row():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [0.0, 5.0, 1.0]])
    toks = sample(SamplerConfig(), logits, jax.random.PRNGKey(0),
                  active=jnp.asarray([True, False]), pad_id=7)
    assert toks.tolist() == [1, 7]
