"""ReplicaRouter: placement policies, admission folding, stream identity.

The router's contract (PR 9 rung 2): N independent engines behind one
``submit`` — affinity placement steers a prompt to the replica already
holding its prefix blocks (same hash chain admission uses), rejection
only surfaces when EVERY replica rejected (kind="breaker" iff all were
breaker sheds), and the router never touches tokens (completed streams
bit-identical to a solo engine).  The bench (serving_bench section 8)
owns the affinity-beats-round-robin hit-rate claim; these tests pin the
mechanisms it rests on.
"""
import asyncio
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.frontend import RejectedError
from repro.serving.openloop import TraceItem
from repro.serving.router import (ROUTER_POLICIES, ReplicaRouter,
                                  RouterStats, _FleetBreaker,
                                  run_open_loop_router)
from repro.serving.warmup import trace_prompt_lens, warmup_prefill


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(tiny, **over):
    cfg, params = tiny
    kw = dict(max_batch=3, max_len=32, mode="continuous", block_size=8,
              num_blocks=24, prefill_chunk=8, prefix_cache=True,
              eos_id=-1)
    kw.update(over)
    return ServingEngine(cfg, params, **kw)


# ---------------------------------------------------------------------------
# Construction + policy validation
# ---------------------------------------------------------------------------

def test_rejects_unknown_policy_and_empty_fleet(tiny):
    with pytest.raises(ValueError, match="at least one engine"):
        ReplicaRouter([])
    with pytest.raises(ValueError, match="not in"):
        ReplicaRouter([_engine(tiny)], policy="sticky")
    assert set(ROUTER_POLICIES) == {"affinity", "round_robin"}


# ---------------------------------------------------------------------------
# Placement ordering
# ---------------------------------------------------------------------------

def test_round_robin_cycles(tiny):
    r = ReplicaRouter([_engine(tiny) for _ in range(3)],
                      policy="round_robin")
    prompt = np.arange(1, 9)
    orders = [r._order(prompt, None) for _ in range(4)]
    assert orders[0] == [0, 1, 2]
    assert orders[1] == [1, 2, 0]
    assert orders[2] == [2, 0, 1]
    assert orders[3] == [0, 1, 2]  # wraps


def test_affinity_prefers_replica_holding_prefix(tiny):
    """Warm one replica's prefix cache with a prompt; a request sharing
    its leading blocks must order that replica first, and the stats must
    count it as an affinity hit."""
    cfg, _ = tiny
    warm, cold = _engine(tiny), _engine(tiny)
    rng = np.random.default_rng(3)
    system = rng.integers(1, cfg.vocab_size, size=16)
    warm.submit(np.concatenate([system, [7, 8]]), max_new_tokens=2)
    warm.run()
    assert warm.match_cached_blocks(
        np.concatenate([system, [9, 10, 11]])) > 0
    r = ReplicaRouter([cold, warm], policy="affinity")
    order = r._order(np.concatenate([system, [9, 10, 11]]), None)
    assert order[0] == 1  # the warm replica, despite higher index
    assert r.stats.affinity_hits == 1 and r.stats.affinity_eligible == 1
    # A cold prompt is not affinity-eligible; ties break by load then
    # index (both idle -> but warm holds live=0 after retire? both 0).
    cold_order = r._order(rng.integers(1, cfg.vocab_size, size=6), None)
    assert r.stats.affinity_eligible == 1  # unchanged
    assert set(cold_order) == {0, 1}


def test_affinity_falls_back_to_least_loaded(tiny):
    r = ReplicaRouter([_engine(tiny), _engine(tiny)], policy="affinity")
    # Fake load: replica 0 busy (queued work), replica 1 idle.
    r.frontends[0].engine.submit(np.arange(1, 9), max_new_tokens=2)
    assert r._load(0) >= 0
    loads = [r._load(i) for i in range(2)]
    order = r._order(np.arange(20, 26), None)
    assert order[0] == int(np.argmin(loads))


# ---------------------------------------------------------------------------
# Rejection folding
# ---------------------------------------------------------------------------

def _reject_router(tiny, kinds):
    r = ReplicaRouter([_engine(tiny) for _ in kinds])

    def make_submit(kind):
        async def submit(*a, **k):
            raise RejectedError(f"nope ({kind})", kind=kind)
        return submit

    for fe, kind in zip(r.frontends, kinds):
        fe.submit = make_submit(kind)
    return r


def test_all_breaker_rejections_fold_to_breaker(tiny):
    r = _reject_router(tiny, ["breaker", "breaker"])
    with pytest.raises(RejectedError) as ei:
        asyncio.run(r.submit(np.arange(1, 6), max_new_tokens=2))
    assert ei.value.kind == "breaker"
    assert r.stats.rejected == 1 and r.stats.submitted == 0


def test_mixed_rejections_fold_to_backpressure(tiny):
    """One full queue among shedding replicas means 'retry later', not
    'the fleet is down' — the folded kind must be backpressure."""
    r = _reject_router(tiny, ["breaker", "backpressure"])
    with pytest.raises(RejectedError) as ei:
        asyncio.run(r.submit(np.arange(1, 6), max_new_tokens=2))
    assert ei.value.kind == "backpressure"


def test_spillover_counts_when_first_choice_rejects(tiny):
    r = _reject_router(tiny, ["backpressure", "backpressure"])

    async def accept(*a, **k):
        return SimpleNamespace(uid=1)

    r.frontends[1].submit = accept
    stream = asyncio.run(r.submit(np.arange(1, 6), max_new_tokens=2))
    assert stream.uid == 1
    assert r.stats.spillovers == 1 and r.stats.submitted == 1
    assert r.stats.per_replica == [0, 1]


# ---------------------------------------------------------------------------
# Fleet breaker aggregation
# ---------------------------------------------------------------------------

def test_fleet_breaker_aggregates_worst_state():
    mk = lambda state, opens=1: SimpleNamespace(
        opens=opens, shed=2, state=state, transitions=[(0.0, state)])
    fb = _FleetBreaker([mk("closed"), mk("open")])
    assert fb.state == "open"
    assert fb.opens == 2 and fb.shed == 4
    assert len(fb.transitions) == 2
    assert _FleetBreaker([mk("closed"), mk("half_open")]).state \
        == "half_open"
    assert _FleetBreaker([mk("closed"), mk("closed")]).state == "closed"


# ---------------------------------------------------------------------------
# End-to-end: streams bit-identical to a solo engine + routing report
# ---------------------------------------------------------------------------

def test_routed_streams_match_solo_engine(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(9)
    system = rng.integers(1, cfg.vocab_size, size=8)
    trace = []
    for i in range(6):
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 7)))
        p = np.concatenate([system, tail]) if i % 2 else tail
        trace.append(TraceItem(arrival_s=i * 0.05, prompt=p,
                               max_new_tokens=3))
    engines = [_engine(tiny) for _ in range(2)]
    for e in engines:
        warmup_prefill(e, cfg.vocab_size,
                       prompt_lens=trace_prompt_lens(trace, e,
                                                     extra=(len(system),)))
    report, router = run_open_loop_router(engines, trace,
                                          policy="affinity",
                                          max_queue_depth=8)
    recs = report.records
    assert all(r.status == "completed" for r in recs)
    ref = _engine(tiny)
    uids = [ref.submit(it.prompt, max_new_tokens=it.max_new_tokens)
            for it in trace]
    ref_out = ref.run()
    for uid, rec in zip(uids, recs):
        assert rec.tokens == ref_out[uid], (
            "routed stream diverged from solo-engine greedy")

    rep = router.routing_report()
    assert rep["policy"] == "affinity" and rep["replicas"] == 2
    assert rep["submitted"] == 6 and rep["rejected"] == 0
    assert sum(rep["per_replica_requests"]) == 6
    assert 0.0 <= rep["affinity_hit_rate"] <= 1.0
    assert 0.0 <= rep["prefix_hit_rate"] <= 1.0
    assert rep["generated_tokens"] == sum(len(r.tokens) for r in recs)
    # summary() works through the router's aggregate breaker view.
    summary = report.summary(slo_ttft_s=30.0)
    assert summary["completed"] == 6
    assert summary["breaker"]["final_state"] == "closed"


def test_router_stats_default_shape():
    s = RouterStats()
    assert (s.submitted, s.rejected, s.spillovers) == (0, 0, 0)
    assert s.per_replica == []
