"""Invariant helpers shared by the randomized property suites.

Kept free of hypothesis so deterministic (seeded) trace tests can reuse
them in environments where hypothesis is not installed — the property
modules import from here.
"""


def shared_prefix_sound(store, contents):
    """Any block listed by two lanes implies identical content up to and
    including that block.

    ``contents`` maps slot -> the lane's canonical token contents; a
    lane's block table only ever covers a prefix of it, which is all
    this compares.
    """
    bs = store.block_size
    owners = {}
    for slot, blocks in store._blocks.items():
        for idx, b in enumerate(blocks):
            owners.setdefault(b, []).append((slot, idx))
    for b, occ in owners.items():
        if len(occ) < 2:
            continue
        (s0, i0) = occ[0]
        for (s1, i1) in occ[1:]:
            assert i0 == i1, f"block {b} at different indices"
            n = (i0 + 1) * bs
            assert list(contents[s0][:n]) == list(contents[s1][:n]), (
                f"block {b} shared by lanes with diverging prefixes")
